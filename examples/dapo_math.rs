//! DAPO competition-math scenario (the paper's Table 2 workload): dynamic
//! sampling + decoupled clip + token-mean, with INT8 quantized rollout and
//! the full QuRL recipe (ACR + UAQ).  Prints the sampling-efficiency
//! series (the DAPO-specific metric) alongside reward.
//!
//! Run: cargo run --release --example dapo_math -- [steps]

use anyhow::Result;
use qurl::benchkit as bk;
use qurl::config;
use qurl::metrics::Recorder;
use qurl::rl::{eval as rleval, Trainer};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let (rt, base) = bk::setup()?;
    let mut cfg = config::dapo_aime();
    cfg.steps = steps;
    cfg.eval_every = (steps / 6).max(1);
    println!("DAPO on the AIME analog: {} steps, INT8 rollout, ACR+UAQ, \
              dynamic sampling on", steps);
    let rec = Recorder::create(&bk::results_dir(), "example_dapo")?;
    let mut tr = Trainer::new(&rt, cfg, base, rec)?;
    let final_reward = tr.run()?;
    println!("\nreward        : {}",
             bk::sparkline(&tr.rec.series("reward"), 56));
    println!("dapo efficiency: {}",
             bk::sparkline(&tr.rec.series("dapo_efficiency"), 56));
    println!("clip fraction : {}",
             bk::sparkline(&tr.rec.series("clip_frac"), 56));
    let tk = Tokenizer::new();
    let suite = Suite::by_name("aime").unwrap();
    let w = rt.engine_weights(QuantMode::Bf16, &tr.ps.params)?;
    let avg1 = rleval::greedy_accuracy(&rt, &w, &tk, &suite, 77, 64)?;
    let avg8 = rleval::avg_at_k(&rt, &w, &tk, &suite, 77, 32, 8, 1.0, 0.7)?;
    println!("final reward {final_reward:.3} | Avg@1 {:.1}% | Avg@8 {:.1}%",
             avg1 * 100.0, avg8 * 100.0);
    Ok(())
}
