//! End-to-end driver (DESIGN.md "End-to-end validation"): SFT-pretrain the
//! base actor from scratch, then run a few hundred GRPO steps with INT8
//! quantized rollout + ACR + UAQ on the DeepScaleR-analog suite, logging
//! the reward curve and periodic greedy evaluations.  Exercises every layer:
//! Pallas INT8 kernels (L1) inside the generate/quantize artifacts (L2)
//! driven by the Rust trainer/coordinator (L3).
//!
//! Run: cargo run --release --example e2e_grpo -- [rl_steps] [sft_steps]
//! Defaults: 200 RL steps, 600 SFT steps (~45 min on 8 CPU cores).
//! Results land in results/e2e_grpo.jsonl; summary printed at the end.

use anyhow::Result;
use qurl::benchkit as bk;
use qurl::config;
use qurl::metrics::{Recorder, Row};
use qurl::rl::{self, eval as rleval, Trainer};
use qurl::runtime::{ParamStore, QuantMode, Runtime};
use qurl::tasks::{Suite, Tokenizer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rl_steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let sft_steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(600);

    let rt = std::sync::Arc::new(Runtime::open(&bk::artifacts_dir())?);
    let man = rt.manifest().clone();
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    println!("== e2e: {}-param actor, {} SFT + {} GRPO(INT8+ACR+UAQ) steps ==",
             man.n_params, sft_steps, rl_steps);

    // ---- phase 1: build the base model (the paper's pretrained checkpoint)
    let base_path = bk::results_dir().join("base_model.bin");
    let base = if base_path.exists() {
        println!("[1/3] base checkpoint found, reusing {base_path:?}");
        ParamStore::load(&base_path)?
    } else {
        println!("[1/3] SFT pretraining ({sft_steps} steps)...");
        let init = rt.init_params(0)?;
        let mut ps = ParamStore::new(&man, init);
        let mut rec = Recorder::create(&bk::results_dir(), "e2e_sft")?;
        let t0 = std::time::Instant::now();
        let loss = rl::pretrain_sft(&rt, &mut ps, &suite, sft_steps, 3e-4, 0,
                                    &mut rec)?;
        println!("      SFT loss {loss:.3} in {:.0}s", t0.elapsed().as_secs_f64());
        ps.reset_optimizer();
        ps.save(&base_path)?;
        ps
    };
    let w0 = rt.engine_weights(QuantMode::Bf16, &base.params)?;
    let base_acc = rleval::greedy_accuracy(&rt, &w0, &tk, &suite, 1234, 32)?;
    println!("      base greedy accuracy: {base_acc:.3}");

    // ---- phase 2: QuRL RL training ----------------------------------------
    println!("[2/3] GRPO with INT8 rollout, ACR objective, UAQ s=1.5...");
    let mut cfg = config::deepscaler_grpo();
    cfg.steps = rl_steps;
    cfg.eval_every = (rl_steps / 10).max(1);
    cfg.analyze_every = 8;
    let rec = Recorder::create(&bk::results_dir(), "e2e_grpo")?;
    let mut trainer = Trainer::new(&rt, cfg, base, rec)?;
    let t0 = std::time::Instant::now();
    let final_reward = trainer.run()?;
    let rl_wall = t0.elapsed().as_secs_f64();

    // ---- phase 3: final evaluation ----------------------------------------
    println!("[3/3] final evaluation...");
    let w1 = rt.engine_weights(QuantMode::Bf16, &trainer.ps.params)?;
    let final_acc = rleval::greedy_accuracy(&rt, &w1, &tk, &suite, 1234, 32)?;
    trainer.rec.log(Row::new(rl_steps as u64)
        .set("final_acc", final_acc)
        .tag("phase", "final"));
    trainer.rec.write_csv(&bk::results_dir(),
                          &["reward", "eval_acc", "kl_behav_prox",
                            "clip_frac"])?;
    trainer.ps.save(&bk::results_dir().join("e2e_grpo_final.bin"))?;

    println!("\n== e2e summary ==");
    println!("reward curve : {}", bk::sparkline(&trainer.rec.series("reward"), 60));
    println!("eval curve   : {}", bk::sparkline(&trainer.rec.series("eval_acc"), 60));
    println!("base greedy  : {base_acc:.3}");
    println!("final greedy : {final_acc:.3}  (delta {:+.3})",
             final_acc - base_acc);
    println!("final reward : {final_reward:.3}");
    println!("RL wall time : {rl_wall:.0}s ({:.1}s/step)",
             rl_wall / rl_steps.max(1) as f64);
    let mut xla = 0.0;
    for (name, st) in rt.store.stats().into_iter().take(5) {
        println!("  {name:16} {:5} calls {:8.1}s  {:7.1} MB h2d",
                 st.calls, st.secs, st.bytes_h2d as f64 / 1e6);
        xla += st.secs;
    }
    println!("  (top-5 XLA time {xla:.0}s of {rl_wall:.0}s wall)");
    anyhow::ensure!(final_acc >= base_acc - 0.02,
                    "RL did not hold/improve accuracy");
    println!("\ne2e PASS: all three layers compose; RL improved the actor.");
    Ok(())
}
