//! Quantization inspector: per-matrix INT8/FP8 error, the UAQ effect
//! (Eq. 11-12), and the resulting policy divergence between the quantized
//! and full-precision actors — the microscope behind §4.3.
//!
//! Run: cargo run --release --example quant_inspect

use anyhow::Result;
use qurl::benchkit as bk;
use qurl::quant::analysis;
use qurl::runtime::QuantMode;
use qurl::tasks::{encode_batch, Suite, Tokenizer};
use qurl::util::timer::print_table;

fn main() -> Result<()> {
    let (rt, base) = bk::setup()?;
    let man = rt.manifest().clone();

    // Per-matrix INT8 error and absolute grid, plain vs UAQ.  Symmetric
    // absmax quantization is scale-invariant (Q(W/s)*s == Q(W)), so the
    // *normalized* error (Eq. 14) is identical — UAQ's lever is the
    // *absolute* grid: steps shrink by s while Adam-sized updates don't,
    // so training updates cross code boundaries s-times more often (Eq. 12).
    let scaled = rt.uaq_scale(&base.params, 1.5)?;
    let mut rows = Vec::new();
    for (label, params) in [("plain", &base.params), ("uaq s=1.5", &scaled)] {
        let b = &params[man.a_size..];
        analysis::for_each_mat(&man, |name, off, k, n| {
            let w = &b[off..off + k * n];
            let (q, s) = qurl::quant::int8::weight_quant(w, k, n);
            let deq = qurl::quant::int8::dequant(&q, &s, k, n);
            let err: f64 = w.iter().zip(&deq)
                .map(|(&a, &d)| ((a - d) as f64).powi(2)).sum();
            let norm: f64 = w.iter().map(|&a| (a as f64).powi(2)).sum();
            let step: f64 = s.iter().map(|&x| x as f64).sum::<f64>()
                / s.len() as f64;
            rows.push(vec![label.to_string(), name.to_string(),
                           format!("{:.3e}", err / norm.max(1e-30)),
                           format!("{:.3e}", step)]);
        });
    }
    print_table("per-matrix INT8 error (Eq. 14, scale-invariant) + absolute \
                 grid step (UAQ's lever)",
                &["params", "matrix", "norm err", "mean step"], &rows);

    // whole-model error + policy gap
    let mut rows = Vec::new();
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let probs = suite.test_set(3, 11);
    let refs: Vec<&qurl::tasks::Problem> =
        probs.iter().take(man.rollout_batch).map(|(_, p)| p).collect();
    let (tokens, lens) = encode_batch(&tk, &refs, man.rollout_batch,
                                      man.max_seq, man.max_prompt);
    for (label, params) in [("plain", &base.params), ("uaq s=1.5", &scaled)] {
        for mode in [QuantMode::Int8, QuantMode::Fp8] {
            let err = analysis::normalized_quant_error(
                &man, &params[man.a_size..], mode);
            // policy divergence on real rollouts: sample with the quantized
            // engine, compare behavior lp against the fp actor
            let w = rt.engine_weights(mode, params)?;
            let gen = rt.generate(&w, &tokens, &lens, 9, 1.0, 1.0)?;
            let lp_fp = rt.score_bf16(params, &gen.tokens)?.logprob;
            let mut gap = 0.0f64;
            let mut kl = 0.0f64;
            let mut n = 0.0;
            for i in 0..gen.mask.len() {
                if gen.mask[i] > 0.5 {
                    gap += ((gen.logprob[i] - lp_fp[i]).abs()) as f64;
                    kl += (gen.logprob[i] - lp_fp[i]) as f64;
                    n += 1.0;
                }
            }
            rows.push(vec![label.to_string(), mode.tag().to_string(),
                           format!("{err:.3e}"),
                           format!("{:.4}", gap / n),
                           format!("{:.5}", kl / n)]);
        }
    }
    print_table("policy divergence of the quantized engine",
                &["params", "mode", "weight err", "mean |dlp|",
                  "KL(behav||prox)"], &rows);
    println!("\nUAQ shrinks both the weight error (~1/s^2) and the policy \
              gap the decoupled objective must correct.");
    Ok(())
}
