//! Quickstart: the QuRL pipeline in ~60 lines.
//!
//! Loads the AOT artifacts, initializes an actor, quantizes it to INT8,
//! rolls out a batch of math problems on the quantized engine, verifies
//! rewards, and runs one ACR policy-gradient step — the full Fig. 1 cycle.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use qurl::metrics::Recorder;
use qurl::rl::{Trainer, TrainerConfig};
use qurl::runtime::{ParamStore, QuantMode, Runtime};
use qurl::tasks::Tokenizer;

fn main() -> Result<()> {
    // 1. the runtime executes HLO artifacts via PJRT; Python is build-only
    //    (Arc: the trainer and its rollout engines share the handle)
    let rt = std::sync::Arc::new(
        Runtime::open(std::path::Path::new("artifacts"))?);
    let man = rt.manifest().clone();
    println!("model: {} params | rollout batch {} | context {}",
             man.n_params, man.rollout_batch, man.max_seq);

    // 2. actor parameters (deterministic init; real runs start from the
    //    SFT base checkpoint — see `qurl pretrain`)
    let params = rt.init_params(0)?;
    let ps = ParamStore::new(&man, params);

    // 3. one QuRL RL step: INT8 rollout + ACR objective
    let cfg = TrainerConfig {
        rollout_mode: QuantMode::Int8,
        steps: 1,
        suite: "gsm8k".into(),
        ..TrainerConfig::default()
    };
    let rec = Recorder::ephemeral("quickstart");
    let mut trainer = Trainer::new(&rt, cfg, ps, rec)?;
    let reward = trainer.step(0)?;
    println!("step 0: mean reward {reward:.3} (random-init model — expect ~0)");

    // 4. inspect a rollout directly
    let w = rt.engine_weights(QuantMode::Int8, &trainer.ps.params)?;
    let tk = Tokenizer::new();
    let suite = qurl::tasks::Suite::by_name("gsm8k").unwrap();
    let probs = suite.test_set(7, 2);
    let refs: Vec<&qurl::tasks::Problem> = probs.iter().map(|(_, p)| p).collect();
    let (tokens, lens) = qurl::tasks::encode_batch(
        &tk, &refs, man.rollout_batch, man.max_seq, man.max_prompt);
    let gen = rt.generate(&w, &tokens, &lens, 1, 1.0, 1.0)?;
    for r in 0..2 {
        let row = &gen.tokens[r * man.max_seq..(r + 1) * man.max_seq];
        println!("prompt: {:24} -> model says: {:?} (answer: {})",
                 refs[r].prompt,
                 tk.decode_generation(row, lens[r] as usize),
                 refs[r].answer);
    }
    println!("\nnext: `qurl pretrain` then `qurl train --preset \
              deepscaler_grpo` for a real run.");
    Ok(())
}
