//! Fig. 8 scenario as a standalone tool: sweep the decode roofline over
//! GPU types, model scales, precisions, batch sizes and context lengths;
//! optionally cross-check against the serving scheduler on this testbed.
//!
//! Run: cargo run --release --example throughput_sim -- [--serve]

use anyhow::Result;
use qurl::benchkit as bk;
use qurl::coordinator::{RolloutRequest, Scheduler, StepEngine};
use qurl::perfmodel::{self, roofline, DecodeConfig, Precision};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer};
use qurl::util::timer::print_table;

fn main() -> Result<()> {
    // full sweep: precision x scale x gpu
    let cfg = DecodeConfig::default();
    let mut rows = Vec::new();
    for prec in [Precision::Bf16, Precision::Int8, Precision::Fp8] {
        for scale in roofline::ALL_SCALES {
            for gpu in perfmodel::ALL_GPUS {
                let q = perfmodel::decode_throughput(gpu, scale, prec, &cfg);
                let s = perfmodel::speedup(gpu, scale, prec, &cfg);
                rows.push(vec![format!("{prec:?}"),
                               scale.name().to_string(),
                               gpu.spec().name.to_string(),
                               format!("{q:.2}"),
                               format!("{:.2}x", s)]);
            }
        }
    }
    print_table("decode roofline sweep",
                &["precision", "model", "gpu", "queries/s", "vs bf16"], &rows);

    // context-length sensitivity: the un-quantized fp16 KV cache erodes the
    // INT8 gain as contexts grow (why the paper excludes KV quantization
    // from the wins, and why bigger models still gain more)
    let mut rows = Vec::new();
    for ctx in [512, 2048, 8192, 16384] {
        let c = DecodeConfig { ctx, ..cfg };
        let s7 = perfmodel::speedup(perfmodel::Gpu::H100, roofline::ModelScale::B7,
                                    Precision::Int8, &c);
        let s32 = perfmodel::speedup(perfmodel::Gpu::H100, roofline::ModelScale::B32,
                                     Precision::Int8, &c);
        rows.push(vec![ctx.to_string(),
                       format!("{:.0}%", (s7 - 1.0) * 100.0),
                       format!("{:.0}%", (s32 - 1.0) * 100.0)]);
    }
    print_table("INT8 speedup vs context length (H100)",
                &["ctx", "7B", "32B"], &rows);

    if std::env::args().any(|a| a == "--serve") {
        println!("\nserving-scheduler cross-check on this testbed...");
        let (rt, base) = bk::setup()?;
        let man = rt.manifest().clone();
        let tk = Tokenizer::new();
        let suite = Suite::by_name("deepscaler").unwrap();
        for mode in [QuantMode::Bf16, QuantMode::Int8] {
            let w = rt.engine_weights(mode, &base.params)?;
            let mut engine = StepEngine::new(&rt, w);
            let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
            let mut sampler = suite.train_sampler(3);
            for id in 0..64u64 {
                let (_, prob) = sampler.next();
                sched.submit(RolloutRequest {
                    id,
                    prompt: std::sync::Arc::new(
                        tk.encode_prompt(&prob.prompt)),
                    max_new: 32, temperature: 1.0, top_p: 1.0, seed: id,
                });
            }
            let res = sched.run_to_completion()?;
            println!("  {:5}: {} reqs, {:.1} tok/s, occupancy {:.2}",
                     mode.tag(), res.len(), sched.stats.tokens_per_s(),
                     sched.stats.mean_occupancy());
        }
    }
    Ok(())
}
