"""AOT driver: lower every L2 entry point to HLO text + manifest.json.

Run via ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).
Python runs ONCE here; the Rust coordinator is self-contained afterwards.

Interchange format is HLO *text* (not serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import ModelConfig, FLAGS, ARTIFACTS
from . import model as M

METRIC_NAMES = [
    "loss", "pg_loss", "kl_ref", "entropy", "value_loss", "clip_frac",
    "ratio_mean", "ratio_max", "rho_max", "grad_norm", "trunc_frac",
    "prob_diff_behav_prox", "kl_behav_prox", "clip_hi_mean", "update_norm",
    "lp_theta_mean",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entry_points(cfg: ModelConfig):
    """name -> (fn, example_args).  Keep signatures in sync with
    rust/src/runtime/exec.rs (the manifest carries them for verification)."""
    f32, i32 = jnp.float32, jnp.int32
    P, Pa, Pb, Nq = cfg.n_params, cfg.a_size, cfg.b_size, cfg.n_qscales
    B, S, Pr = cfg.rollout_batch, cfg.max_seq, cfg.max_prompt
    Bt, T = cfg.train_batch, cfg.max_seq
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    NF = FLAGS.N
    max_new = S - Pr

    params = _sds((P,), f32)
    flat_a = _sds((Pa,), f32)
    flat_b = _sds((Pb,), f32)
    qw = _sds((Pb,), jnp.int8)
    qs = _sds((Nq,), f32)
    toks_r = _sds((B, S), i32)
    lens = _sds((B,), i32)
    kv = _sds((L, B, H, S, Dh), f32)
    pos = _sds((B,), i32)
    tok1 = _sds((B,), i32)
    toks_t = _sds((Bt, T), i32)
    grid_t = _sds((Bt, T), f32)
    scalar_f = _sds((), f32)
    scalar_i = _sds((), i32)
    flags = _sds((NF,), f32)
    prompt = _sds((B, Pr), i32)

    def w_bf(p):
        return M.weights_bf16(cfg, p)

    def w_i8(a, q, s):
        return M.weights_int8(cfg, a, q, s)

    def w_f8(a, b):
        return M.weights_fp8(cfg, a, b)

    eps = {}

    eps["init_params"] = (
        lambda seed: (M.init_params(cfg, seed),),
        [scalar_i])

    # ---- rollout (generate): the QuRL hot path ---------------------------
    eps["generate_bf16"] = (
        lambda p, t, l, seed, temp, tp:
            M.generate(cfg, w_bf(p), t, l, seed, temp, tp, max_new),
        [params, toks_r, lens, scalar_i, scalar_f, scalar_f])
    eps["generate_int8"] = (
        lambda a, q, s, t, l, seed, temp, tp:
            M.generate(cfg, w_i8(a, q, s), t, l, seed, temp, tp, max_new),
        [flat_a, qw, qs, toks_r, lens, scalar_i, scalar_f, scalar_f])
    eps["generate_fp8"] = (
        lambda a, b, t, l, seed, temp, tp:
            M.generate(cfg, w_f8(a, b), t, l, seed, temp, tp, max_new),
        [flat_a, flat_b, toks_r, lens, scalar_i, scalar_f, scalar_f])

    # ---- serving-style prefill/decode (per-step scheduler path) ----------
    eps["prefill_bf16"] = (
        lambda p, t, l: M.prefill(cfg, w_bf(p), t, l),
        [params, prompt, lens])
    eps["prefill_int8"] = (
        lambda a, q, s, t, l: M.prefill(cfg, w_i8(a, q, s), t, l),
        [flat_a, qw, qs, prompt, lens])
    eps["prefill_fp8"] = (
        lambda a, b, t, l: M.prefill(cfg, w_f8(a, b), t, l),
        [flat_a, flat_b, prompt, lens])
    eps["decode_bf16"] = (
        lambda p, ck, cv, ps, tk: M.decode_step(cfg, w_bf(p), ck, cv, ps, tk),
        [params, kv, kv, pos, tok1])
    eps["decode_int8"] = (
        lambda a, q, s, ck, cv, ps, tk:
            M.decode_step(cfg, w_i8(a, q, s), ck, cv, ps, tk),
        [flat_a, qw, qs, kv, kv, pos, tok1])
    eps["decode_fp8"] = (
        lambda a, b, ck, cv, ps, tk:
            M.decode_step(cfg, w_f8(a, b), ck, cv, ps, tk),
        [flat_a, flat_b, kv, kv, pos, tok1])

    # ---- teacher-forced scoring ------------------------------------------
    eps["logprob_bf16"] = (
        lambda p, t: M.sequence_scores(cfg, w_bf(p), t),
        [params, toks_t])
    eps["logprob_int8"] = (
        lambda a, q, s, t: (M.sequence_scores(cfg, w_i8(a, q, s), t)[0],),
        [flat_a, qw, qs, toks_t])
    eps["logprob_fp8"] = (
        lambda a, b, t: (M.sequence_scores(cfg, w_f8(a, b), t)[0],),
        [flat_a, flat_b, toks_t])

    # ---- optimization -----------------------------------------------------
    eps["train_step"] = (
        lambda p, m, v, st, t, mk, ad, lb, lpx, lr_, rt, ov, fl:
            M.train_step(cfg, p, m, v, st, t, mk, ad, lb, lpx, lr_, rt, ov, fl),
        [params, params, params, scalar_f, toks_t, grid_t, grid_t, grid_t,
         grid_t, grid_t, grid_t, grid_t, flags])
    eps["sft_step"] = (
        lambda p, m, v, st, t, mk, fl: M.sft_step(cfg, p, m, v, st, t, mk, fl),
        [params, params, params, scalar_f, toks_t, grid_t, flags])

    # ---- quantization ------------------------------------------------------
    eps["quantize_int8"] = (
        lambda b: M.quantize_section_b_int8(cfg, b), [flat_b])
    eps["quantize_fp8"] = (
        lambda b: (M.quantize_section_b_fp8(cfg, b),), [flat_b])
    eps["uaq_scale"] = (
        lambda p, s: (M.uaq_scale(cfg, p, s),), [params, scalar_f])

    return eps


def lower_all(cfg: ModelConfig, out_dir: str, only=None, verbose=True):
    eps = build_entry_points(cfg)
    os.makedirs(out_dir, exist_ok=True)
    sigs = {}
    for name, (fn, args) in eps.items():
        if only and name not in only:
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *args)
        sigs[name] = {
            "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in args],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in out_avals],
        }
        if verbose:
            print(f"  {name:16s} {len(text)/1e6:7.2f} MB hlo "
                  f"({time.time()-t0:5.1f}s)", flush=True)
    return sigs


def write_manifest(cfg: ModelConfig, sigs, out_dir: str):
    manifest = {
        "config": cfg.to_manifest_dict(),
        "flags": {k: getattr(FLAGS, k) for k in
                  [a for a in dir(FLAGS) if a.isupper()]},
        "metric_names": METRIC_NAMES,
        "special_tokens": {"pad": M.PAD_ID, "bos": M.BOS_ID, "eos": M.EOS_ID},
        "max_new": cfg.max_seq - cfg.max_prompt,
        "artifacts": sigs,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="lower a subset (still rewrites the manifest)")
    args = ap.parse_args()
    cfg = ModelConfig()
    t0 = time.time()
    print(f"lowering {len(ARTIFACTS) + 4} artifacts "
          f"(model: {cfg.n_params} params)", flush=True)
    sigs = lower_all(cfg, args.out_dir, only=args.only)
    write_manifest(cfg, sigs, args.out_dir)
    print(f"done in {time.time()-t0:.1f}s -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
