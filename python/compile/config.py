"""Model / artifact configuration shared by the L2 model and the AOT driver.

The same numbers are emitted into ``artifacts/manifest.json`` so the Rust
coordinator (L3) never hard-codes shapes: it reads the manifest and sizes its
buffers from it.  Keep this file dependency-free (no jax import) so the AOT
driver can be introspected cheaply.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Transformer LM dimensions (the paper's actor, scaled to this testbed).

    The paper trains 0.5B-32B Qwen/DeepSeek models; the QuRL phenomena
    (importance-ratio blow-up, clipping instability, update-vs-quantization
    noise mismatch) are dimensionless, so we reproduce them on a from-scratch
    ~0.8M-param model (see DESIGN.md §2 for the substitution argument).
    """

    vocab_size: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 128          # KV-cache length == train sequence length
    max_prompt: int = 48        # prefill artifact width
    rollout_batch: int = 64     # decode/prefill batch (GRPO: 8 prompts x G=8)
    train_batch: int = 64       # train_step microbatch (sequences)
    # INT8 W8A8 tiling (TPU-shaped; interpret=True on CPU). 'fused' profile
    # uses one block over K for speed; 'tiled' splits K for the VMEM story.
    block_m: int = 64
    block_n: int = 128
    block_k: int = 128
    kernel_profile: str = "fused"  # "fused" | "tiled"

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # ---- flat parameter layout -------------------------------------------
    # Section A (never quantized): embed, pos, norms, lm head, value head.
    # Section B (quantized matrices): per layer qkv, attn_out, mlp_up,
    # mlp_down.  A comes first so Rust can slice [0..a_size) / [a_size..).
    def section_a(self) -> List[Tuple[str, Tuple[int, ...]]]:
        names: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed", (self.vocab_size, self.d_model)),
            ("pos", (self.max_seq, self.d_model)),
        ]
        for l in range(self.n_layers):
            names.append((f"layer{l}.ln1", (self.d_model,)))
            names.append((f"layer{l}.ln2", (self.d_model,)))
        names.append(("ln_f", (self.d_model,)))
        names.append(("head", (self.d_model, self.vocab_size)))
        names.append(("v_head", (self.d_model,)))
        names.append(("v_bias", (1,)))
        return names

    def section_b(self) -> List[Tuple[str, Tuple[int, ...]]]:
        names: List[Tuple[str, Tuple[int, ...]]] = []
        for l in range(self.n_layers):
            names.append((f"layer{l}.qkv", (self.d_model, 3 * self.d_model)))
            names.append((f"layer{l}.attn_out", (self.d_model, self.d_model)))
            names.append((f"layer{l}.mlp_up", (self.d_model, self.d_ff)))
            names.append((f"layer{l}.mlp_down", (self.d_ff, self.d_model)))
        return names

    def layout(self) -> List[Tuple[str, Tuple[int, ...]]]:
        return self.section_a() + self.section_b()

    @staticmethod
    def _numel(shape: Tuple[int, ...]) -> int:
        n = 1
        for s in shape:
            n *= s
        return n

    @property
    def a_size(self) -> int:
        return sum(self._numel(s) for _, s in self.section_a())

    @property
    def b_size(self) -> int:
        return sum(self._numel(s) for _, s in self.section_b())

    @property
    def n_params(self) -> int:
        return self.a_size + self.b_size

    @property
    def n_qscales(self) -> int:
        """One scale per output channel of each quantized matrix."""
        return sum(s[-1] for _, s in self.section_b())

    def offsets(self):
        """name -> (offset, shape) over the full flat vector (A then B)."""
        out = {}
        off = 0
        for name, shape in self.layout():
            out[name] = (off, shape)
            off += self._numel(shape)
        return out

    def scale_offsets(self):
        """name -> (offset, n_channels) into the flat per-channel scale vec."""
        out = {}
        off = 0
        for name, shape in self.section_b():
            out[name] = (off, shape[-1])
            off += shape[-1]
        return out

    def to_manifest_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["a_size"] = self.a_size
        d["b_size"] = self.b_size
        d["n_params"] = self.n_params
        d["n_qscales"] = self.n_qscales
        d["params"] = [
            {"name": n, "shape": list(s), "offset": self.offsets()[n][0]}
            for n, s in self.layout()
        ]
        d["qscales"] = [
            {"name": n, "offset": self.scale_offsets()[n][0],
             "channels": self.scale_offsets()[n][1]}
            for n, _ in self.section_b()
        ]
        return d


@dataclass(frozen=True)
class TrainFlags:
    """Indices into the flat f32 `flags` input of the train_step artifact.

    Keep in sync with rust/src/rl/objective.rs (FLAG_* constants) — the
    manifest also carries these indices for cross-checking.
    """

    OBJ_MODE: int = 0       # 0=onpolicy 1=naive(Eq.3) 2=decoupled(Eq.4)
    #                         3=TIS(Eq.5) 4=ACR(Eq.9)
    EPS_LOW: int = 1        # lower clip epsilon
    EPS_HIGH: int = 2       # upper clip epsilon (DAPO decoupled clip)
    TIS_CAP: int = 3        # C in min(pi_prox/pi_behav, C)
    KL_COEF: int = 4        # k3 KL-to-reference coefficient (GRPO)
    VF_COEF: int = 5        # value-loss coefficient (PPO)
    ENT_COEF: int = 6       # entropy bonus coefficient
    TOKEN_MEAN: int = 7     # 0 = GRPO seq-mean-of-token-mean, 1 = DAPO token-mean
    LR: int = 8
    BETA1: int = 9
    BETA2: int = 10
    ADAM_EPS: int = 11
    WEIGHT_DECAY: int = 12
    VALUE_CLIP: int = 13
    MAX_GRAD_NORM: int = 14  # 0 = no clipping
    N: int = 15


FLAGS = TrainFlags()

# Artifact names (basenames under artifacts/); the Rust runtime enumerates
# this list from the manifest.
ARTIFACTS = [
    "prefill_bf16",
    "prefill_int8",
    "prefill_fp8",
    "decode_bf16",
    "decode_int8",
    "decode_fp8",
    "logprob_bf16",
    "logprob_int8",
    "logprob_fp8",
    "train_step",
    "quantize_int8",
    "quantize_fp8",
    "uaq_scale",
    "init_params",
]
