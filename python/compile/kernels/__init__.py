"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""
from . import ref, int8, fp8, quantize  # noqa: F401
