"""Pallas FP8 (e4m3fn) fake-quantized matmul (L1).

The FP8 rollout path of the paper uses vLLM's FP8 GEMMs.  On this testbed we
emulate e4m3fn *exactly* (RNE onto the 3-mantissa-bit grid, saturation at
+-448, subnormals to 2^-9) in f32 — "fake quantization".  Weights arrive
already fake-quantized (per-output-channel scale folded back in, see
ref.weight_quant_fp8 / the quantize_fp8 artifact); the kernel fuses
token-wise activation fake-quantization into its prologue and runs the GEMM
in f32 (a real deployment would keep e4m3 operands and accumulate in f32 on
the MXU — numerics are identical).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import E4M3_MAX, E4M3_MIN_EXP, E4M3_MAX_EXP, SCALE_EPS


def _quant_e4m3(x):
    """In-kernel e4m3fn grid rounding (same math as ref.quant_e4m3)."""
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(2.0 ** -40))))
    e = jnp.clip(e, E4M3_MIN_EXP, E4M3_MAX_EXP)
    step = jnp.exp2(e - 3.0)
    q = jnp.round(x / step) * step
    return jnp.clip(q, -E4M3_MAX, E4M3_MAX)


def _fp8_kernel(x_ref, w_ref, o_ref):
    """Block: x [bm, K] f32, w_fq [K, bn] f32 -> o [bm, bn] f32."""
    x = x_ref[...]
    # prologue: token-wise scaled e4m3 fake quantization
    absmax = jnp.max(jnp.abs(x), axis=1)
    s = jnp.maximum(absmax, SCALE_EPS) / E4M3_MAX
    xq = _quant_e4m3(x / s[:, None]) * s[:, None]
    o_ref[...] = jnp.dot(xq, w_ref[...])


def fp8_matmul(x, w_fq, *, block_m=64, block_n=128):
    """x [M, K] f32 @ w_fq [K, N] (fake-quantized f32) -> [M, N] f32."""
    m, k = x.shape
    k2, n = w_fq.shape
    assert k == k2
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _fp8_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_fq)


def _quant_e4m3_kernel(x_ref, o_ref):
    o_ref[...] = _quant_e4m3(x_ref[...])


def quant_e4m3_pallas(x, *, block=4096):
    """Standalone e4m3 grid rounding over a flat vector (used by the
    quantize_fp8 artifact's per-channel path and by tests)."""
    (n,) = x.shape
    b = min(block, n)
    assert n % b == 0
    return pl.pallas_call(
        _quant_e4m3_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
