"""Pallas INT8 W8A8 matmul — the paper's rollout hot-spot (L1).

The paper rides vLLM's CUTLASS INT8 GEMMs (threadblock tiling + tensor
cores).  Re-expressed for TPU (see DESIGN.md §6 Hardware-Adaptation):

* the HBM<->VMEM schedule is a Pallas grid + BlockSpecs — (M, N[, K]) tiles
  instead of CUDA threadblocks;
* the MXU systolic array is fed i8 x i8 -> i32; on this CPU testbed we run
  ``interpret=True`` so the i32 accumulation is emulated with *exact* f32
  integer arithmetic (|acc| <= 127^2 * K < 2^24 for K <= 1024 — asserted);
* token-wise activation quantization (absmax -> scale -> RNE round) is fused
  into the kernel prologue, exactly where vLLM fuses it into the GEMM;
* per-output-channel weight scales multiply the accumulator in the epilogue.

Two profiles (ModelConfig.kernel_profile):
  "fused"  — one kernel, grid (M/bm, N/bn), whole K resident in VMEM.  The
             default: all QuRL layer shapes (K <= 512) fit comfortably.
  "tiled"  — split-K pipeline, grid (M/bm, N/bn, K/bk) with a separate
             activation-quant kernel; the shape a real TPU would use when K
             outgrows VMEM.  Kept for the VMEM-schedule ablation.
Both are validated against kernels/ref.py (bit-exact).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INT8_QMAX, SCALE_EPS

# Exactness bound for f32 emulation of the i32 MXU accumulator.
_MAX_EXACT_K = 1024


def _ceil_div(a, b):
    return (a + b - 1) // b


# --------------------------------------------------------------------------
# fused profile: activation-quant prologue + GEMM in one kernel
# --------------------------------------------------------------------------

def _fused_kernel(x_ref, wq_ref, wscale_ref, o_ref):
    """Block: x [bm, K] f32, wq [K, bn] i8, wscale [bn] f32 -> o [bm, bn]."""
    x = x_ref[...]
    # prologue: token-wise symmetric int8 quantization (fused, like vLLM)
    absmax = jnp.max(jnp.abs(x), axis=1)
    ascale = jnp.maximum(absmax, SCALE_EPS) / INT8_QMAX
    xq = jnp.clip(jnp.round(x / ascale[:, None]), -INT8_QMAX, INT8_QMAX)
    # MXU: i8 x i8 -> i32; f32 ints are exact here (|acc| < 2^24, K <= 1024)
    acc = jnp.dot(xq, wq_ref[...].astype(jnp.float32))
    # epilogue: dequantize with a_scale[m] * w_scale[n]
    o_ref[...] = acc * ascale[:, None] * wscale_ref[...][None, :]


def int8_matmul_fused(x, wq, wscale, *, block_m=64, block_n=128):
    """x [M, K] f32 @ wq [K, N] i8 (per-channel wscale [N]) -> [M, N] f32."""
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and wscale.shape == (n,)
    assert k <= _MAX_EXACT_K, "f32 emulation of i32 accumulate needs K<=1024"
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _fused_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, wq, wscale)


# --------------------------------------------------------------------------
# tiled profile: standalone act-quant kernel + split-K GEMM
# --------------------------------------------------------------------------

def _act_quant_kernel(x_ref, xq_ref, s_ref):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=1)
    s = jnp.maximum(absmax, SCALE_EPS) / INT8_QMAX
    xq_ref[...] = jnp.clip(jnp.round(x / s[:, None]), -INT8_QMAX, INT8_QMAX
                           ).astype(jnp.int8)
    s_ref[...] = s


def act_quant_int8_pallas(x, *, block_m=64):
    """Token-wise int8 activation quantization as its own Pallas kernel."""
    m, k = x.shape
    bm = min(block_m, m)
    assert m % bm == 0
    return pl.pallas_call(
        _act_quant_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,
    )(x)


def _tiled_kernel(nk, xq_ref, ascale_ref, wq_ref, wscale_ref, o_ref):
    """Split-K accumulation: grid (M/bm, N/bn, K/bk), K innermost.

    o_ref doubles as the accumulator (raw integer partial sums, exact in
    f32); the epilogue on the last K step applies both scales.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(xq_ref[...].astype(jnp.float32),
                   wq_ref[...].astype(jnp.float32))
    o_ref[...] += part

    @pl.when(kk == nk - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...]
                      * ascale_ref[...][:, None]
                      * wscale_ref[...][None, :])


def int8_matmul_tiled(x, wq, wscale, *, block_m=64, block_n=128, block_k=128):
    """Split-K W8A8 GEMM (act-quant kernel + 3D-grid GEMM kernel)."""
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2 and wscale.shape == (n,)
    assert k <= _MAX_EXACT_K
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    xq, ascale = act_quant_int8_pallas(x, block_m=bm)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_tiled_kernel, nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(xq, ascale, wq, wscale)


def int8_matmul(x, wq, wscale, *, profile="fused",
                block_m=64, block_n=128, block_k=128):
    """Dispatch on kernel profile (see module docstring)."""
    if profile == "fused":
        return int8_matmul_fused(x, wq, wscale, block_m=block_m,
                                 block_n=block_n)
    if profile == "tiled":
        return int8_matmul_tiled(x, wq, wscale, block_m=block_m,
                                 block_n=block_n, block_k=block_k)
    raise ValueError(f"unknown kernel profile {profile!r}")


def vmem_bytes_fused(block_m, k, block_n):
    """VMEM footprint estimate of one fused-profile block (DESIGN.md §8)."""
    x = block_m * k * 4          # f32 activations
    xq = block_m * k * 4         # quantized copy (interpret keeps f32 width)
    w = k * block_n * 1          # i8 weights
    o = block_m * block_n * 4    # f32 out tile
    scales = (block_m + block_n) * 4
    return x + xq + w + o + scales
