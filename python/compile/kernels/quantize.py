"""Pallas per-output-channel weight quantizers (L1).

These back the ``quantize_int8`` / ``quantize_fp8`` artifacts that L3 runs
once per RL step to refresh the rollout engine's weights — the QuRL pipeline
step "theta_old -> Q(theta_old)" (paper Fig. 1).  Grid is over output
channels so each block sees whole columns (the scale reduction axis).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INT8_QMAX, E4M3_MAX, SCALE_EPS
from .fp8 import _quant_e4m3


def _wq_int8_kernel(w_ref, q_ref, s_ref):
    w = w_ref[...]
    absmax = jnp.max(jnp.abs(w), axis=0)
    s = jnp.maximum(absmax, SCALE_EPS) / INT8_QMAX
    q_ref[...] = jnp.clip(jnp.round(w / s[None, :]), -INT8_QMAX, INT8_QMAX
                          ).astype(jnp.int8)
    s_ref[...] = s


def weight_quant_int8_pallas(w, *, block_n=128):
    """w [K, N] f32 -> (q [K, N] i8, scale [N] f32), per-output-channel."""
    k, n = w.shape
    bn = min(block_n, n)
    assert n % bn == 0
    return pl.pallas_call(
        _wq_int8_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w)


def _wq_fp8_kernel(w_ref, o_ref):
    w = w_ref[...]
    absmax = jnp.max(jnp.abs(w), axis=0)
    s = jnp.maximum(absmax, SCALE_EPS) / E4M3_MAX
    o_ref[...] = _quant_e4m3(w / s[None, :]) * s[None, :]


def weight_quant_fp8_pallas(w, *, block_n=128):
    """w [K, N] f32 -> fake-quantized f32 [K, N], per-output-channel e4m3."""
    k, n = w.shape
    bn = min(block_n, n)
    assert n % bn == 0
    return pl.pallas_call(
        _wq_fp8_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((k, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(w)
