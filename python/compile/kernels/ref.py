"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact (or bit-exact) counterpart here;
pytest + hypothesis assert the Pallas implementations match.  The Rust
``quant`` module mirrors the same arithmetic (cross-checked in cargo tests via
the quantize_* artifacts), so these functions are the single source of truth
for QuRL's quantization semantics:

* INT8: symmetric, per-output-channel weight scales (absmax/127), token-wise
  activation scales (absmax/127), round-to-nearest-even, i32 accumulation.
* FP8:  OCP e4m3fn "fake quantization" — round-to-nearest-even onto the e4m3
  grid with saturation to +-448, subnormals down to 2^-9, applied to both
  weights (per-channel scaled) and activations (token-wise scaled).
"""

import jax.numpy as jnp

INT8_QMAX = 127.0
E4M3_MAX = 448.0
E4M3_MIN_EXP = -6.0   # smallest normal exponent
E4M3_MAX_EXP = 8.0    # largest normal exponent (448 = 2^8 * 1.75)
SCALE_EPS = 1e-8      # floor on absmax so all-zero rows stay well-defined


# --------------------------------------------------------------------------
# INT8
# --------------------------------------------------------------------------

def act_quant_int8(x):
    """Token-wise symmetric INT8 quantization of activations.

    x: [M, K] f32  ->  (q: [M, K] i8, scale: [M] f32)  with
    scale = max(|x_row|, eps)/127,  q = clip(rne(x/scale), -127, 127).
    """
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, SCALE_EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(x / scale[..., None]), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scale


def weight_quant_int8(w):
    """Per-output-channel symmetric INT8 quantization.

    w: [K, N] f32  ->  (q: [K, N] i8, scale: [N] f32).
    """
    absmax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(absmax, SCALE_EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(w / scale[None, :]), -INT8_QMAX, INT8_QMAX)
    return q.astype(jnp.int8), scale


def int8_matmul(x, wq, wscale):
    """W8A8 GEMM: quantize activations token-wise, multiply in integers
    (i32 accumulation), dequantize with a_scale[m] * w_scale[n].

    x: [M, K] f32, wq: [K, N] i8, wscale: [N] f32 -> [M, N] f32.
    """
    xq, ascale = act_quant_int8(x)
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32))
    return acc.astype(jnp.float32) * ascale[:, None] * wscale[None, :]


def dequant_int8(wq, wscale):
    """Inverse of weight_quant_int8 up to rounding: [K,N] i8 -> f32."""
    return wq.astype(jnp.float32) * wscale[None, :]


# --------------------------------------------------------------------------
# FP8 (e4m3fn)
# --------------------------------------------------------------------------

def quant_e4m3(x):
    """Round-to-nearest-even onto the e4m3fn grid with saturation.

    Exact emulation: the quantum at exponent e is 2^(e-3) (3 mantissa bits);
    exponents below -6 share the subnormal quantum 2^-9; values above 448
    saturate (e4m3fn has no inf).
    """
    a = jnp.abs(x)
    # floor(log2 a), guarded for zeros; clamp to the normal exponent range.
    e = jnp.floor(jnp.log2(jnp.maximum(a, jnp.float32(2.0 ** -40))))
    e = jnp.clip(e, E4M3_MIN_EXP, E4M3_MAX_EXP)
    step = jnp.exp2(e - 3.0)
    q = jnp.round(x / step) * step  # jnp.round = RNE
    return jnp.clip(q, -E4M3_MAX, E4M3_MAX)


def weight_quant_fp8(w):
    """Per-output-channel scaled e4m3 fake quantization.

    Returns the *fake-quantized* f32 weights (scale folded back in), which is
    what the fp8 decode/logprob artifacts consume — numerically identical to
    storing e4m3 + scale, without needing an FP8 dtype on this testbed.
    """
    absmax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(absmax, SCALE_EPS) / E4M3_MAX
    return quant_e4m3(w / scale[None, :]) * scale[None, :]


def act_quant_fp8(x):
    """Token-wise scaled e4m3 fake quantization of activations."""
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, SCALE_EPS) / E4M3_MAX
    return quant_e4m3(x / scale[..., None]) * scale[..., None]


def fp8_matmul(x, w_fq):
    """FP8 GEMM with fake-quantized weights: fq(x) @ w_fq in f32."""
    return jnp.matmul(act_quant_fp8(x), w_fq)
