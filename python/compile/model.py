"""L2: the QuRL actor — a transformer LM in JAX, plus the paper's RL losses.

Everything here is *build-time only*: `aot.py` lowers the jitted entry points
to HLO text which the Rust coordinator executes via PJRT.  The module covers:

* the actor network (RMSNorm, MHA with learned positions, GELU MLP), with
  three weight modes — ``bf16`` (full precision), ``int8`` (W8A8 via the
  Pallas kernel), ``fp8`` (e4m3 fake-quantized weights + fused activation
  fake-quant kernel);
* batched generation (prefill + lax.scan decode + sampling + EOS masking) —
  the paper's *rollout*, all inside one HLO module so the request path has
  no per-token host/device round-trips;
* teacher-forced log-probabilities / values / entropies;
* the QuRL training objective (Eq. 1/3/4/5/9 selected by a runtime flag:
  on-policy, naive quantized IS, decoupled PPO, TIS, ACR), k3 KL
  regularization, PPO value loss, AdamW;
* Update-Aware Quantization's invariant scaling (Eq. 11-12);
* parameter init / flatten / unflatten against the manifest layout.

Conventions: tokens are left-aligned with PAD=0; position t's logits predict
token t+1; ``lp[b, t]`` is the logprob of token t given its prefix (lp[:,0]
is 0).  A generation mask marks sampled tokens (EOS inclusive).
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, FLAGS
from .kernels import int8 as k_int8
from .kernels import fp8 as k_fp8
from .kernels import quantize as k_quant

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

_NEG_INF = -1e9
_RMS_EPS = 1e-6


class Weights(NamedTuple):
    """Actor weights in one of three modes.

    mode "bf16": mats = full-precision section-B matrices.
    mode "fp8":  mats = fake-quantized section-B matrices (same graph shape).
    mode "int8": qw/qs = int8 matrices + per-output-channel scales.
    ``aux`` always holds section A (embed, pos, norms, head, value head).
    """

    mode: str
    aux: dict
    mats: dict
    qw: dict
    qs: dict


# --------------------------------------------------------------------------
# parameter plumbing
# --------------------------------------------------------------------------

def unflatten(cfg: ModelConfig, flat):
    out = {}
    for name, (off, shape) in cfg.offsets().items():
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
    return out


def flatten(cfg: ModelConfig, params: dict):
    parts = [params[name].reshape(-1) for name, _ in cfg.layout()]
    return jnp.concatenate(parts)


def unflatten_b(cfg: ModelConfig, flat_b):
    """Section-B-only flat vector -> dict of matrices."""
    out = {}
    a = cfg.a_size
    for name, shape in cfg.section_b():
        off = cfg.offsets()[name][0] - a
        n = shape[0] * shape[1]
        out[name] = jax.lax.dynamic_slice(flat_b, (off,), (n,)).reshape(shape)
    return out


def unflatten_scales(cfg: ModelConfig, flat_s):
    out = {}
    for name, (off, ch) in cfg.scale_offsets().items():
        out[name] = jax.lax.dynamic_slice(flat_s, (off,), (ch,))
    return out


def weights_bf16(cfg: ModelConfig, flat):
    p = unflatten(cfg, flat)
    aux = {n: p[n] for n, _ in cfg.section_a()}
    mats = {n: p[n] for n, _ in cfg.section_b()}
    return Weights("bf16", aux, mats, {}, {})


def weights_fp8(cfg: ModelConfig, flat_a, flat_b_fq):
    aux_all = unflatten(cfg, jnp.concatenate([flat_a, flat_b_fq]))
    aux = {n: aux_all[n] for n, _ in cfg.section_a()}
    mats = {n: aux_all[n] for n, _ in cfg.section_b()}
    return Weights("fp8", aux, mats, {}, {})


def weights_int8(cfg: ModelConfig, flat_a, flat_qw, flat_qs):
    a_named = {}
    off = 0
    for name, shape in cfg.section_a():
        n = 1
        for s in shape:
            n *= s
        a_named[name] = jax.lax.dynamic_slice(flat_a, (off,), (n,)).reshape(shape)
        off += n
    qw = unflatten_b(cfg, flat_qw)
    qs = unflatten_scales(cfg, flat_qs)
    return Weights("int8", a_named, {}, qw, qs)


def init_params(cfg: ModelConfig, seed):
    """Deterministic GPT-style init from an i32 seed (exported artifact)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + 6 * cfg.n_layers)
    p = {}
    p["embed"] = 0.02 * jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
    p["pos"] = 0.01 * jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model))
    p["head"] = 0.02 * jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size))
    p["v_head"] = jnp.zeros((cfg.d_model,))
    p["v_bias"] = jnp.zeros((1,))
    p["ln_f"] = jnp.ones((cfg.d_model,))
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for l in range(cfg.n_layers):
        k = ks[4 + 6 * l:4 + 6 * (l + 1)]
        p[f"layer{l}.ln1"] = jnp.ones((cfg.d_model,))
        p[f"layer{l}.ln2"] = jnp.ones((cfg.d_model,))
        p[f"layer{l}.qkv"] = 0.02 * jax.random.normal(
            k[0], (cfg.d_model, 3 * cfg.d_model))
        p[f"layer{l}.attn_out"] = 0.02 * resid_scale * jax.random.normal(
            k[1], (cfg.d_model, cfg.d_model))
        p[f"layer{l}.mlp_up"] = 0.02 * jax.random.normal(
            k[2], (cfg.d_model, cfg.d_ff))
        p[f"layer{l}.mlp_down"] = 0.02 * resid_scale * jax.random.normal(
            k[3], (cfg.d_ff, cfg.d_model))
    return flatten(cfg, {n: p[n].astype(jnp.float32) for n, _ in cfg.layout()})


# --------------------------------------------------------------------------
# network pieces
# --------------------------------------------------------------------------

def rmsnorm(x, g):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + _RMS_EPS) * g


def _linear(cfg: ModelConfig, w: Weights, name: str, x2d):
    """Quantization-mode-dispatched linear over [M, K] activations."""
    if w.mode == "bf16":
        return jnp.matmul(x2d, w.mats[name])
    m = x2d.shape[0]
    bm = m if m <= 512 else 512
    if w.mode == "fp8":
        return k_fp8.fp8_matmul(x2d, w.mats[name], block_m=bm,
                                block_n=cfg.block_n)
    if w.mode == "int8":
        return k_int8.int8_matmul(
            x2d, w.qw[name], w.qs[name], profile=cfg.kernel_profile,
            block_m=bm, block_n=cfg.block_n, block_k=cfg.block_k)
    raise ValueError(w.mode)


def embed_tokens(cfg: ModelConfig, w: Weights, tokens):
    """One-hot matmul embedding (avoids HLO gather for the 0.5.1 parser)."""
    oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=jnp.float32)
    return oh @ w.aux["embed"]


def forward_full(cfg: ModelConfig, w: Weights, tokens):
    """Teacher-forced forward over [B, T] tokens -> hidden states [B, T, d].

    Causal attention; PAD positions flow through but are masked out by the
    caller (their keys are attended only by other PAD queries to the right,
    whose outputs are discarded -- PAD only ever appears as a suffix).
    """
    b, t = tokens.shape
    x = embed_tokens(cfg, w, tokens) + w.aux["pos"][None, :t, :]
    causal = jnp.tril(jnp.ones((t, t), dtype=jnp.float32))
    neg = (1.0 - causal) * _NEG_INF
    scale = 1.0 / jnp.sqrt(float(cfg.head_dim))
    for l in range(cfg.n_layers):
        h = rmsnorm(x, w.aux[f"layer{l}.ln1"])
        qkv = _linear(cfg, w, f"layer{l}.qkv", h.reshape(b * t, cfg.d_model))
        qkv = qkv.reshape(b, t, 3, cfg.n_heads, cfg.head_dim)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # [B, H, T, T]
        scores = jnp.einsum("bihd,bjhd->bhij", q, k) * scale + neg[None, None]
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhij,bjhd->bihd", probs, v).reshape(b * t, cfg.d_model)
        x = x + _linear(cfg, w, f"layer{l}.attn_out", ctx).reshape(b, t, -1)
        h = rmsnorm(x, w.aux[f"layer{l}.ln2"])
        u = _linear(cfg, w, f"layer{l}.mlp_up", h.reshape(b * t, cfg.d_model))
        u = jax.nn.gelu(u, approximate=True)
        x = x + _linear(cfg, w, f"layer{l}.mlp_down", u).reshape(b, t, -1)
    return rmsnorm(x, w.aux["ln_f"])


def logits_from_hidden(w: Weights, h):
    return h @ w.aux["head"]


def values_from_hidden(w: Weights, h):
    return jnp.squeeze(h @ w.aux["v_head"][:, None], -1) + w.aux["v_bias"][0]


def sequence_scores(cfg: ModelConfig, w: Weights, tokens):
    """Per-token logprob / value / entropy aligned to token index.

    lp[b, t]   = log pi(tokens[b, t] | tokens[b, :t])      (lp[:, 0] = 0)
    value[b,t] = V(prefix before sampling token t)          (value[:,0] = 0)
    ent[b, t]  = entropy of that sampling distribution.
    """
    b, t = tokens.shape
    h = forward_full(cfg, w, tokens)
    logits = logits_from_hidden(w, h)                      # [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    oh_next = jax.nn.one_hot(tokens[:, 1:], cfg.vocab_size, dtype=jnp.float32)
    lp_next = jnp.sum(logp[:, :-1, :] * oh_next, axis=-1)  # [B, T-1]
    zeros = jnp.zeros((b, 1), dtype=jnp.float32)
    lp = jnp.concatenate([zeros, lp_next], axis=1)
    ent_t = -jnp.sum(jnp.exp(logp) * logp, axis=-1)        # [B, T]
    ent = jnp.concatenate([zeros, ent_t[:, :-1]], axis=1)
    val_t = values_from_hidden(w, h)                       # [B, T]
    value = jnp.concatenate([zeros, val_t[:, :-1]], axis=1)
    return lp, value, ent


# --------------------------------------------------------------------------
# prefill / decode (KV cache) — the serving path
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, w: Weights, tokens, lens):
    """Fill the KV cache for prompt tokens and return last-position logits.

    tokens: [B, P] i32 (left-aligned, PAD right), lens: [B] i32.
    Returns (cache_k, cache_v, logits_last) with caches [L, B, H, S, Dh];
    cache slots >= len stay zero (decode overwrites them in order, so
    garbage is never attended — see coordinator/kv.rs invariant test).
    """
    b, p = tokens.shape
    s = cfg.max_seq
    x = embed_tokens(cfg, w, tokens) + w.aux["pos"][None, :p, :]
    causal = jnp.tril(jnp.ones((p, p), dtype=jnp.float32))
    neg = (1.0 - causal) * _NEG_INF
    scale = 1.0 / jnp.sqrt(float(cfg.head_dim))
    # [B, P] validity of each prompt position
    valid = (jnp.arange(p)[None, :] < lens[:, None]).astype(jnp.float32)
    cks, cvs = [], []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, w.aux[f"layer{l}.ln1"])
        qkv = _linear(cfg, w, f"layer{l}.qkv", h.reshape(b * p, cfg.d_model))
        qkv = qkv.reshape(b, p, 3, cfg.n_heads, cfg.head_dim)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # cache layout [B, H, S, Dh]; positions >= len masked to zero
        k_m = k * valid[:, :, None, None]
        v_m = v * valid[:, :, None, None]
        pad = jnp.zeros((b, s - p, cfg.n_heads, cfg.head_dim), jnp.float32)
        cks.append(jnp.transpose(jnp.concatenate([k_m, pad], 1), (0, 2, 1, 3)))
        cvs.append(jnp.transpose(jnp.concatenate([v_m, pad], 1), (0, 2, 1, 3)))
        scores = jnp.einsum("bihd,bjhd->bhij", q, k) * scale + neg[None, None]
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhij,bjhd->bihd", probs, v).reshape(b * p, cfg.d_model)
        x = x + _linear(cfg, w, f"layer{l}.attn_out", ctx).reshape(b, p, -1)
        h = rmsnorm(x, w.aux[f"layer{l}.ln2"])
        u = _linear(cfg, w, f"layer{l}.mlp_up", h.reshape(b * p, cfg.d_model))
        u = jax.nn.gelu(u, approximate=True)
        x = x + _linear(cfg, w, f"layer{l}.mlp_down", u).reshape(b, p, -1)
    hf = rmsnorm(x, w.aux["ln_f"])
    # gather h at position len-1 via one-hot over P
    oh_last = jax.nn.one_hot(lens - 1, p, dtype=jnp.float32)       # [B, P]
    h_last = jnp.einsum("bp,bpd->bd", oh_last, hf)
    logits_last = logits_from_hidden(w, h_last)
    cache_k = jnp.stack(cks)   # [L, B, H, S, Dh]
    cache_v = jnp.stack(cvs)
    return cache_k, cache_v, logits_last


def decode_step(cfg: ModelConfig, w: Weights, cache_k, cache_v, pos, tok):
    """One decode step: token `tok` sits at index `pos` (per row).

    Writes its K/V at `pos`, attends indices <= pos, returns logits
    predicting the token at pos+1 plus the updated caches.
    """
    b = tok.shape[0]
    s = cfg.max_seq
    oh_pos = jax.nn.one_hot(pos, s, dtype=jnp.float32)             # [B, S]
    x = embed_tokens(cfg, w, tok) + oh_pos @ w.aux["pos"]          # [B, d]
    attend = (jnp.arange(s)[None, :] <= pos[:, None]).astype(jnp.float32)
    neg = (1.0 - attend) * _NEG_INF                                # [B, S]
    scale = 1.0 / jnp.sqrt(float(cfg.head_dim))
    new_k, new_v = [], []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, w.aux[f"layer{l}.ln1"])
        qkv = _linear(cfg, w, f"layer{l}.qkv", h)
        qkv = qkv.reshape(b, 3, cfg.n_heads, cfg.head_dim)
        q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]                  # [B, H, Dh]
        sel = oh_pos[:, None, :, None]                             # [B,1,S,1]
        ck = cache_k[l] * (1.0 - sel) + k[:, :, None, :] * sel
        cv = cache_v[l] * (1.0 - sel) + v[:, :, None, :] * sel
        new_k.append(ck)
        new_v.append(cv)
        scores = jnp.einsum("bhd,bhsd->bhs", q, ck) * scale + neg[:, None, :]
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bhsd->bhd", probs, cv).reshape(b, cfg.d_model)
        x = x + _linear(cfg, w, f"layer{l}.attn_out", ctx)
        h = rmsnorm(x, w.aux[f"layer{l}.ln2"])
        u = jax.nn.gelu(_linear(cfg, w, f"layer{l}.mlp_up", h),
                        approximate=True)
        x = x + _linear(cfg, w, f"layer{l}.mlp_down", u)
    hf = rmsnorm(x, w.aux["ln_f"])
    logits = logits_from_hidden(w, hf)
    return jnp.stack(new_k), jnp.stack(new_v), logits


# --------------------------------------------------------------------------
# sampling + generation (the rollout artifact)
# --------------------------------------------------------------------------

def sample_token(logits, key, temp, top_p):
    """Temperature + nucleus sampling with exact behavior logprobs.

    Returns (token [B] i32, lp [B] f32) where lp is the log-probability of
    the sampled token under the *actual* sampling distribution (post
    temperature + top-p renormalization) — this is pi_behav.
    temp < 1e-7 selects greedy decoding (lp from the untempered dist).
    """
    b, v = logits.shape
    t_safe = jnp.maximum(temp, 1e-6)
    lt = logits / t_safe
    logp = jax.nn.log_softmax(lt, axis=-1)
    p = jnp.exp(logp)
    # nucleus: keep the smallest prefix of the probability-sorted
    # distribution with cumulative mass >= top_p.  Boundary ties break by
    # sort order (equal probabilities keep ascending token id) — mirrored
    # exactly by the host-side scheduler sampler (coordinator/sampler.rs);
    # a `p >= threshold` filter would keep every boundary-tied token and
    # inflate the nucleus past the minimal set.
    # Sort/gather-free formulation for the 0.5.1 parser (V is tiny): token
    # i is kept iff the mass of tokens strictly preceding it in the
    # descending (p, -index) order is < top_p.
    idx = jnp.arange(v)
    pi = p[:, :, None]                                     # [B, V(i), 1]
    pj = p[:, None, :]                                     # [B, 1, V(j)]
    precedes = (pj > pi) | (
        (pj == pi) & (idx[None, None, :] < idx[None, :, None]))
    mass_before = jnp.sum(jnp.where(precedes, pj, 0.0), axis=-1)  # [B, V]
    # the first-ranked token (mass_before == 0) is always kept so the
    # nucleus is never empty even at top_p <= 0 (no NaN logprobs) —
    # matching the host sampler's never-empty prefix
    keep = (mass_before < top_p) | (mass_before == 0.0)
    filt_logp = jnp.where(keep, logp, _NEG_INF)
    filt_logp = jax.nn.log_softmax(filt_logp, axis=-1)     # renormalized
    g = jax.random.gumbel(key, (b, v), dtype=jnp.float32)
    sampled = jnp.argmax(filt_logp + g, axis=-1).astype(jnp.int32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    use_greedy = temp < 1e-7
    tok = jnp.where(use_greedy, greedy, sampled)
    oh = jax.nn.one_hot(tok, v, dtype=jnp.float32)
    lp_sampled = jnp.sum(filt_logp * oh, axis=-1)
    lp_greedy = jnp.sum(jax.nn.log_softmax(logits, axis=-1) * oh, axis=-1)
    lp = jnp.where(use_greedy, lp_greedy, lp_sampled)
    return tok, lp


def generate(cfg: ModelConfig, w: Weights, tokens, lens, seed, temp, top_p,
             max_new: int):
    """Batched rollout: prefill + `max_new` scanned decode steps.

    tokens: [B, S] i32, prompt left-aligned (only [:, :max_prompt] read);
    returns (tokens' [B, S], lp [B, S], genmask [B, S]) where genmask marks
    sampled tokens (EOS inclusive) and lp holds behavior logprobs there.
    """
    b, s = tokens.shape
    p = cfg.max_prompt
    cache_k, cache_v, logits0 = prefill(cfg, w, tokens[:, :p], lens)
    key0 = jax.random.PRNGKey(seed)

    oh_last = jax.nn.one_hot(lens - 1, s, dtype=jnp.float32)
    last_tok = jnp.sum(oh_last * tokens.astype(jnp.float32), -1).astype(jnp.int32)

    def write_at(arr, idx, val, gate):
        """arr [B, S]: write val [B] at per-row idx [B] where gate [B] is 1."""
        oh = jax.nn.one_hot(idx, s, dtype=jnp.float32) * gate[:, None]
        return arr * (1.0 - oh) + val[:, None].astype(jnp.float32) * oh

    def step(carry, i):
        ck, cv, toks, lp, mask, cur_tok, cur_pos, done, logits = carry
        key = jax.random.fold_in(key0, i)
        t_new, lp_new = sample_token(logits, key, temp, top_p)
        idx = jnp.minimum(cur_pos + 1, s - 1)
        alive = 1.0 - done
        tok_write = jnp.where(done > 0.5, PAD_ID, t_new)
        toks = write_at(toks, idx, tok_write.astype(jnp.float32), alive)
        lp = write_at(lp, idx, lp_new, alive)
        mask = write_at(mask, idx, alive, alive)
        done = jnp.maximum(done, (t_new == EOS_ID).astype(jnp.float32))
        # also stop rows that hit the context limit
        done = jnp.maximum(done, (idx >= s - 1).astype(jnp.float32))
        ck, cv, logits = decode_step(
            cfg, w, ck, cv, idx, tok_write.astype(jnp.int32))
        return (ck, cv, toks, lp, mask, tok_write.astype(jnp.int32), idx,
                done, logits), ()

    toks_f = tokens.astype(jnp.float32)
    lp0 = jnp.zeros((b, s), jnp.float32)
    mask0 = jnp.zeros((b, s), jnp.float32)
    done0 = jnp.zeros((b,), jnp.float32)
    carry = (cache_k, cache_v, toks_f, lp0, mask0, last_tok, lens - 1,
             done0, logits0)
    carry, _ = jax.lax.scan(step, carry, jnp.arange(max_new))
    _, _, toks, lp, mask, _, _, _, _ = carry
    return toks.astype(jnp.int32), lp, mask


# --------------------------------------------------------------------------
# RL objective (Eq. 1 / 3 / 4 / 5 / 9) + value/KL/entropy terms
# --------------------------------------------------------------------------

def rl_loss(cfg: ModelConfig, flat_params, tokens, mask, adv,
            lp_behav, lp_prox, lp_ref, returns, old_values, flags):
    """QuRL surrogate loss; objective variant chosen by flags[OBJ_MODE].

    0 on-policy GRPO/PPO clip (Eq. 1)        ratio vs prox, no IS factor
    1 naive quantized IS (Eq. 3)             ratio vs *behavior* policy
    2 decoupled PPO (Eq. 4)                  x (prox/behav), uncapped
    3 TIS / FlashRL (Eq. 5)                  x min(prox/behav, C)
    4 ACR / QuRL (Eq. 9)                     TIS + upper bound (1+eps)/r
    Returns (loss, metrics[16]).
    """
    w = weights_bf16(cfg, flat_params)
    lp_theta, value, entropy = sequence_scores(cfg, w, tokens)

    mode = flags[FLAGS.OBJ_MODE]
    eps_lo = flags[FLAGS.EPS_LOW]
    eps_hi = flags[FLAGS.EPS_HIGH]
    cap = flags[FLAGS.TIS_CAP]

    d_prox = jnp.clip(lp_theta - lp_prox, -20.0, 20.0)
    d_behav = jnp.clip(lp_theta - lp_behav, -20.0, 20.0)
    d_pb = jnp.clip(lp_prox - lp_behav, -20.0, 20.0)
    ratio_prox = jnp.exp(d_prox)
    ratio_behav = jnp.exp(d_behav)
    rho = jnp.exp(d_pb)                       # prox-to-behavior ratio
    tis_w = jnp.minimum(rho, cap)
    r = tis_w / rho                           # in (0, 1]; <1 iff truncated

    is_naive = (mode == 1.0)
    ratio = jnp.where(is_naive, ratio_behav, ratio_prox)
    factor = jnp.where(mode == 2.0, rho,
                       jnp.where(mode == 3.0, tis_w,
                                 jnp.where(mode == 4.0, tis_w, 1.0)))
    hi = jnp.where(mode == 4.0, (1.0 + eps_hi) / r, 1.0 + eps_hi)
    lo = 1.0 - eps_lo

    unclipped = ratio * adv
    clipped = jnp.clip(ratio, lo, hi) * adv
    surr = factor * jnp.minimum(unclipped, clipped)
    was_clipped = (unclipped > clipped + 1e-12).astype(jnp.float32)

    # k3 KL to the reference policy (Schulman 2020)
    d_ref = jnp.clip(lp_ref - lp_theta, -20.0, 20.0)
    kl3 = jnp.exp(d_ref) - d_ref - 1.0

    tok_loss = (-surr
                + flags[FLAGS.KL_COEF] * kl3
                - flags[FLAGS.ENT_COEF] * entropy)

    msum = jnp.maximum(jnp.sum(mask), 1.0)
    seq_msum = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    grpo_agg = jnp.mean(jnp.sum(tok_loss * mask, axis=1) / seq_msum)
    dapo_agg = jnp.sum(tok_loss * mask) / msum
    pg_loss = jnp.where(flags[FLAGS.TOKEN_MEAN] > 0.5, dapo_agg, grpo_agg)

    # PPO clipped value loss
    vclip = flags[FLAGS.VALUE_CLIP]
    v_clipped = old_values + jnp.clip(value - old_values, -vclip, vclip)
    v_err = jnp.maximum(jnp.square(value - returns),
                        jnp.square(v_clipped - returns))
    v_loss = 0.5 * jnp.sum(v_err * mask) / msum

    loss = pg_loss + flags[FLAGS.VF_COEF] * v_loss

    def mmean(x):
        return jnp.sum(x * mask) / msum

    def mmax(x):
        return jnp.max(x * mask)

    metrics = jnp.stack([
        loss,
        pg_loss,
        mmean(kl3),                               # 2: KL(theta||ref) est.
        mmean(entropy),                           # 3
        v_loss,                                   # 4
        mmean(was_clipped),                       # 5: token clipped fraction
        mmean(ratio),                             # 6
        mmax(ratio),                              # 7
        mmax(rho),                                # 8: max prox/behav (Fig 3b)
        0.0,                                      # 9: grad_norm (filled later)
        mmean((rho > cap).astype(jnp.float32)),   # 10: truncated fraction
        mmean(jnp.abs(jnp.exp(lp_prox) - jnp.exp(lp_behav))),  # 11: Fig 4b
        mmean(lp_behav - lp_prox),                # 12: KL(behav||prox), Fig 3a
        mmean(hi * jnp.ones_like(ratio)),         # 13: mean upper clip bound
        0.0,                                      # 14: update_norm (later)
        mmean(lp_theta),                          # 15
    ])
    return loss, metrics


def sft_loss(cfg: ModelConfig, flat_params, tokens, mask):
    """Masked next-token cross-entropy (builds the RL base model)."""
    w = weights_bf16(cfg, flat_params)
    lp, _, _ = sequence_scores(cfg, w, tokens)
    msum = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(lp * mask) / msum
    acc_tok = jnp.sum(jnp.exp(lp) * mask) / msum   # mean token prob (proxy)
    return loss, jnp.stack([loss, acc_tok])


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw_update(flat_params, grads, m, v, step, flags):
    """AdamW with optional global-norm clipping; step is f32 (1-based)."""
    lr = flags[FLAGS.LR]
    b1 = flags[FLAGS.BETA1]
    b2 = flags[FLAGS.BETA2]
    eps = flags[FLAGS.ADAM_EPS]
    wd = flags[FLAGS.WEIGHT_DECAY]
    max_norm = flags[FLAGS.MAX_GRAD_NORM]

    gnorm = jnp.sqrt(jnp.sum(jnp.square(grads)) + 1e-12)
    scale = jnp.where((max_norm > 0.0) & (gnorm > max_norm),
                      max_norm / gnorm, 1.0)
    g = grads * scale

    m1 = b1 * m + (1.0 - b1) * g
    v1 = b2 * v + (1.0 - b2) * jnp.square(g)
    bc1 = 1.0 - jnp.exp(step * jnp.log(b1))
    bc2 = 1.0 - jnp.exp(step * jnp.log(b2))
    mh = m1 / bc1
    vh = v1 / bc2
    upd = lr * (mh / (jnp.sqrt(vh) + eps) + wd * flat_params)
    new_params = flat_params - upd
    unorm = jnp.sqrt(jnp.sum(jnp.square(upd)) + 1e-12)
    return new_params, m1, v1, gnorm, unorm


def train_step(cfg: ModelConfig, flat_params, m, v, step, tokens, mask, adv,
               lp_behav, lp_prox, lp_ref, returns, old_values, flags):
    """One RL optimization step (the train_step artifact)."""
    grad_fn = jax.grad(lambda p: rl_loss(cfg, p, tokens, mask, adv, lp_behav,
                                         lp_prox, lp_ref, returns, old_values,
                                         flags), has_aux=True)
    grads, metrics = grad_fn(flat_params)
    new_params, m1, v1, gnorm, unorm = adamw_update(
        flat_params, grads, m, v, step, flags)
    metrics = metrics.at[9].set(gnorm).at[14].set(unorm)
    return new_params, m1, v1, metrics


def sft_step(cfg: ModelConfig, flat_params, m, v, step, tokens, mask, flags):
    grad_fn = jax.grad(lambda p: sft_loss(cfg, p, tokens, mask), has_aux=True)
    grads, metrics = grad_fn(flat_params)
    new_params, m1, v1, gnorm, _ = adamw_update(
        flat_params, grads, m, v, step, flags)
    return new_params, m1, v1, jnp.concatenate([metrics, gnorm[None]])


# --------------------------------------------------------------------------
# quantization entry points (ride the Pallas quantizers)
# --------------------------------------------------------------------------

def quantize_section_b_int8(cfg: ModelConfig, flat_b):
    """Section-B flat f32 -> (flat i8 qweights, flat f32 per-channel scales)."""
    mats = unflatten_b(cfg, flat_b)
    qws, qss = [], []
    for name, _ in cfg.section_b():
        qw, qs = k_quant.weight_quant_int8_pallas(mats[name],
                                                  block_n=cfg.block_n)
        qws.append(qw.reshape(-1))
        qss.append(qs)
    return jnp.concatenate(qws), jnp.concatenate(qss)


def quantize_section_b_fp8(cfg: ModelConfig, flat_b):
    """Section-B flat f32 -> fake-quantized flat f32 (per-channel e4m3)."""
    mats = unflatten_b(cfg, flat_b)
    out = []
    for name, _ in cfg.section_b():
        out.append(k_quant.weight_quant_fp8_pallas(
            mats[name], block_n=cfg.block_n).reshape(-1))
    return jnp.concatenate(out)


def uaq_scale(cfg: ModelConfig, flat_params, s):
    """Update-Aware Quantization invariant scaling (Eq. 11).

    For every LN-preceded quantized linear (qkv, mlp_up): W <- W/s and the
    preceding RMSNorm gain <- gain*s.  Network function is exactly preserved;
    weight quantization error shrinks by s while effective weight updates
    grow by s (the s^2 effect of Eq. 12).
    """
    p = unflatten(cfg, flat_params)
    for l in range(cfg.n_layers):
        p[f"layer{l}.ln1"] = p[f"layer{l}.ln1"] * s
        p[f"layer{l}.qkv"] = p[f"layer{l}.qkv"] / s
        p[f"layer{l}.ln2"] = p[f"layer{l}.ln2"] * s
        p[f"layer{l}.mlp_up"] = p[f"layer{l}.mlp_up"] / s
    return flatten(cfg, p)
