"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

The integer path must be bit-exact; dequantization scaling is allowed one
ulp of f32 reassociation.  Hypothesis sweeps shapes, scales and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fp8 as k_fp8
from compile.kernels import int8 as k_int8
from compile.kernels import quantize as k_quant
from compile.kernels import ref


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# INT8
# --------------------------------------------------------------------------

@pytest.mark.parametrize("profile", ["fused", "tiled"])
@pytest.mark.parametrize("m,k,n", [(64, 128, 128), (64, 128, 384),
                                   (128, 512, 128), (512, 128, 512)])
def test_int8_matmul_matches_ref(profile, m, k, n):
    x = rand((m, k), seed=m + k)
    w = rand((k, n), seed=n)
    wq, ws = ref.weight_quant_int8(w)
    got = k_int8.int8_matmul(x, wq, ws, profile=profile)
    want = ref.int8_matmul(x, wq, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_int8_integer_accumulation_exact():
    """The raw integer products must agree exactly with i32 math."""
    x = rand((64, 512), seed=1, scale=3.0)
    w = rand((512, 128), seed=2, scale=3.0)
    wq, ws = ref.weight_quant_int8(w)
    xq, ascale = ref.act_quant_int8(x)
    acc_i32 = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32))
    got = k_int8.int8_matmul(x, wq, ws, profile="fused")
    want = acc_i32.astype(jnp.float32) * ascale[:, None] * ws[None, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_weight_quant_pallas_bitexact():
    w = rand((128, 384), seed=3, scale=0.05)
    q_ref, s_ref = ref.weight_quant_int8(w)
    q, s = k_quant.weight_quant_int8_pallas(w)
    assert bool(jnp.all(q == q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)


def test_act_quant_pallas_bitexact():
    x = rand((128, 512), seed=4)
    q_ref, s_ref = ref.act_quant_int8(x)
    q, s = k_int8.act_quant_int8_pallas(x)
    assert bool(jnp.all(q == q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    m_blocks=st.integers(1, 4),
    k=st.sampled_from([64, 128, 256, 512]),
    n_blocks=st.integers(1, 3),
    scale=st.sampled_from([1e-3, 0.1, 1.0, 30.0]),
    seed=st.integers(0, 2**16),
)
def test_int8_matmul_hypothesis(m_blocks, k, n_blocks, scale, seed):
    m, n = 64 * m_blocks, 128 * n_blocks
    x = rand((m, k), seed=seed, scale=scale)
    w = rand((k, n), seed=seed + 1, scale=scale)
    wq, ws = ref.weight_quant_int8(w)
    got = k_int8.int8_matmul(x, wq, ws, profile="fused")
    want = ref.int8_matmul(x, wq, ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5 * scale * scale * k)


def test_quant_error_bounded_by_half_step():
    w = rand((256, 128), seed=5, scale=0.02)
    wq, ws = ref.weight_quant_int8(w)
    deq = ref.dequant_int8(wq, ws)
    err = jnp.abs(deq - w)
    bound = 0.5 * ws[None, :] + 1e-9
    assert bool(jnp.all(err <= bound))


def test_zero_rows_are_safe():
    x = jnp.zeros((64, 128), jnp.float32)
    w = rand((128, 128), seed=6)
    wq, ws = ref.weight_quant_int8(w)
    out = k_int8.int8_matmul(x, wq, ws, profile="fused")
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


# --------------------------------------------------------------------------
# FP8
# --------------------------------------------------------------------------

def test_e4m3_grid_values():
    # representable values are fixed points
    vals = jnp.asarray([1.0, 1.125, 0.875, 448.0, -448.0, 2.0 ** -9,
                        2.0 ** -6, 240.0, 0.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref.quant_e4m3(vals)),
                                  np.asarray(vals))


def test_e4m3_saturation():
    vals = jnp.asarray([1e6, -1e6, 460.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref.quant_e4m3(vals)),
                                  [448.0, -448.0, 448.0])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16),
       scale=st.sampled_from([1e-4, 0.02, 1.0, 50.0]))
def test_e4m3_relative_error(seed, scale):
    x = rand((1024,), seed=seed, scale=scale)
    q = ref.quant_e4m3(x)
    # normal range: rel err <= 2^-4; subnormal range (|x| < 2^-6): abs err
    # <= 2^-10, i.e. <= 2^-4 relative to the smallest normal 2^-6.
    rel = np.abs(np.asarray(q - x)) / np.maximum(np.abs(np.asarray(x)),
                                                 2.0 ** -6)
    assert rel.max() <= 1.0 / 16.0 + 1e-5


@pytest.mark.parametrize("m,k,n", [(64, 128, 128), (128, 512, 128)])
def test_fp8_matmul_matches_ref(m, k, n):
    x = rand((m, k), seed=7)
    w = rand((k, n), seed=8, scale=0.05)
    w_fq = ref.weight_quant_fp8(w)
    got = k_fp8.fp8_matmul(x, w_fq)
    want = ref.fp8_matmul(x, w_fq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fp8_weight_quant_pallas_matches_ref():
    w = rand((128, 384), seed=9, scale=0.05)
    got = k_quant.weight_quant_fp8_pallas(w)
    want = ref.weight_quant_fp8(w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-8)


def test_fp8_idempotent():
    w = rand((64, 128), seed=10, scale=0.1)
    q1 = ref.weight_quant_fp8(w)
    q2 = ref.weight_quant_fp8(q1)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-6, atol=1e-9)


def test_quant_e4m3_pallas_matches_ref():
    x = rand((8192,), seed=11, scale=2.0)
    got = k_fp8.quant_e4m3_pallas(x)
    want = ref.quant_e4m3(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_vmem_estimate_reasonable():
    # the fused block at default shapes must fit a 16 MB VMEM budget
    b = k_int8.vmem_bytes_fused(64, 512, 128)
    assert b < 16 * 1024 * 1024
