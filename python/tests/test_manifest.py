"""Build-path contract tests: manifest layout arithmetic and artifact
signatures must match what the Rust runtime assumes."""

import json
import os

import pytest

from compile.config import ModelConfig, FLAGS, ARTIFACTS

CFG = ModelConfig()


def test_layout_contiguous():
    off = 0
    for name, (o, shape) in CFG.offsets().items():
        assert o == off, name
        n = 1
        for s in shape:
            n *= s
        off += n
    assert off == CFG.n_params


def test_section_split():
    assert CFG.a_size + CFG.b_size == CFG.n_params
    # section A entries all come before section B
    a_names = {n for n, _ in CFG.section_a()}
    boundary = CFG.a_size
    for name, (off, _) in CFG.offsets().items():
        if name in a_names:
            assert off < boundary
        else:
            assert off >= boundary


def test_qscale_channels():
    total = sum(ch for _, (_, ch) in CFG.scale_offsets().items())
    assert total == CFG.n_qscales
    for name, shape in CFG.section_b():
        assert CFG.scale_offsets()[name][1] == shape[-1]


def test_flags_are_dense():
    idx = sorted(getattr(FLAGS, a) for a in dir(FLAGS)
                 if a.isupper() and a != "N")
    assert idx == list(range(FLAGS.N))


def test_dims_divisible_for_kernels():
    assert CFG.d_model % CFG.n_heads == 0
    # pallas block shapes must divide the linear dims
    for _, shape in CFG.section_b():
        assert shape[-1] % min(CFG.block_n, shape[-1]) == 0


def test_prompt_plus_gen_fits_context():
    assert CFG.max_prompt < CFG.max_seq


@pytest.mark.skipif(not os.path.exists("../artifacts/manifest.json"),
                    reason="artifacts not built")
def test_manifest_matches_config():
    with open("../artifacts/manifest.json") as f:
        man = json.load(f)
    c = man["config"]
    assert c["n_params"] == CFG.n_params
    assert c["a_size"] == CFG.a_size
    assert c["vocab_size"] == CFG.vocab_size
    assert man["max_new"] == CFG.max_seq - CFG.max_prompt
    for art in ARTIFACTS:
        if art in ("prefill_bf16",):  # every listed artifact has a signature
            assert art in man["artifacts"]
    # all signatures have inputs and outputs
    for name, sig in man["artifacts"].items():
        assert sig["inputs"], name
        assert sig["outputs"], name


@pytest.mark.skipif(not os.path.exists("../artifacts"),
                    reason="artifacts not built")
def test_all_artifacts_lowered():
    missing = [a for a in ARTIFACTS
               if not os.path.exists(f"../artifacts/{a}.hlo.txt")]
    # generate_* are extra (not in the base ARTIFACTS list); check core set
    assert not missing, missing
