"""L2 invariants: model forward/generation/objective semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig, FLAGS
from compile import model as M

CFG = ModelConfig()


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def weights(params):
    return M.weights_bf16(CFG, params)


def make_prompts(seed=0, b=8):
    rng = np.random.default_rng(seed)
    s = CFG.max_seq
    lens = rng.integers(4, CFG.max_prompt, b).astype(np.int32)
    toks = np.zeros((b, s), dtype=np.int32)
    for i in range(b):
        toks[i, 0] = M.BOS_ID
        toks[i, 1:lens[i]] = rng.integers(3, CFG.vocab_size, lens[i] - 1)
    return jnp.asarray(toks), jnp.asarray(lens)


def test_param_layout_roundtrip(params):
    p = M.unflatten(CFG, params)
    flat2 = M.flatten(CFG, p)
    np.testing.assert_array_equal(np.asarray(params), np.asarray(flat2))
    assert params.shape == (CFG.n_params,)


def test_init_deterministic():
    a = M.init_params(CFG, 7)
    b = M.init_params(CFG, 7)
    c = M.init_params(CFG, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(jnp.max(jnp.abs(a - c))) > 0


def test_logprobs_are_valid(weights):
    toks, _ = make_prompts(1)
    lp, value, ent = jax.jit(
        lambda t: M.sequence_scores(CFG, weights, t))(toks)
    assert bool(jnp.all(lp <= 1e-6))
    assert bool(jnp.all(jnp.isfinite(lp)))
    assert bool(jnp.all(ent >= -1e-5))
    # entropy of a 64-way distribution is at most ln(64)
    assert float(jnp.max(ent)) <= np.log(CFG.vocab_size) + 1e-4
    assert bool(jnp.all(jnp.isfinite(value)))


def test_causality(weights):
    """Changing a future token must not change past logprobs."""
    toks, _ = make_prompts(2, b=4)
    lp1, _, _ = M.sequence_scores(CFG, weights, toks)
    toks2 = toks.at[:, 60].set(5)
    lp2, _, _ = M.sequence_scores(CFG, weights, toks2)
    np.testing.assert_allclose(np.asarray(lp1[:, :60]),
                               np.asarray(lp2[:, :60]), atol=1e-5)


def test_generate_matches_teacher_forcing(weights):
    toks, lens = make_prompts(3)
    gen_t, gen_lp, gen_mask = jax.jit(
        lambda t, l: M.generate(CFG, weights, t, l, 11, jnp.float32(1.0),
                                jnp.float32(1.0), 20))(toks, lens)
    lp_tf, _, _ = M.sequence_scores(CFG, weights, gen_t)
    m = np.asarray(gen_mask)
    diff = np.abs(np.asarray(lp_tf) - np.asarray(gen_lp)) * m
    assert diff.max() < 1e-4


def test_generate_greedy_deterministic(weights):
    toks, lens = make_prompts(4)
    f = jax.jit(lambda t, l, s: M.generate(CFG, weights, t, l, s,
                                           jnp.float32(0.0),
                                           jnp.float32(1.0), 16))
    t1, _, _ = f(toks, lens, 1)
    t2, _, _ = f(toks, lens, 999)  # seed must not matter for greedy
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_generate_mask_structure(weights):
    toks, lens = make_prompts(5)
    gen_t, _, gen_mask = M.generate(CFG, weights, toks, lens, 2,
                                    jnp.float32(1.0), jnp.float32(1.0), 24)
    t_np, m_np = np.asarray(gen_t), np.asarray(gen_mask)
    for b in range(t_np.shape[0]):
        l = int(lens[b])
        # mask zero on the prompt
        assert m_np[b, :l].sum() == 0
        on = np.where(m_np[b] > 0.5)[0]
        if len(on):
            # generated span is contiguous starting at the prompt end
            assert on[0] == l
            assert np.array_equal(on, np.arange(on[0], on[-1] + 1))
            # EOS at most once, and only at the end of the span
            eos_pos = np.where(t_np[b] == M.EOS_ID)[0]
            if len(eos_pos):
                assert eos_pos[0] == on[-1]


def test_prefill_decode_consistency(weights):
    """One decode step after prefill equals the full forward's next logits."""
    toks, lens = make_prompts(6, b=4)
    p = CFG.max_prompt
    ck, cv, logits_last = M.prefill(CFG, weights, toks[:, :p], lens)
    # teacher-forced logits at position len-1:
    h = M.forward_full(CFG, weights, toks[:, :p])
    logits_all = M.logits_from_hidden(weights, h)
    for b in range(4):
        l = int(lens[b]) - 1
        np.testing.assert_allclose(np.asarray(logits_last[b]),
                                   np.asarray(logits_all[b, l]),
                                   rtol=1e-4, atol=1e-4)


def test_uaq_exact_invariance(params):
    toks, _ = make_prompts(7, b=4)
    lp0, v0, _ = M.sequence_scores(CFG, M.weights_bf16(CFG, params), toks)
    for s in [1.5, 2.0, 0.5]:
        p2 = M.uaq_scale(CFG, params, jnp.float32(s))
        lp, v, _ = M.sequence_scores(CFG, M.weights_bf16(CFG, p2), toks)
        np.testing.assert_allclose(np.asarray(lp0), np.asarray(lp),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(v0), np.asarray(v), atol=2e-5)


def test_uaq_reduces_quant_error_and_gap(params):
    """UAQ shrinks INT8 weight-quantization error on scaled matrices (Eq. 12
    intuition) and reduces the quantized-vs-fp logprob gap."""
    from compile.kernels import ref
    p = M.unflatten(CFG, params)
    p_u = M.unflatten(CFG, M.uaq_scale(CFG, params, jnp.float32(1.5)))
    name = "layer0.qkv"
    def err(w):
        wq, ws = ref.weight_quant_int8(w)
        return float(jnp.sum(jnp.square(ref.dequant_int8(wq, ws) - w)))
    # absolute quant error on W/s is (1/s^2) x error on W
    assert err(p_u[name]) < err(p[name]) * 0.6


def test_sampling_top_p_restricts_support(weights):
    logits = jnp.asarray(
        np.random.default_rng(0).normal(size=(64, CFG.vocab_size)) * 4.0,
        jnp.float32)
    key = jax.random.PRNGKey(0)
    toks, lp = M.sample_token(logits, key, jnp.float32(1.0), jnp.float32(0.3))
    # every sampled token must be inside the nucleus: p(tok) >= threshold
    p = jax.nn.softmax(logits, axis=-1)
    p_tok = jnp.take_along_axis(p, toks[:, None], axis=1)[:, 0]
    # with top_p=0.3 the nucleus is small; sampled tokens are high-prob
    assert float(jnp.min(p_tok)) > 0.01
    assert bool(jnp.all(lp <= 0.0))


def test_sampling_tied_logits_minimal_nucleus():
    """Boundary ties break by sort order: a three-way tie at the top with
    top_p=0.4 keeps exactly two tokens (ids 0 and 1, mass 2/3 >= 0.4) and
    never the third — the kept set is the minimal nucleus, matching the
    host-side scheduler sampler."""
    row = np.full((CFG.vocab_size,), -30.0, np.float32)
    row[:3] = 2.0
    logits = jnp.asarray(np.tile(row, (8, 1)))
    for s in range(6):
        toks, lp = M.sample_token(logits, jax.random.PRNGKey(s),
                                  jnp.float32(1.0), jnp.float32(0.4))
        assert bool(jnp.all(toks < 2)), np.asarray(toks)
        # renormalized two-token nucleus: lp == ln(1/2)
        np.testing.assert_allclose(np.asarray(lp), np.log(0.5), atol=1e-5)


def test_sampling_top_p_zero_keeps_top_token():
    """Degenerate top_p: the nucleus is never empty — the top token is kept
    with a finite renormalized logprob (0.0), never NaN."""
    row = np.zeros((CFG.vocab_size,), np.float32)
    row[5] = 3.0
    logits = jnp.asarray(np.tile(row, (4, 1)))
    toks, lp = M.sample_token(logits, jax.random.PRNGKey(1),
                              jnp.float32(1.0), jnp.float32(0.0))
    assert bool(jnp.all(toks == 5))
    np.testing.assert_allclose(np.asarray(lp), 0.0, atol=1e-6)


def test_objective_modes_differ(params):
    """The five objective modes must induce different losses when behavior
    and proximal policies diverge."""
    b, t = CFG.train_batch, CFG.max_seq
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(3, 60, (b, t)).astype(np.int32))
    mask = jnp.asarray((rng.random((b, t)) < 0.3).astype(np.float32))
    adv = jnp.asarray(rng.normal(size=(b, t)).astype(np.float32))
    w = M.weights_bf16(CFG, params)
    lp_theta, _, _ = M.sequence_scores(CFG, w, toks)
    lp_prox = lp_theta - 0.05
    lp_behav = lp_theta - jnp.asarray(
        np.abs(rng.normal(size=(b, t))).astype(np.float32))
    zeros = jnp.zeros((b, t), jnp.float32)
    losses = []
    for mode in [0.0, 1.0, 2.0, 3.0, 4.0]:
        flags = np.zeros(FLAGS.N, np.float32)
        flags[FLAGS.OBJ_MODE] = mode
        flags[FLAGS.EPS_LOW] = 0.2
        flags[FLAGS.EPS_HIGH] = 0.28
        flags[FLAGS.TIS_CAP] = 2.0
        loss, mets = M.rl_loss(CFG, params, toks, mask, adv, lp_behav,
                               lp_prox, lp_theta, zeros, zeros,
                               jnp.asarray(flags))
        assert bool(jnp.isfinite(loss)), f"mode {mode}"
        losses.append(float(loss))
    assert len({round(x, 6) for x in losses}) >= 4, losses


def test_train_step_descends(params):
    """Repeated steps on a fixed batch must reduce the surrogate loss."""
    b, t = CFG.train_batch, CFG.max_seq
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(3, 60, (b, t)).astype(np.int32))
    mask = jnp.zeros((b, t), jnp.float32).at[:, 10:30].set(1.0)
    adv = jnp.asarray((rng.random((b, t)) - 0.4).astype(np.float32))
    w = M.weights_bf16(CFG, params)
    lp, _, _ = M.sequence_scores(CFG, w, toks)
    zeros = jnp.zeros((b, t), jnp.float32)
    flags = np.zeros(FLAGS.N, np.float32)
    flags[FLAGS.OBJ_MODE] = 4.0
    flags[FLAGS.EPS_LOW] = 0.2
    flags[FLAGS.EPS_HIGH] = 0.28
    flags[FLAGS.TIS_CAP] = 2.0
    flags[FLAGS.LR] = 1e-3
    flags[FLAGS.BETA1] = 0.9
    flags[FLAGS.BETA2] = 0.999
    flags[FLAGS.ADAM_EPS] = 1e-8
    flags[FLAGS.MAX_GRAD_NORM] = 1.0
    flags = jnp.asarray(flags)
    p, m, v = params, jnp.zeros_like(params), jnp.zeros_like(params)
    step_fn = jax.jit(lambda p, m, v, s: M.train_step(
        CFG, p, m, v, s, toks, mask, adv, lp, lp, lp, zeros, zeros, flags))
    first = None
    last = None
    for i in range(5):
        p, m, v, mets = step_fn(p, m, v, jnp.float32(i + 1.0))
        if first is None:
            first = float(mets[0])
        last = float(mets[0])
    assert last < first, (first, last)


def test_sft_loss_decreases(params):
    b, t = CFG.train_batch, CFG.max_seq
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(3, 60, (b, t)).astype(np.int32))
    mask = jnp.zeros((b, t), jnp.float32).at[:, 5:20].set(1.0)
    flags = np.zeros(FLAGS.N, np.float32)
    flags[FLAGS.LR] = 1e-3
    flags[FLAGS.BETA1] = 0.9
    flags[FLAGS.BETA2] = 0.999
    flags[FLAGS.ADAM_EPS] = 1e-8
    flags = jnp.asarray(flags)
    p, m, v = params, jnp.zeros_like(params), jnp.zeros_like(params)
    f = jax.jit(lambda p, m, v, s: M.sft_step(CFG, p, m, v, s, toks, mask,
                                              flags))
    p, m, v, m0 = f(p, m, v, jnp.float32(1.0))
    for i in range(4):
        p, m, v, mets = f(p, m, v, jnp.float32(i + 2.0))
    assert float(mets[0]) < float(m0[0])


def test_quantize_sections_shapes(params):
    fb = params[CFG.a_size:]
    qw, qs = M.quantize_section_b_int8(CFG, fb)
    assert qw.shape == (CFG.b_size,) and qw.dtype == jnp.int8
    assert qs.shape == (CFG.n_qscales,)
    fq = M.quantize_section_b_fp8(CFG, fb)
    assert fq.shape == (CFG.b_size,)
    # fake-quantized values stay close
    assert float(jnp.mean(jnp.abs(fq - fb))) < float(jnp.mean(jnp.abs(fb))) * 0.1
