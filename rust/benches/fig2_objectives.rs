//! Fig. 2 reproduction: training rewards + token-clipped-fraction under
//! different objectives with INT8 quantized rollout.
//!
//! Paper setup: DeepScaleR GRPO.  Series:
//!   (a) BF16 on-policy           — the full-precision reference
//!   (b) INT8 + Eq. 3 (naive IS against the quantized actor) — unstable,
//!       clip fraction spikes then collapses
//!   (c) INT8 + Eq. 1 (ratio vs fp old actor, mismatch ignored) — stable
//!       curve but a growing gap vs BF16
//!   (d) INT8 + decoupled/TIS (Eq. 4/5) — stable
//!
//! Expected shape: (b) degrades or collapses, (d) tracks (a) closely,
//! (c) in between.  `QURL_FULL=1` runs the preset's full horizon.

use qurl::benchkit as bk;
use qurl::config;
use qurl::rl::ObjectiveKind;
use qurl::runtime::QuantMode;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(6, 160);
    let variants: [(&str, QuantMode, ObjectiveKind); 4] = [
        ("bf16_onpolicy", QuantMode::Bf16, ObjectiveKind::OnPolicy),
        ("int8_naive_eq3", QuantMode::Int8, ObjectiveKind::NaiveQuant),
        ("int8_fpold_eq1", QuantMode::Int8, ObjectiveKind::OnPolicy),
        ("int8_tis_eq5", QuantMode::Int8, ObjectiveKind::Tis),
    ];
    let mut finals = Vec::new();
    for (name, mode, kind) in variants {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.rollout_mode = mode;
        cfg.objective.kind = kind;
        cfg.uaq_scale = 1.0; // isolate the objective axis
        cfg.eval_every = 0;
        let run = format!("fig2_{name}");
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("\n== Fig 2 series: {name} ==");
        bk::print_curve(name, &tr.rec, "reward");
        bk::print_curve(name, &tr.rec, "clip_frac");
        tr.rec.write_csv(&bk::results_dir(), &["reward", "clip_frac"])?;
        finals.push((name, reward, tr.rec.tail_mean("clip_frac", 8)
                     .unwrap_or(0.0)));
    }
    println!("\n== Fig 2 summary (tail means over last 8 steps) ==");
    println!("{:18} {:>8} {:>10}", "series", "reward", "clip_frac");
    for (name, r, c) in finals {
        println!("{name:18} {r:8.3} {c:10.4}");
    }
    Ok(())
}
