//! Fig. 3 reproduction: long-horizon divergence between behavior and
//! proximal policies — (a) KL(behav || prox) growth, (b) max prox/behav
//! probability ratio — comparing TIS (Eq. 5) against ACR (Eq. 9).
//!
//! The paper observes KL rising ~12x (0.002 -> 0.025) past step 1000 with
//! TIS, while ACR flattens it.  On this testbed the same mechanism is
//! exercised at a shorter horizon, with an `engine_noise` knob standing in
//! for the larger engine-mismatch component of the ratio (DESIGN.md §2).

use qurl::benchkit as bk;
use qurl::config;
use qurl::rl::ObjectiveKind;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(8, 400);
    let noise = std::env::var("QURL_NOISE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05f32);
    let mut rows = Vec::new();
    for (name, kind) in [("tis", ObjectiveKind::Tis),
                         ("acr", ObjectiveKind::Acr)] {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.objective.kind = kind;
        cfg.uaq_scale = 1.0;
        cfg.engine_noise = noise;
        cfg.eval_every = 0;
        let run = format!("fig3_{name}");
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("\n== Fig 3 series: {name} (engine_noise={noise}) ==");
        bk::print_curve(name, &tr.rec, "kl_behav_prox");
        bk::print_curve(name, &tr.rec, "rho_max");
        bk::print_curve(name, &tr.rec, "reward");
        tr.rec.write_csv(&bk::results_dir(),
                         &["kl_behav_prox", "rho_max", "reward"])?;
        let kl_series = tr.rec.series("kl_behav_prox");
        let early: f64 = kl_series.iter().take(8).map(|&(_, v)| v).sum::<f64>()
            / 8.0_f64.min(kl_series.len() as f64);
        let late = tr.rec.tail_mean("kl_behav_prox", 8).unwrap_or(0.0);
        rows.push((name, early, late, reward,
                   tr.rec.series("rho_max").iter().map(|&(_, v)| v)
                       .fold(0.0f64, f64::max)));
    }
    println!("\n== Fig 3 summary ==");
    println!("{:6} {:>12} {:>12} {:>9} {:>12}", "series", "KL(early)",
             "KL(late)", "reward", "max rho");
    for (name, e, l, r, mx) in rows {
        println!("{name:6} {e:12.5} {l:12.5} {r:9.3} {mx:12.1}");
    }
    println!("\nexpected shape: TIS KL grows with horizon; ACR stays flat \
              or lower at matched reward.");
    Ok(())
}
