//! Fig. 4 reproduction: the weight-update problem (§4.3).
//!
//! (a) analytic illustration — per-matrix INT8 quantization step vs the
//!     typical per-step weight update (the paper's Fig. 4a intuition);
//! (b) measured |pi_qhat - pi| (mean absolute probability difference
//!     between the quantized and fp old actors) over RL steps, with and
//!     without UAQ — UAQ should keep the quantized engine tracking the
//!     training dynamics (larger, *changing* diff) instead of freezing.

use qurl::benchkit as bk;
use qurl::config;
use qurl::quant::analysis;
use qurl::runtime::QuantMode;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let man = rt.manifest().clone();

    // ---- (a) analytic: quant step vs update magnitude --------------------
    println!("== Fig 4(a): INT8 step size vs typical update (base model) ==");
    println!("{:20} {:>12} {:>14} {:>10}", "matrix", "mean|w|",
             "quant step", "ratio");
    let flat_b = &base.params[man.a_size..];
    analysis::for_each_mat(&man, |name, off, k, n| {
        let w = &flat_b[off..off + k * n];
        let mean_abs: f64 = w.iter().map(|&x| x.abs() as f64).sum::<f64>()
            / w.len() as f64;
        // per-channel scale ~ absmax/127; average across channels
        let (_, scales) = qurl::quant::int8::weight_quant(w, k, n);
        let step: f64 = scales.iter().map(|&s| s as f64).sum::<f64>()
            / scales.len() as f64;
        // paper: update ~ alpha * G with G in [0.1, 1]; our testbed lr
        let upd = 5e-5 * 0.3;
        println!("{name:20} {mean_abs:12.5} {step:14.6} {:10.2}",
                 step / upd);
    });
    println!("(ratio >> 1 means quantization masks the per-step update — \
              the paper's Eq. 10 mismatch)\n");

    // ---- (b) measured pi-diff over training ------------------------------
    let steps = bk::bench_steps(5, 200);
    for (name, uaq) in [("no_uaq", 1.0f32), ("uaq1.5", 1.5f32)] {
        let mut cfg = config::dapo_aime();
        cfg.steps = steps;
        cfg.rollout_mode = QuantMode::Int8;
        cfg.uaq_scale = uaq;
        cfg.analyze_every = 4;
        cfg.eval_every = 0;
        let run = format!("fig4_{name}");
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("== Fig 4(b) series: {name} ==");
        bk::print_curve(name, &tr.rec, "prob_diff_behav_prox");
        bk::print_curve(name, &tr.rec, "int8_code_change_frac");
        tr.rec.write_csv(&bk::results_dir(),
                         &["prob_diff_behav_prox", "int8_code_change_frac",
                           "norm_weight_update", "norm_quant_error"])?;
        let frac = tr.rec.tail_mean("int8_code_change_frac", 4).unwrap_or(0.0);
        println!("  int8 codes changed per analysis interval: {frac:.4}\n");
    }
    println!("expected shape: with UAQ the quantized engine's code-change \
              fraction rises (updates exceed the quant grid), tracking \
              training dynamics.");
    Ok(())
}
