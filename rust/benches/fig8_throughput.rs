//! Fig. 8 reproduction: INT8 rollout acceleration.
//!
//! Two parts:
//! 1. the roofline simulator sweep over {7B, 14B, 32B} x {A6000, A100,
//!    H100} — the paper's actual grid (this testbed has no GPUs; DESIGN.md
//!    §2 argues the model preserves the figure's shape);
//! 2. measured decode throughput of THIS testbed's artifacts (bf16/int8/
//!    fp8 generate waves on CPU) — honest numbers for the interpret-mode
//!    Pallas path, not a GPU proxy.

use std::sync::Arc;

use qurl::benchkit as bk;
use qurl::coordinator::{pages_for, DecodeEngine, FinishReason, GroupResult,
                        GroupSpec, KvConfig, KvLayout, PlacementLog,
                        PrunePolicy, RolloutRequest, RolloutService,
                        Scheduler, SchedulerStats, StealPolicy, StepEngine,
                        StripePolicy};
use qurl::perfmodel::{self, roofline, DecodeConfig, Precision};
use qurl::quant::delta;
use qurl::runtime::QuantMode;
use qurl::tasks::{encode_batch, Problem, Suite, Tokenizer};
use qurl::util::json::Json;
use qurl::util::timer::{bench, print_table};

fn main() -> anyhow::Result<()> {
    // ---- part 1: roofline grid (the paper's figure) -----------------------
    let cfg = DecodeConfig::default();
    let mut rows = Vec::new();
    for scale in roofline::ALL_SCALES {
        for gpu in perfmodel::ALL_GPUS {
            let bf16 = perfmodel::decode_throughput(gpu, scale, Precision::Bf16, &cfg);
            let int8 = perfmodel::decode_throughput(gpu, scale, Precision::Int8, &cfg);
            rows.push(vec![
                scale.name().to_string(),
                gpu.spec().name.to_string(),
                format!("{bf16:.2}"),
                format!("{int8:.2}"),
                format!("+{:.0}%", (int8 / bf16 - 1.0) * 100.0),
            ]);
        }
    }
    print_table(
        &format!("Fig. 8 analog: roofline decode throughput (queries/s, \
                  batch={}, ctx={}, gen={})", cfg.batch, cfg.ctx, cfg.gen_len),
        &["model", "gpu", "bf16 q/s", "int8 q/s", "speedup"], &rows);
    println!("paper reference: 7B +20-30%, 32B +70% (A100) / +90% (H100); \
              larger models gain more.");

    // batch sensitivity (why bigger models gain more: weight traffic
    // dominates the fp16 KV as params grow)
    let mut rows = Vec::new();
    for batch in [8, 32, 64, 128] {
        let c = DecodeConfig { batch, ..cfg };
        let s7 = perfmodel::speedup(perfmodel::Gpu::A100, roofline::ModelScale::B7,
                                    Precision::Int8, &c);
        let s32 = perfmodel::speedup(perfmodel::Gpu::A100, roofline::ModelScale::B32,
                                     Precision::Int8, &c);
        rows.push(vec![batch.to_string(), format!("{:.0}%", (s7 - 1.0) * 100.0),
                       format!("{:.0}%", (s32 - 1.0) * 100.0)]);
    }
    print_table("speedup vs batch (A100)", &["batch", "7B", "32B"], &rows);

    // ---- part 2: measured CPU decode of the actual artifacts --------------
    let (rt, base) = bk::setup()?;
    let man = rt.manifest().clone();
    let (b, s) = (man.rollout_batch, man.max_seq);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let probs = suite.test_set(5, 11);
    let refs: Vec<&qurl::tasks::Problem> =
        probs.iter().take(b).map(|(_, p)| p).collect();
    let (tokens, lens) = encode_batch(&tk, &refs, b, s, man.max_prompt);
    let mut rows = Vec::new();
    for mode in [QuantMode::Bf16, QuantMode::Int8, QuantMode::Fp8] {
        let w = rt.engine_weights(mode, &base.params)?;
        let mut seed = 0i32;
        let _ = rt.generate(&w, &tokens, &lens, 0, 1.0, 1.0)?; // compile+warm
        let mut toks = 0f64;
        let stat = bench(&format!("generate_{}", mode.tag()), 0, 2, 10.0, || {
            seed += 1;
            let out = rt.generate(&w, &tokens, &lens, seed, 1.0, 1.0).unwrap();
            toks += out.mask.iter().sum::<f32>() as f64;
        });
        rows.push(vec![
            mode.tag().to_string(),
            format!("{:.2}", stat.mean_s),
            format!("{:.0}", toks / (stat.mean_s * stat.iters as f64)),
        ]);
    }
    print_table("measured CPU-testbed rollout (interpret-mode Pallas; NOT a \
                 GPU proxy)",
                &["engine", "s/wave", "tok/s"], &rows);
    println!("\nNote: interpret-mode INT8 runs extra quantize ops on CPU \
              with no INT8 hardware path, so CPU wall-clock does not show \
              the GPU speedup; the roofline sweep above carries Fig. 8's \
              claim. See DESIGN.md §Hardware-Adaptation.");

    // ---- part 3: fused lockstep waves vs continuous-batching scheduler ----
    // Mixed-length request sets expose the lockstep tax: a fused wave's
    // decode scan always runs the full max_new trip count, so every short
    // sequence pays for the longest, while the scheduler releases a KV slot
    // the moment a sequence finishes and backfills it from the queue.  The
    // decode-step columns are the hardware-independent comparison; tok/s is
    // this CPU testbed's measured rate.
    let w = rt.engine_weights(QuantMode::Int8, &base.params)?;
    let mut sampler = suite.train_sampler(42);
    let mixes: [(&str, usize, fn(usize, usize) -> usize); 3] = [
        // n requests, per-request max_new as f(request index, man.max_new)
        ("uniform 1xB", b, |_, m| m),
        ("mixed 2xB", 2 * b,
         |i, m| if i % 2 == 0 { (m / 4).max(1) } else { m }),
        ("short-heavy 3xB", 3 * b,
         |i, m| if i % 3 == 2 { m } else { (m / 8).max(1) }),
    ];
    let mut rows = Vec::new();
    let mut mix_json: Vec<Json> = Vec::new();
    for (label, n, max_new_of) in mixes {
        let probs: Vec<Problem> = (0..n).map(|_| sampler.next().1).collect();
        // fused path: waves of B prompts, full decode scan per wave.  The
        // store's per-artifact byte counters measure its copy tax (weights
        // + token grids staged per wave).
        rt.store.reset_stats();
        let t0 = std::time::Instant::now();
        let mut fused_tokens = 0f64;
        let mut waves = 0usize;
        for wave in probs.chunks(b) {
            let refs: Vec<&Problem> = wave.iter().collect();
            let (tokens, lens) = encode_batch(&tk, &refs, b, s, man.max_prompt);
            let gen = rt.generate(&w, &tokens, &lens, 1000 + waves as i32,
                                  1.0, 1.0)?;
            fused_tokens += gen.mask.iter().sum::<f32>() as f64;
            waves += 1;
        }
        let fused_wall = t0.elapsed().as_secs_f64();
        let fused_steps = waves * man.max_new;
        let fused_h2d: u64 = rt.store.stats().iter()
            .filter(|(name, _)| name.starts_with("generate_"))
            .map(|(_, st)| st.bytes_h2d)
            .sum();
        // scheduler path: everything submitted up front, per-request length
        let mut engine = StepEngine::new(&rt, w.clone());
        let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
        for (i, p) in probs.iter().enumerate() {
            sched.submit(RolloutRequest {
                id: i as u64,
                prompt: Arc::new(tk.encode_prompt(&p.prompt)),
                max_new: max_new_of(i, man.max_new),
                temperature: 1.0,
                top_p: 1.0,
                seed: 0x9eed ^ i as u64,
            });
        }
        let results = sched.run_to_completion()?;
        assert_eq!(results.len(), n, "scheduler dropped requests");
        let st = sched.take_stats();
        let per_tick = |bytes: u64| bytes as f64 / st.decode_calls.max(1) as f64;
        rows.push(vec![
            label.to_string(),
            n.to_string(),
            fused_steps.to_string(),
            st.decode_calls.to_string(),
            format!("-{:.0}%",
                    (1.0 - st.decode_calls as f64 / fused_steps as f64)
                        * 100.0),
            format!("{:.2}", st.mean_occupancy()),
            format!("{:.0}", fused_h2d as f64 / fused_steps as f64 / 1e3),
            format!("{:.0}", per_tick(st.bytes_h2d) / 1e3),
            format!("{:.0}", fused_tokens / fused_wall.max(1e-9)),
            format!("{:.0}", st.tokens_per_s()),
        ]);
        mix_json.push(Json::obj(vec![
            ("workload", Json::str(label)),
            ("requests", Json::num(n as f64)),
            ("fused_decode_steps", Json::num(fused_steps as f64)),
            ("sched_decode_calls", Json::num(st.decode_calls as f64)),
            ("sched_decode_steps_per_s",
             Json::num(st.decode_calls as f64 / st.wall_s.max(1e-9))),
            ("sched_tokens_per_s", Json::num(st.tokens_per_s())),
            ("sched_prefill_rows", Json::num(st.prefill_rows as f64)),
            ("sched_bytes_h2d_per_tick", Json::num(per_tick(st.bytes_h2d))),
            ("sched_bytes_d2h_per_tick", Json::num(per_tick(st.bytes_d2h))),
            ("fused_bytes_h2d_per_step",
             Json::num(fused_h2d as f64 / fused_steps as f64)),
        ]));
    }
    print_table("fused waves vs continuous-batching scheduler (int8 engine)",
                &["workload", "reqs", "fused decode steps",
                  "sched decode calls", "saved", "occupancy",
                  "fused h2d KB/step", "sched h2d KB/tick",
                  "fused tok/s", "sched tok/s"], &rows);
    println!("continuous batching cuts decode steps on every mix — the \
              substrate QeRL-style quantized serving and rollout pruning \
              build on.");

    // ---- part 4: RolloutService — group-shared prefill + striping --------
    // GRPO/DAPO rollouts come in groups of one prompt; the service prefills
    // each distinct prompt once and forks its KV rows into the sibling
    // slots (DecodeEngine::fork_kv), and stripes whole groups across
    // engine replicas.  Baseline = the PR-1 per-request behavior
    // (share_prefix off) on identical submissions.
    let group = 4usize;
    let n_groups = (2 * b).div_ceil(group);
    let probs: Vec<Problem> =
        (0..n_groups).map(|_| sampler.next().1).collect();
    let variants: [(&str, usize, bool); 3] = [
        ("per-request (PR-1)", 1, false),
        ("service fork x1", 1, true),
        ("service fork x2", 2, true),
    ];
    let mut rows = Vec::new();
    for (label, n_engines, share) in variants {
        let engines: Vec<StepEngine> = (0..n_engines)
            .map(|_| StepEngine::new(&rt, w.clone()))
            .collect();
        let mut svc = RolloutService::new(engines, man.max_seq, man.eos_id);
        svc.set_share_prefix(share);
        for (gid, p) in probs.iter().enumerate() {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: tk.encode_prompt(&p.prompt),
                group_size: group,
                max_new: man.max_new,
                temperature: 1.0,
                top_p: 1.0,
                seed: 0x11 ^ ((gid as u64) << 8),
            });
        }
        let results = svc.run(|_, _| 0.0)?;
        assert_eq!(results.len(), n_groups, "service dropped groups");
        let st = svc.take_stats()?;
        rows.push(vec![
            label.to_string(),
            n_engines.to_string(),
            st.prefill_rows.to_string(),
            st.forked.to_string(),
            format!("{:.1}", st.mean_prefill_batch()),
            st.decode_calls.to_string(),
            format!("{:.0}", st.tokens_per_s()),
        ]);
    }
    print_table(&format!("rollout service: {n_groups} groups x {group} \
                          (int8 engine)"),
                &["path", "engines", "prefill rows", "forked", "rows/call",
                  "decode calls", "tok/s"], &rows);
    println!("group-shared prefill cuts prefill rows ~{group}x; striping \
              splits the decode queue across engine replicas.  In-flight \
              pruning savings are measured in the table2 bench (DAPO).");

    // ---- part 5: the per-tick copy tax — resident vs per-call inputs -----
    // Same workload twice through one StepEngine configuration: resident
    // inputs (weights staged once per weight epoch, KV literals recycled
    // decode→decode — the default) vs the per-call baseline (weights
    // reconvert and KV round-trips through host vectors every tick).
    // Outputs are bit-identical (integration-tested); only the copy
    // columns move.  This is the PCIe-shaped cost a GPU backend inherits.
    let tax_probs: Vec<Problem> =
        (0..b).map(|_| sampler.next().1).collect();
    let run_tax = |resident: bool|
        -> anyhow::Result<(qurl::coordinator::SchedulerStats, u64)> {
        let mut engine = StepEngine::new(&rt, w.clone());
        engine.set_resident(resident);
        let weight_bytes = engine.weight_bytes();
        let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
        for (i, p) in tax_probs.iter().enumerate() {
            sched.submit(RolloutRequest {
                id: i as u64,
                prompt: Arc::new(tk.encode_prompt(&p.prompt)),
                max_new: man.max_new,
                temperature: 1.0,
                top_p: 1.0,
                seed: 0x7a5e ^ i as u64,
            });
        }
        let results = sched.run_to_completion()?;
        assert_eq!(results.len(), tax_probs.len());
        Ok((sched.take_stats(), weight_bytes))
    };
    let (res_st, weight_bytes) = run_tax(true)?;
    let (pc_st, _) = run_tax(false)?;
    let mut rows = Vec::new();
    for (label, st) in [("resident (default)", &res_st),
                        ("per-call baseline", &pc_st)] {
        rows.push(vec![
            label.to_string(),
            st.decode_calls.to_string(),
            format!("{:.1}", st.bytes_h2d as f64 / 1e6),
            format!("{:.1}",
                    st.bytes_h2d as f64 / st.decode_calls.max(1) as f64 / 1e3),
            format!("{:.1}", st.bytes_d2h as f64 / 1e6),
            format!("{:.0}", st.tokens_per_s()),
        ]);
    }
    print_table(&format!("per-tick copy tax, resident vs per-call inputs \
                          (weights = {:.1} MB/conversion)",
                         weight_bytes as f64 / 1e6),
                &["input path", "decode calls", "MB h2d total",
                  "KB h2d/tick", "MB d2h total", "tok/s"], &rows);
    println!("resident inputs stage weights once per weight epoch and \
              recycle KV literals decode→decode; the per-call baseline \
              re-converts weights + both KV caches every tick.");

    // ---- part 6: KV memory — dense reservation vs paged allocation -------
    // Same grouped workload through both KV layouts at one FIXED page
    // budget (enough full-length dense reservations for half the slots).
    // Dense reserves pages_for(max_seq) pages per admission, so at most
    // B/2 sequences run concurrently; paged admits on the prompt footprint
    // and grows page-by-page, so the same budget carries more concurrent
    // sequences — and forked siblings alias their prompt pages outright.
    // Peak resident KV bytes = high-water pages x page_size positions x
    // 2 (K+V) x L x H x Dh x 4 bytes.
    let kv_page = 8usize;
    let budget = (b / 2).max(1) * pages_for(man.max_seq, kv_page);
    let pos_bytes =
        (2 * man.n_layers * man.n_heads * man.head_dim * 4) as f64;
    let kv_probs: Vec<Problem> =
        (0..n_groups).map(|_| sampler.next().1).collect();
    let run_kv = |layout: KvLayout|
        -> anyhow::Result<qurl::coordinator::SchedulerStats> {
        let mut svc = RolloutService::new(
            vec![StepEngine::new(&rt, w.clone())], man.max_seq, man.eos_id);
        svc.set_kv(KvConfig {
            layout,
            page_size: kv_page,
            budget_pages: Some(budget),
        });
        for (gid, p) in kv_probs.iter().enumerate() {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: tk.encode_prompt(&p.prompt),
                group_size: group,
                max_new: man.max_new,
                temperature: 1.0,
                top_p: 1.0,
                seed: 0x6b ^ ((gid as u64) << 8),
            });
        }
        let results = svc.run(|_, _| 0.0)?;
        assert_eq!(results.len(), n_groups, "kv bench dropped groups");
        svc.take_stats()
    };
    let kv_dense = run_kv(KvLayout::Dense)?;
    let kv_paged = run_kv(KvLayout::Paged)?;
    assert_eq!(kv_dense.kv_pages_freed, kv_dense.kv_pages_allocated);
    assert_eq!(kv_paged.kv_pages_freed, kv_paged.kv_pages_allocated);
    let mut rows = Vec::new();
    for (label, st) in [("dense (reserve max_seq)", &kv_dense),
                        ("paged (grow + alias)", &kv_paged)] {
        rows.push(vec![
            label.to_string(),
            st.kv_pages_high_water.to_string(),
            format!("{:.1}",
                    st.kv_pages_high_water as f64 * kv_page as f64
                        * pos_bytes / 1e6),
            st.kv_pages_shared.to_string(),
            st.kv_pages_cow.to_string(),
            format!("{:.1}", st.mean_occupancy() * b as f64),
            format!("{:.0}", st.tokens_per_s()),
        ]);
    }
    print_table(&format!("KV memory at a fixed budget of {budget} pages x \
                          {kv_page} positions (int8 engine, {n_groups} \
                          groups x {group})"),
                &["kv layout", "peak pages", "peak KV MB", "shared",
                  "cow", "eff. concurrency", "tok/s"], &rows);
    println!("paged KV admits on the prompt footprint instead of a full \
              max_seq reservation: more sequences in flight at the same \
              memory, with forked siblings aliasing prompt pages (shared) \
              and detaching lazily on first write (cow).");

    // ---- part 7: work-stealing placement on a straggler workload ----------
    // Even groups decode the full budget and are uniform-rewarded, so
    // online pruning cancels their remainders mid-wave; odd groups finish
    // almost immediately.  Submission-time load estimates can't see any of
    // that, so static placement (rr / least-loaded) strands one replica
    // with the stragglers while the other idles — exactly the gap
    // `--steal idle` closes by moving still-queued groups onto the idle
    // replica.  Ticks-to-drain = max per-engine decode steps (the
    // hardware-independent wall-clock analog); the steal run's placement
    // log is dumped and replayed to confirm placement-as-data reproduces
    // the run (completed members compared bit-for-bit; the enforced
    // steal-beats-least-loaded assertion lives in the mock unit test).
    let n_eng7 = 2usize;
    let strag_probs: Vec<Problem> =
        (0..n_groups).map(|_| sampler.next().1).collect();
    let run_place = |stripe: StripePolicy, steal: StealPolicy,
                     replay: Option<PlacementLog>|
        -> anyhow::Result<(SchedulerStats, Vec<SchedulerStats>,
                           PlacementLog, Vec<GroupResult>)> {
        let engines: Vec<StepEngine> = (0..n_eng7)
            .map(|_| StepEngine::new(&rt, w.clone()))
            .collect();
        let mut svc = RolloutService::new(engines, man.max_seq, man.eos_id);
        svc.stripe = stripe;
        svc.steal = steal;
        if let Some(log) = replay {
            svc.set_replay(log);
        }
        svc.prune = PrunePolicy::online(2);
        for (gid, p) in strag_probs.iter().enumerate() {
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: tk.encode_prompt(&p.prompt),
                group_size: group,
                max_new: if gid % 2 == 0 { man.max_new }
                         else { (man.max_new / 8).max(1) },
                temperature: 1.0,
                top_p: 1.0,
                seed: 0x57ee1 ^ ((gid as u64) << 8),
            });
        }
        let results = svc.run(|gid, res| if gid % 2 == 0 { 1.0 } else {
            (res.generated.len() % 2) as f32
        })?;
        assert_eq!(results.len(), n_groups,
                   "placement bench dropped groups");
        let st = svc.take_stats()?;
        let per = svc.last_engine_stats().to_vec();
        Ok((st, per, svc.placement_log().clone(), results))
    };
    let (rr_st, rr_per, _, _) =
        run_place(StripePolicy::RoundRobin, StealPolicy::Off, None)?;
    let (ll_st, ll_per, _, _) =
        run_place(StripePolicy::LeastLoaded, StealPolicy::Off, None)?;
    let (sl_st, sl_per, sl_log, sl_res) =
        run_place(StripePolicy::LeastLoaded, StealPolicy::Idle, None)?;
    let log_path = bk::results_dir().join("placement_log.json");
    sl_log.save(&log_path)?;
    let (_, _, _, rp_res) = run_place(StripePolicy::Replay, StealPolicy::Off,
                                      Some(PlacementLog::load(&log_path)?))?;
    // completed members only: cancelled-partial lengths under pruning are
    // timing artifacts everywhere, replayed or not
    let fp = |rs: &[GroupResult]| -> Vec<(usize, Vec<i32>, Vec<u32>)> {
        rs.iter()
            .flat_map(|gr| {
                gr.members
                    .iter()
                    .filter(|m| m.result.finish != FinishReason::Cancelled)
                    .map(move |m| {
                        (gr.engine,
                         m.result.generated.clone(),
                         m.result.logprobs.iter().map(|l| l.to_bits())
                             .collect::<Vec<u32>>())
                    })
            })
            .collect()
    };
    let replay_ok = fp(&sl_res) == fp(&rp_res);
    let drain = |per: &[SchedulerStats]| {
        per.iter().map(|s| s.decode_steps).max().unwrap_or(0)
    };
    let mut rows = Vec::new();
    for (label, st, per) in [("round-robin", &rr_st, &rr_per),
                             ("least-loaded", &ll_st, &ll_per),
                             ("least-loaded + steal", &sl_st, &sl_per)] {
        rows.push(vec![
            label.to_string(),
            drain(per).to_string(),
            per.iter().map(|s| s.decode_steps.to_string())
                .collect::<Vec<_>>().join("/"),
            st.idle_ticks.to_string(),
            st.steals.to_string(),
            format!("{:.2}", SchedulerStats::load_imbalance(per)),
            format!("{:.0}", st.tokens_per_s()),
        ]);
    }
    print_table(&format!("straggler placement: {n_groups} groups x {group} \
                          on {n_eng7} engines, skewed budgets + online \
                          pruning (int8 engine)"),
                &["placement", "ticks to drain", "per-engine steps",
                  "idle ticks", "steals", "imbalance", "tok/s"], &rows);
    println!("replay of the stolen run's placement log: {} ({} records, \
              {} steals) -> {}",
             if replay_ok { "bit-identical" } else { "MISMATCH" },
             sl_log.records.len(), sl_log.steals(), log_path.display());

    // ---- part 8: delta requantization — change-aware weight refresh -------
    // A weight refresh used to rebuild AND re-stage every payload no matter
    // how little the step moved the network.  The delta path quantizes
    // through the same artifacts (fanning the host-mirror work across
    // threads), reuses the previous epoch's Arc for every tensor whose
    // quantized payload is bit-identical, and the engine keeps the cached
    // device conversion for every pointer-equal payload — so refresh cost
    // tracks what actually changed.  Sweep update locality and measure the
    // per-tensor report plus the engine's swap-restage ledger (payload
    // granularity: section A / int8 codes / scales re-stage independently).
    let n_tensors = man.params.len();
    let flat_b = &base.params[man.a_size..];
    let q_workers = delta::default_workers(delta::mat_layout(&man).len());
    let t0 = std::time::Instant::now();
    let (qw_1, qs_1) = delta::quant_int8_parallel(&man, flat_b, 1);
    let quant_serial_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (qw_n, qs_n) = delta::quant_int8_parallel(&man, flat_b, q_workers);
    let quant_parallel_s = t0.elapsed().as_secs_f64();
    assert!(qw_1 == qw_n && qs_1 == qs_n,
            "worker count changed quantization bits");
    // deterministic RL-sized relative noise (benches stay seed-free)
    let noise = |i: usize| -> f32 {
        let h = (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> 40) as f32 / 16_777_216.0 - 0.5
    };
    let (w_prev, _) =
        rt.engine_weights_delta(QuantMode::Int8, &base.params, None)?;
    let full_bytes = w_prev.byte_len();
    let b_mats = delta::mat_layout(&man);
    let updates: Vec<(&str, Vec<(usize, usize)>)> = vec![
        ("no update", vec![]),
        ("section A only", vec![(0, man.a_size)]),
        ("half of B",
         b_mats[..b_mats.len().div_ceil(2)]
             .iter()
             .map(|m| (man.a_size + m.w_off, m.numel()))
             .collect()),
        ("every tensor", vec![(0, base.params.len())]),
    ];
    let mut rows = Vec::new();
    let mut sweep_json: Vec<Json> = Vec::new();
    for (label, regions) in updates {
        let mut p1 = base.params.clone();
        for (off, len) in regions {
            for (j, v) in p1[off..off + len].iter_mut().enumerate() {
                *v += 1e-3 * noise(off + j) * v.abs().max(1e-3);
            }
        }
        let (w1, rep) =
            rt.engine_weights_delta(QuantMode::Int8, &p1, Some(&w_prev))?;
        let mut eng = StepEngine::new(&rt, w_prev.clone());
        eng.swap_weights(w1, 1);
        let staged = eng.take_swap_h2d();
        rows.push(vec![
            label.to_string(),
            format!("{}/{}", rep.tensors_changed, rep.total()),
            format!("{:.2}", rep.changed_fraction()),
            format!("{:.0}", staged as f64 / 1e3),
            format!("{:.0}%", staged as f64 / full_bytes as f64 * 100.0),
        ]);
        sweep_json.push(Json::obj(vec![
            ("update", Json::str(label)),
            ("tensors_changed", Json::num(rep.tensors_changed as f64)),
            ("tensors_skipped", Json::num(rep.tensors_skipped as f64)),
            ("changed_fraction", Json::num(rep.changed_fraction())),
            ("swap_bytes_h2d", Json::num(staged as f64)),
        ]));
    }
    print_table(&format!("delta requantization: refresh cost vs update \
                          locality (int8 engine, {n_tensors} tensors, full \
                          restage = {:.0} KB)", full_bytes as f64 / 1e3),
                &["update", "tensors changed", "frac", "swap h2d KB",
                  "vs full"], &rows);
    println!("host quant (section B): serial {quant_serial_s:.3}s vs \
              {q_workers}-worker {quant_parallel_s:.3}s, bit-identical.  A \
              refresh whose tensors all requantized identically swaps for \
              free; localized updates re-stage only their payload section.");

    // machine-readable perf trajectory for later PRs to regress against
    let place_json = |st: &SchedulerStats, per: &[SchedulerStats]| {
        Json::obj(vec![
            ("ticks_to_drain", Json::num(drain(per) as f64)),
            ("decode_steps_per_engine",
             Json::Arr(per.iter().map(|s| Json::num(s.decode_steps as f64))
                 .collect())),
            ("idle_ticks", Json::num(st.idle_ticks as f64)),
            ("steals", Json::num(st.steals as f64)),
            ("load_imbalance",
             Json::num(SchedulerStats::load_imbalance(per))),
            ("cancelled", Json::num(st.cancelled as f64)),
            ("pruned_groups", Json::num(st.pruned_groups as f64)),
            ("tokens_per_s", Json::num(st.tokens_per_s())),
        ])
    };
    let json = Json::obj(vec![
        ("bench", Json::str("fig8_rollout")),
        ("engine", Json::str("int8")),
        ("rollout_batch", Json::num(b as f64)),
        ("max_seq", Json::num(man.max_seq as f64)),
        ("weight_bytes", Json::num(weight_bytes as f64)),
        ("mixes", Json::Arr(mix_json)),
        ("copy_tax", Json::obj(vec![
            ("resident", tax_json(&res_st)),
            ("per_call", tax_json(&pc_st)),
        ])),
        ("kv_memory", Json::obj(vec![
            ("page_size", Json::num(kv_page as f64)),
            ("budget_pages", Json::num(budget as f64)),
            ("bytes_per_position", Json::num(pos_bytes)),
            ("dense", kv_json(&kv_dense, kv_page, pos_bytes, b)),
            ("paged", kv_json(&kv_paged, kv_page, pos_bytes, b)),
        ])),
        ("placement", Json::obj(vec![
            ("engines", Json::num(n_eng7 as f64)),
            ("groups", Json::num(n_groups as f64)),
            ("group_size", Json::num(group as f64)),
            ("rr", place_json(&rr_st, &rr_per)),
            ("least_loaded", place_json(&ll_st, &ll_per)),
            ("steal", place_json(&sl_st, &sl_per)),
            ("steal_records", Json::num(sl_log.steals() as f64)),
            ("replay_bit_identical", Json::Bool(replay_ok)),
            ("placement_log", Json::str("placement_log.json")),
        ])),
        ("requant", Json::obj(vec![
            ("tensors_total", Json::num(n_tensors as f64)),
            ("full_restage_bytes", Json::num(full_bytes as f64)),
            ("host_quant_serial_s", Json::num(quant_serial_s)),
            ("host_quant_parallel_s", Json::num(quant_parallel_s)),
            ("quant_workers", Json::num(q_workers as f64)),
            ("updates", Json::Arr(sweep_json)),
        ])),
    ]);
    let path = bk::results_dir().join("BENCH_rollout.json");
    std::fs::write(&path, json.to_string())?;
    println!("\nwrote {}", path.display());
    Ok(())
}

/// One KV-layout run as JSON (page ledger + memory + concurrency).
fn kv_json(st: &qurl::coordinator::SchedulerStats, page: usize,
           pos_bytes: f64, slots: usize) -> Json {
    Json::obj(vec![
        ("kv_pages_high_water", Json::num(st.kv_pages_high_water as f64)),
        ("peak_kv_bytes",
         Json::num(st.kv_pages_high_water as f64 * page as f64 * pos_bytes)),
        ("kv_pages_allocated", Json::num(st.kv_pages_allocated as f64)),
        ("kv_pages_shared", Json::num(st.kv_pages_shared as f64)),
        ("kv_pages_cow", Json::num(st.kv_pages_cow as f64)),
        ("effective_concurrency",
         Json::num(st.mean_occupancy() * slots as f64)),
        ("tokens_per_s", Json::num(st.tokens_per_s())),
    ])
}

/// One copy-tax run as JSON (decode throughput + per-tick staging bytes).
fn tax_json(st: &qurl::coordinator::SchedulerStats) -> Json {
    let ticks = st.decode_calls.max(1) as f64;
    Json::obj(vec![
        ("decode_calls", Json::num(st.decode_calls as f64)),
        ("decode_steps_per_s",
         Json::num(st.decode_calls as f64 / st.wall_s.max(1e-9))),
        ("tokens_per_s", Json::num(st.tokens_per_s())),
        ("prefill_rows", Json::num(st.prefill_rows as f64)),
        ("bytes_h2d_per_tick", Json::num(st.bytes_h2d as f64 / ticks)),
        ("bytes_d2h_per_tick", Json::num(st.bytes_d2h as f64 / ticks)),
    ])
}
