//! Fig. 8 reproduction: INT8 rollout acceleration.
//!
//! Two parts:
//! 1. the roofline simulator sweep over {7B, 14B, 32B} x {A6000, A100,
//!    H100} — the paper's actual grid (this testbed has no GPUs; DESIGN.md
//!    §2 argues the model preserves the figure's shape);
//! 2. measured decode throughput of THIS testbed's artifacts (bf16/int8/
//!    fp8 generate waves on CPU) — honest numbers for the interpret-mode
//!    Pallas path, not a GPU proxy.

use qurl::benchkit as bk;
use qurl::perfmodel::{self, roofline, DecodeConfig, Precision};
use qurl::runtime::QuantMode;
use qurl::tasks::{encode_batch, Suite, Tokenizer};
use qurl::util::timer::{bench, print_table};

fn main() -> anyhow::Result<()> {
    // ---- part 1: roofline grid (the paper's figure) -----------------------
    let cfg = DecodeConfig::default();
    let mut rows = Vec::new();
    for scale in roofline::ALL_SCALES {
        for gpu in perfmodel::ALL_GPUS {
            let bf16 = perfmodel::decode_throughput(gpu, scale, Precision::Bf16, &cfg);
            let int8 = perfmodel::decode_throughput(gpu, scale, Precision::Int8, &cfg);
            rows.push(vec![
                scale.name().to_string(),
                gpu.spec().name.to_string(),
                format!("{bf16:.2}"),
                format!("{int8:.2}"),
                format!("+{:.0}%", (int8 / bf16 - 1.0) * 100.0),
            ]);
        }
    }
    print_table(
        &format!("Fig. 8 analog: roofline decode throughput (queries/s, \
                  batch={}, ctx={}, gen={})", cfg.batch, cfg.ctx, cfg.gen_len),
        &["model", "gpu", "bf16 q/s", "int8 q/s", "speedup"], &rows);
    println!("paper reference: 7B +20-30%, 32B +70% (A100) / +90% (H100); \
              larger models gain more.");

    // batch sensitivity (why bigger models gain more: weight traffic
    // dominates the fp16 KV as params grow)
    let mut rows = Vec::new();
    for batch in [8, 32, 64, 128] {
        let c = DecodeConfig { batch, ..cfg };
        let s7 = perfmodel::speedup(perfmodel::Gpu::A100, roofline::ModelScale::B7,
                                    Precision::Int8, &c);
        let s32 = perfmodel::speedup(perfmodel::Gpu::A100, roofline::ModelScale::B32,
                                     Precision::Int8, &c);
        rows.push(vec![batch.to_string(), format!("{:.0}%", (s7 - 1.0) * 100.0),
                       format!("{:.0}%", (s32 - 1.0) * 100.0)]);
    }
    print_table("speedup vs batch (A100)", &["batch", "7B", "32B"], &rows);

    // ---- part 2: measured CPU decode of the actual artifacts --------------
    let (rt, base) = bk::setup()?;
    let man = rt.manifest().clone();
    let (b, s) = (man.rollout_batch, man.max_seq);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let probs = suite.test_set(5, 11);
    let refs: Vec<&qurl::tasks::Problem> =
        probs.iter().take(b).map(|(_, p)| p).collect();
    let (tokens, lens) = encode_batch(&tk, &refs, b, s, man.max_prompt);
    let mut rows = Vec::new();
    for mode in [QuantMode::Bf16, QuantMode::Int8, QuantMode::Fp8] {
        let w = rt.engine_weights(mode, &base.params)?;
        let mut seed = 0i32;
        let _ = rt.generate(&w, &tokens, &lens, 0, 1.0, 1.0)?; // compile+warm
        let mut toks = 0f64;
        let stat = bench(&format!("generate_{}", mode.tag()), 0, 2, 10.0, || {
            seed += 1;
            let out = rt.generate(&w, &tokens, &lens, seed, 1.0, 1.0).unwrap();
            toks += out.mask.iter().sum::<f32>() as f64;
        });
        rows.push(vec![
            mode.tag().to_string(),
            format!("{:.2}", stat.mean_s),
            format!("{:.0}", toks / (stat.mean_s * stat.iters as f64)),
        ]);
    }
    print_table("measured CPU-testbed rollout (interpret-mode Pallas; NOT a \
                 GPU proxy)",
                &["engine", "s/wave", "tok/s"], &rows);
    println!("\nNote: interpret-mode INT8 runs extra quantize ops on CPU \
              with no INT8 hardware path, so CPU wall-clock does not show \
              the GPU speedup; the roofline sweep above carries Fig. 8's \
              claim. See DESIGN.md §Hardware-Adaptation.");
    Ok(())
}
