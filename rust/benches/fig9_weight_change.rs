//! Fig. 9 (Appendix A) reproduction: normalized weight update (Eq. 13) vs
//! normalized weight quantization error (Eq. 14) over RL steps, measured
//! every `analyze_every` steps like the paper's 16-step intervals.
//!
//! Expected shape: quant error orders of magnitude above the per-interval
//! update, especially early; UAQ shrinks the error by ~1/s^2 and raises
//! the effective update.

use qurl::benchkit as bk;
use qurl::config;
use qurl::runtime::QuantMode;
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(8, 160);
    let mut rows = Vec::new();
    let mut rq_rows = Vec::new();
    for (label, uaq) in [("s=1.0", 1.0f32), ("s=1.5", 1.5f32)] {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.rollout_mode = QuantMode::Int8;
        cfg.uaq_scale = uaq;
        cfg.analyze_every = 4;
        cfg.eval_every = 0;
        let run = format!("fig9_{label}");
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("== Fig 9 series ({label}) ==");
        bk::print_curve(label, &tr.rec, "norm_weight_update");
        bk::print_curve(label, &tr.rec, "norm_quant_error");
        tr.rec.write_csv(&bk::results_dir(),
                         &["norm_weight_update", "norm_quant_error",
                           "int8_code_change_frac"])?;
        let upd = tr.rec.tail_mean("norm_weight_update", 6).unwrap_or(0.0);
        let err = tr.rec.tail_mean("norm_quant_error", 6).unwrap_or(0.0);
        let codes = tr.rec.tail_mean("int8_code_change_frac", 6).unwrap_or(0.0);
        rows.push(vec![label.to_string(), format!("{upd:.3e}"),
                       format!("{err:.3e}"),
                       format!("{:.1}", err / upd.max(1e-18)),
                       format!("{codes:.4}")]);
        // delta-requantization companion: how much of the network the WHOLE
        // run actually moved through the int8 grid, tensor-granular — the
        // refresh cost a delta requant pays vs the full rebuild
        let p0 = if (uaq - 1.0).abs() > 1e-6 {
            rt.uaq_scale(&base.params, uaq)?
        } else {
            base.params.clone()
        };
        let (w0, _) = rt.engine_weights_delta(QuantMode::Int8, &p0, None)?;
        let (w1, rep) =
            rt.engine_weights_delta(QuantMode::Int8, &tr.ps.params,
                                    Some(&w0))?;
        let swap: u64 = w0
            .host_tensors()
            .iter()
            .zip(w1.host_tensors())
            .filter(|(o, n)| !o.same_payload(n))
            .map(|(_, n)| n.byte_len())
            .sum();
        rq_rows.push(vec![
            label.to_string(),
            format!("{}/{}", rep.tensors_changed, rep.total()),
            format!("{:.3}", rep.changed_fraction()),
            format!("{:.0}", swap as f64 / 1e3),
            format!("{:.0}", w1.byte_len() as f64 / 1e3),
        ]);
    }
    print_table("Fig. 9 analog: update vs quantization noise (tail means)",
                &["uaq", "norm update (Eq.13)", "norm quant err (Eq.14)",
                  "err/upd", "int8 codes changed"], &rows);
    print_table(&format!("delta requantization over the run ({steps} RL \
                          steps)"),
                &["uaq", "tensors changed", "frac", "swap h2d KB",
                  "full restage KB"], &rq_rows);
    println!("\nexpected: err/upd >> 1 at s=1 (updates masked); s=1.5 cuts \
              the ratio ~s^2 = 2.25x and more codes change per interval.  \
              The requant table prices the same masking at refresh time: \
              only tensors whose quantized payload moved re-stage.");
    Ok(())
}
