//! Fig. 9 (Appendix A) reproduction: normalized weight update (Eq. 13) vs
//! normalized weight quantization error (Eq. 14) over RL steps, measured
//! every `analyze_every` steps like the paper's 16-step intervals.
//!
//! Expected shape: quant error orders of magnitude above the per-interval
//! update, especially early; UAQ shrinks the error by ~1/s^2 and raises
//! the effective update.

use qurl::benchkit as bk;
use qurl::config;
use qurl::runtime::QuantMode;
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(8, 160);
    let mut rows = Vec::new();
    for (label, uaq) in [("s=1.0", 1.0f32), ("s=1.5", 1.5f32)] {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.rollout_mode = QuantMode::Int8;
        cfg.uaq_scale = uaq;
        cfg.analyze_every = 4;
        cfg.eval_every = 0;
        let run = format!("fig9_{label}");
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("== Fig 9 series ({label}) ==");
        bk::print_curve(label, &tr.rec, "norm_weight_update");
        bk::print_curve(label, &tr.rec, "norm_quant_error");
        tr.rec.write_csv(&bk::results_dir(),
                         &["norm_weight_update", "norm_quant_error",
                           "int8_code_change_frac"])?;
        let upd = tr.rec.tail_mean("norm_weight_update", 6).unwrap_or(0.0);
        let err = tr.rec.tail_mean("norm_quant_error", 6).unwrap_or(0.0);
        let codes = tr.rec.tail_mean("int8_code_change_frac", 6).unwrap_or(0.0);
        rows.push(vec![label.to_string(), format!("{upd:.3e}"),
                       format!("{err:.3e}"),
                       format!("{:.1}", err / upd.max(1e-18)),
                       format!("{codes:.4}")]);
    }
    print_table("Fig. 9 analog: update vs quantization noise (tail means)",
                &["uaq", "norm update (Eq.13)", "norm quant err (Eq.14)",
                  "err/upd", "int8 codes changed"], &rows);
    println!("\nexpected: err/upd >> 1 at s=1 (updates masked); s=1.5 cuts \
              the ratio ~s^2 = 2.25x and more codes change per interval.");
    Ok(())
}
