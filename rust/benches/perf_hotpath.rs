//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf source of truth):
//! steady-state latency of every artifact on the training path, the
//! serving scheduler's throughput, and the host-side (non-XLA) overhead
//! share — the "coordinator is not the bottleneck" check.

use std::time::Instant;

use qurl::benchkit as bk;
use qurl::coordinator::{RolloutRequest, Scheduler, StepEngine};
use qurl::runtime::{QuantMode, TrainBatch};
use qurl::tasks::{encode_batch, Suite, Tokenizer};
use qurl::util::timer::{bench, print_table};

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let man = rt.manifest().clone();
    let (b, s) = (man.rollout_batch, man.max_seq);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let probs = suite.test_set(5, 11);
    let refs: Vec<&qurl::tasks::Problem> =
        probs.iter().take(b).map(|(_, p)| p).collect();
    let (tokens, lens) = encode_batch(&tk, &refs, b, s, man.max_prompt);

    let mut rows = Vec::new();

    // quantization (per RL step when requantize_every=1)
    for mode in [QuantMode::Int8, QuantMode::Fp8] {
        let _ = rt.engine_weights(mode, &base.params)?; // compile
        let stat = bench(&format!("quantize_{}", mode.tag()), 1, 5, 3.0, || {
            let _ = rt.engine_weights(mode, &base.params).unwrap();
        });
        rows.push(vec![format!("quantize_{}", mode.tag()),
                       format!("{:.1}", stat.mean_s * 1e3), "ms".into()]);
    }

    // rollout generate (the paper's 70% phase)
    for mode in [QuantMode::Bf16, QuantMode::Int8, QuantMode::Fp8] {
        let w = rt.engine_weights(mode, &base.params)?;
        let _ = rt.generate(&w, &tokens, &lens, 0, 1.0, 1.0)?;
        let mut seed = 0;
        let stat = bench(&format!("generate_{}", mode.tag()), 0, 2, 8.0, || {
            seed += 1;
            let _ = rt.generate(&w, &tokens, &lens, seed, 1.0, 1.0).unwrap();
        });
        rows.push(vec![format!("generate_{} (B={b})", mode.tag()),
                       format!("{:.1}", stat.mean_s * 1e3), "ms".into()]);
    }

    // scoring + train step
    let _ = rt.score_bf16(&base.params, &tokens)?;
    let stat = bench("score_bf16", 0, 4, 4.0, || {
        let _ = rt.score_bf16(&base.params, &tokens).unwrap();
    });
    rows.push(vec!["score_bf16".into(), format!("{:.1}", stat.mean_s * 1e3),
                   "ms".into()]);

    let sc = rt.score_bf16(&base.params, &tokens)?;
    let batch = TrainBatch {
        tokens: tokens.clone(),
        mask: vec![1.0; b * s],
        adv: vec![0.1; b * s],
        lp_behav: sc.logprob.clone(),
        lp_prox: sc.logprob.clone(),
        lp_ref: sc.logprob.clone(),
        returns: vec![0.0; b * s],
        old_values: vec![0.0; b * s],
    };
    let obj = qurl::rl::Objective::default();
    let flags = obj.to_flags(&man.flags);
    let mut ps = qurl::runtime::ParamStore::new(&man, base.params.clone());
    let _ = rt.train_step(&mut ps, &batch, &flags)?;
    let stat = bench("train_step", 0, 3, 6.0, || {
        let _ = rt.train_step(&mut ps, &batch, &flags).unwrap();
    });
    rows.push(vec!["train_step".into(), format!("{:.1}", stat.mean_s * 1e3),
                   "ms".into()]);

    print_table("artifact steady-state latency", &["op", "mean", "unit"],
                &rows);

    // ---- end-to-end RL step decomposition ---------------------------------
    rt.store.reset_stats();
    let mut cfg = qurl::config::deepscaler_grpo();
    cfg.steps = 2;
    cfg.eval_every = 0;
    let rec = qurl::metrics::Recorder::ephemeral("perf");
    let mut tr = qurl::rl::Trainer::new(&rt, cfg, base.clone(), rec)?;
    let t0 = Instant::now();
    tr.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut rows = Vec::new();
    let mut xla_total = 0.0;
    for (name, st) in rt.store.stats() {
        xla_total += st.secs;
        rows.push(vec![name, st.calls.to_string(), format!("{:.2}", st.secs),
                       format!("{:.1}", st.bytes_h2d as f64 / 1e6),
                       format!("{:.1}", st.bytes_d2h as f64 / 1e6)]);
    }
    rows.push(vec!["TOTAL XLA".into(), String::new(),
                   format!("{xla_total:.2}"), String::new(), String::new()]);
    rows.push(vec!["host (L3) overhead".into(), String::new(),
                   format!("{:.2} ({:.1}%)", wall - xla_total,
                           (wall - xla_total) / wall * 100.0),
                   String::new(), String::new()]);
    print_table(&format!("RL-step decomposition (3 steps, {wall:.2}s wall)"),
                &["artifact", "calls", "seconds", "MB h2d", "MB d2h"],
                &rows);

    // ---- serving scheduler throughput -------------------------------------
    let w = rt.engine_weights(QuantMode::Int8, &base.params)?;
    let mut engine = StepEngine::new(&rt, w);
    let mut sched = Scheduler::new(&mut engine, man.max_seq, man.eos_id);
    let mut sampler = suite.train_sampler(1);
    for id in 0..16u64 {
        let (_, prob) = sampler.next();
        sched.submit(RolloutRequest {
            id,
            prompt: std::sync::Arc::new(tk.encode_prompt(&prob.prompt)),
            max_new: 16,
            temperature: 1.0,
            top_p: 1.0,
            seed: id,
        });
    }
    let results = sched.run_to_completion()?;
    println!("\nscheduler: {} reqs, {:.1} tok/s, occupancy {:.2}, \
              {} decode calls",
             results.len(), sched.stats.tokens_per_s(),
             sched.stats.mean_occupancy(), sched.stats.decode_calls);
    Ok(())
}
