//! Table 1 + Fig. 6/7 reproduction: PPO on the GSM8K analog.
//!
//! Paper rows: RL/BF16, {RL, FlashRL(TIS), QuRL(ACR)} x {INT8, FP8},
//! final-checkpoint greedy accuracy, plus the convergence curves.
//! UAQ is off (the paper disables it at this experiment's high lr).
//!
//! Expected ordering: naive < TIS < ACR <= BF16 within each precision;
//! naive-FP8 in the paper scores 0.0 (collapse).

use qurl::benchkit as bk;
use qurl::config;
use qurl::rl::{eval as rleval, ObjectiveKind};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer};
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(5, 120);
    let n_eval = bk::env_usize("QURL_EVAL_N", 18);
    let variants: [(&str, QuantMode, ObjectiveKind); 7] = [
        ("RL bf16", QuantMode::Bf16, ObjectiveKind::OnPolicy),
        ("RL int8 (naive)", QuantMode::Int8, ObjectiveKind::NaiveQuant),
        ("FlashRL int8 (TIS)", QuantMode::Int8, ObjectiveKind::Tis),
        ("QuRL int8 (ACR)", QuantMode::Int8, ObjectiveKind::Acr),
        ("RL fp8 (naive)", QuantMode::Fp8, ObjectiveKind::NaiveQuant),
        ("FlashRL fp8 (TIS)", QuantMode::Fp8, ObjectiveKind::Tis),
        ("QuRL fp8 (ACR)", QuantMode::Fp8, ObjectiveKind::Acr),
    ];
    let tk = Tokenizer::new();
    let suite = Suite::by_name("gsm8k").unwrap();
    let mut rows = Vec::new();
    for (label, mode, kind) in variants {
        let mut cfg = config::gsm8k_ppo();
        cfg.steps = steps;
        cfg.rollout_mode = mode;
        cfg.objective.kind = kind;
        cfg.eval_every = (steps / 8).max(1);
        let run = format!("table1_{}_{}", mode.tag(), kind.name());
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        // final greedy accuracy with a BF16 eval engine (paper evaluates
        // the trained fp checkpoint)
        let w = rt.engine_weights(QuantMode::Bf16, &tr.ps.params)?;
        let acc = rleval::greedy_accuracy(&rt, &w, &tk, &suite, 1234, n_eval)?;
        tr.rec.write_csv(&bk::results_dir(), &["reward", "eval_acc"])?;
        println!("== Fig 6/7 convergence: {label} ==");
        bk::print_curve(label, &tr.rec, "reward");
        rows.push(vec![label.to_string(), mode.tag().to_string(),
                       format!("{:.2}", acc * 100.0),
                       format!("{reward:.3}")]);
    }
    print_table("Table 1 analog: GSM8K accuracy (greedy, %)",
                &["method", "bitwidth", "accuracy", "train reward"], &rows);
    println!("\npaper reference (0.5B, 435 steps): BF16 55.35 | INT8 naive \
              48.78, TIS 51.40, ACR 53.55 | FP8 naive 0.0, TIS 53.60, \
              ACR 54.28");
    Ok(())
}
