//! Table 2 reproduction: DAPO on the AIME analog — Avg@1 (greedy) and
//! Avg@K sampled accuracy, isolating the UAQ contribution.
//!
//! Paper rows: RL/BF16; {RL naive, FlashRL, QuRL w/o UAQ, QuRL w/ UAQ} on
//! INT8 (FP8 optional via QURL_FP8=1).  Expected ordering:
//! naive collapses; FlashRL < QuRL w/o UAQ <= QuRL w/ UAQ ~= BF16.

use qurl::benchkit as bk;
use qurl::config;
use qurl::coordinator::StripePolicy;
use qurl::rl::{eval as rleval, ObjectiveKind, RolloutExec, RolloutPath};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer};
use qurl::util::timer::print_table;

struct Variant {
    label: &'static str,
    mode: QuantMode,
    kind: ObjectiveKind,
    uaq: f32,
}

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(5, 100);
    let k = bk::env_usize("QURL_EVAL_K", 4);
    let n_eval = bk::env_usize("QURL_EVAL_N", 12);
    let mut variants = vec![
        Variant { label: "RL", mode: QuantMode::Bf16,
                  kind: ObjectiveKind::OnPolicy, uaq: 1.0 },
        Variant { label: "RL (naive)", mode: QuantMode::Int8,
                  kind: ObjectiveKind::NaiveQuant, uaq: 1.0 },
        Variant { label: "FlashRL", mode: QuantMode::Int8,
                  kind: ObjectiveKind::Tis, uaq: 1.0 },
        Variant { label: "QuRL w/o UAQ", mode: QuantMode::Int8,
                  kind: ObjectiveKind::Acr, uaq: 1.0 },
        Variant { label: "QuRL w/ UAQ", mode: QuantMode::Int8,
                  kind: ObjectiveKind::Acr, uaq: 1.5 },
    ];
    if std::env::var("QURL_FP8").map(|v| v == "1").unwrap_or(false) {
        variants.push(Variant { label: "FlashRL fp8", mode: QuantMode::Fp8,
                                kind: ObjectiveKind::Tis, uaq: 1.0 });
        variants.push(Variant { label: "QuRL fp8 w/ UAQ", mode: QuantMode::Fp8,
                                kind: ObjectiveKind::Acr, uaq: 1.5 });
    }
    let tk = Tokenizer::new();
    let suite = Suite::by_name("aime").unwrap();
    let mut rows = Vec::new();
    for v in &variants {
        let mut cfg = config::dapo_aime();
        cfg.steps = steps;
        cfg.rollout_mode = v.mode;
        cfg.objective.kind = v.kind;
        cfg.uaq_scale = v.uaq;
        cfg.eval_every = 0;
        let run = format!("table2_{}_{}_uaq{}", v.mode.tag(), v.kind.name(),
                          v.uaq);
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        let w = rt.engine_weights(QuantMode::Bf16, &tr.ps.params)?;
        let avg1 = rleval::greedy_accuracy(&rt, &w, &tk, &suite, 77, n_eval)?;
        let avgk = rleval::avg_at_k(&rt, &w, &tk, &suite, 77, n_eval, k,
                                    1.0, 0.7)?;
        tr.rec.write_csv(&bk::results_dir(), &["reward"])?;
        bk::print_curve(v.label, &tr.rec, "reward");
        rows.push(vec![v.label.to_string(), v.mode.tag().to_string(),
                       format!("{:.2}", avg1 * 100.0),
                       format!("{:.2}", avgk * 100.0),
                       format!("{reward:.3}")]);
    }
    print_table(&format!("Table 2 analog: AIME accuracy (Avg@1 / Avg@{k}, %)"),
                &["method", "bitwidth", "Avg@1", &format!("Avg@{k}"),
                  "train reward"], &rows);
    println!("\npaper reference (7B, 200 steps, INT8): BF16 33.3/31.7 | \
              naive 0.0 | FlashRL 26.7/30.3 | QuRL w/o UAQ 33.3/30.6 | \
              QuRL w/ UAQ 33.3/31.3");

    // ---- DAPO rollout serving: in-flight pruning vs post-hoc filtering --
    // Same preset on the service path, with and without prune-as-you-
    // generate: cancelling reward-decided groups mid-flight recovers the
    // decode budget DAPO's dynamic sampling would otherwise discard after
    // the fact.  Counters are per-run sums of the sched_* Recorder rows.
    let sum_of = |tr: &qurl::rl::Trainer, key: &str| -> f64 {
        tr.rec.series(key).iter().map(|&(_, v)| v).sum()
    };
    let mut rows = Vec::new();
    for prune in [false, true] {
        let mut cfg = config::dapo_aime();
        cfg.steps = steps.min(4);
        cfg.rollout_path = RolloutPath::Scheduler;
        cfg.prune_rollouts = prune;
        cfg.eval_every = 0;
        let run = format!("table2_sched_prune_{prune}");
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        rows.push(vec![
            String::from(if prune { "prune in flight" } else
                         { "post-hoc filter" }),
            format!("{:.0}", sum_of(&tr, "sched_generated_tokens")),
            format!("{:.0}", sum_of(&tr, "sched_prefill_calls")),
            format!("{:.0}", sum_of(&tr, "sched_prefill_rows")),
            format!("{:.0}", sum_of(&tr, "sched_cancelled")),
            format!("{:.0}", sum_of(&tr, "sched_pruned_groups")),
            format!("{:.3}", tr.rec.last("dapo_efficiency").unwrap_or(0.0)),
        ]);
    }
    print_table("DAPO rollout serving (scheduler path): prune-as-you-\
                 generate vs post-hoc group filtering",
                &["policy", "decoded tokens", "prefill calls",
                  "prefill rows", "cancelled", "pruned groups",
                  "dapo efficiency"], &rows);

    // ---- fused vs rollout service, exec backend and stripe policy -------
    // The ROADMAP gap this closes: the DAPO table compared fused waves
    // only.  Same preset per row; thread count = engine replicas when the
    // executor is threaded, 1 when inline or fused.  Rewards at temp>0
    // differ across paths by sampling-stream construction, so the columns
    // to compare are serving counters and wall-clock, not accuracy.
    let serving: [(&str, RolloutPath, usize, RolloutExec, StripePolicy); 4] = [
        ("fused waves", RolloutPath::Fused, 1,
         RolloutExec::Inline, StripePolicy::RoundRobin),
        ("service inline rr", RolloutPath::Scheduler, 2,
         RolloutExec::Inline, StripePolicy::RoundRobin),
        ("service threaded rr", RolloutPath::Scheduler, 2,
         RolloutExec::Threaded, StripePolicy::RoundRobin),
        ("service threaded least-loaded", RolloutPath::Scheduler, 2,
         RolloutExec::Threaded, StripePolicy::LeastLoaded),
    ];
    let mut rows = Vec::new();
    for (label, path, engines, exec, stripe) in serving {
        let mut cfg = config::dapo_aime();
        cfg.steps = steps.min(4);
        cfg.rollout_path = path;
        cfg.rollout_engines = engines;
        cfg.rollout_exec = exec;
        cfg.rollout_stripe = stripe;
        cfg.eval_every = 0;
        let run = format!("table2_serve_{}_{}_{}", path.name(), exec.name(),
                          stripe.name());
        let t0 = std::time::Instant::now();
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        let wall = t0.elapsed().as_secs_f64();
        let threads = if exec == RolloutExec::Threaded { engines } else { 1 };
        rows.push(vec![
            label.to_string(),
            format!("{threads}"),
            stripe.name().to_string(),
            format!("{wall:.1}"),
            format!("{:.0}", sum_of(&tr, "sched_generated_tokens")),
            format!("{:.0}", sum_of(&tr, "sched_decode_calls")),
            // per-tick copy tax: resident inputs keep this at control-
            // tensor size between requantizations (fused path logs no
            // sched rows)
            match bk::h2d_per_decode(&tr) {
                Some(b) => format!("{:.1}", b / 1e3),
                None => "-".into(),
            },
            format!("{:.0}",
                    tr.rec.last("sched_weight_epoch").unwrap_or(0.0)),
            format!("{reward:.3}"),
        ]);
    }
    print_table("DAPO serving paths: fused vs rollout service (exec \
                 backend x stripe policy)",
                &["path", "threads", "stripe", "wall s", "sched tokens",
                  "sched decode calls", "h2d KB/tick", "weight epoch",
                  "train reward"],
                &rows);
    Ok(())
}
