//! Table 3 + Fig. 10 reproduction: GRPO on the DeepScaleR analog — per-task
//! Avg@K across the 6-family suite, plus long-horizon test-accuracy curves.
//!
//! Paper rows: Base, RL/BF16, {RL, FlashRL, QuRL w/o UAQ, QuRL w/ UAQ} on
//! INT8.  Expected ordering: Base < RL int8 < FlashRL < QuRL w/o UAQ <
//! QuRL w/ UAQ <= RL bf16, per family and on average.

use qurl::benchkit as bk;
use qurl::config;
use qurl::coordinator::StripePolicy;
use qurl::rl::{eval as rleval, ObjectiveKind, RolloutExec, RolloutPath};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer, ALL_FAMILIES};
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(6, 160);
    let k = bk::env_usize("QURL_EVAL_K", 2);
    let n_eval = bk::env_usize("QURL_EVAL_N", 5);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();

    let header: Vec<String> = std::iter::once("method".to_string())
        .chain(std::iter::once("bits".to_string()))
        .chain(ALL_FAMILIES.iter().map(|f| f.name().to_string()))
        .chain(std::iter::once("Avg".to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rows: Vec<Vec<String>> = Vec::new();

    let eval_row = |rt: &qurl::runtime::Runtime, params: &[f32],
                    label: &str, bits: &str|
                    -> anyhow::Result<Vec<String>> {
        let w = rt.engine_weights(QuantMode::Bf16, params)?;
        let per = rleval::per_family_accuracy(rt, &w, &tk, &suite, 99,
                                              n_eval, k, 0.6, 0.95)?;
        let mut row = vec![label.to_string(), bits.to_string()];
        let mut total = 0.0;
        for fam in ALL_FAMILIES {
            let (acc, _) = per[fam.name()];
            row.push(format!("{:.1}", acc * 100.0));
            total += acc;
        }
        row.push(format!("{:.1}", total / ALL_FAMILIES.len() as f64 * 100.0));
        Ok(row)
    };

    // Base model row
    rows.push(eval_row(&rt, &base.params, "Base", "bf16")?);

    let variants: [(&str, QuantMode, ObjectiveKind, f32); 5] = [
        ("RL", QuantMode::Bf16, ObjectiveKind::OnPolicy, 1.0),
        ("RL", QuantMode::Int8, ObjectiveKind::NaiveQuant, 1.0),
        ("FlashRL", QuantMode::Int8, ObjectiveKind::Tis, 1.0),
        ("QuRL w/o UAQ", QuantMode::Int8, ObjectiveKind::Acr, 1.0),
        ("QuRL w/ UAQ", QuantMode::Int8, ObjectiveKind::Acr, 1.5),
    ];
    for (label, mode, kind, uaq) in variants {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.rollout_mode = mode;
        cfg.objective.kind = kind;
        cfg.uaq_scale = uaq;
        cfg.eval_every = (steps / 2).max(1); // Fig. 10 test-acc curve
        let run = format!("table3_{}_{}_uaq{uaq}", mode.tag(), kind.name());
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("== Fig 10 test-accuracy curve: {label} {} ==", mode.tag());
        bk::print_curve(label, &tr.rec, "eval_acc");
        tr.rec.write_csv(&bk::results_dir(), &["reward", "eval_acc"])?;
        rows.push(eval_row(&rt, &tr.ps.params, label, mode.tag())?);
    }

    print_table(&format!("Table 3 analog: DeepScaleR Avg@{k} per family (%)"),
                &header_refs, &rows);
    println!("\npaper reference (1.5B, avg): Base 48.8 | RL bf16 56.4 | RL \
              int8 52.3 | FlashRL 53.8 | QuRL w/o UAQ 54.8 | QuRL w/ UAQ \
              55.5");

    // ---- fused vs rollout service on the GRPO preset --------------------
    // Closes the ROADMAP gap "DAPO/DeepScaleR tables compare fused waves
    // only": the same short GRPO run served by fused waves and by the
    // rollout service (inline and threaded executor, rr and least-loaded
    // placement).  Thread count = engine replicas when threaded, else 1.
    // Greedy parity guarantees identical learning at temp 0; at the
    // preset's temp the comparison is serving counters + wall-clock.
    let sum_of = |tr: &qurl::rl::Trainer, key: &str| -> f64 {
        tr.rec.series(key).iter().map(|&(_, v)| v).sum()
    };
    let serving: [(&str, RolloutPath, usize, RolloutExec, StripePolicy); 3] = [
        ("fused waves", RolloutPath::Fused, 1,
         RolloutExec::Inline, StripePolicy::RoundRobin),
        ("service inline rr", RolloutPath::Scheduler, 2,
         RolloutExec::Inline, StripePolicy::RoundRobin),
        ("service threaded least-loaded", RolloutPath::Scheduler, 2,
         RolloutExec::Threaded, StripePolicy::LeastLoaded),
    ];
    let mut rows = Vec::new();
    for (label, path, engines, exec, stripe) in serving {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps.min(4);
        cfg.rollout_path = path;
        cfg.rollout_engines = engines;
        cfg.rollout_exec = exec;
        cfg.rollout_stripe = stripe;
        cfg.eval_every = 0;
        cfg.analyze_every = 0;
        let run = format!("table3_serve_{}_{}_{}", path.name(), exec.name(),
                          stripe.name());
        let t0 = std::time::Instant::now();
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        let wall = t0.elapsed().as_secs_f64();
        let threads = if exec == RolloutExec::Threaded { engines } else { 1 };
        rows.push(vec![
            label.to_string(),
            format!("{threads}"),
            stripe.name().to_string(),
            format!("{wall:.1}"),
            format!("{:.0}", sum_of(&tr, "sched_generated_tokens")),
            format!("{:.0}", sum_of(&tr, "sched_decode_calls")),
            // per-tick copy tax (see table2 for the column's definition)
            match bk::h2d_per_decode(&tr) {
                Some(b) => format!("{:.1}", b / 1e3),
                None => "-".into(),
            },
            format!("{reward:.3}"),
        ]);
    }
    print_table("DeepScaleR serving paths: fused vs rollout service (exec \
                 backend x stripe policy)",
                &["path", "threads", "stripe", "wall s", "sched tokens",
                  "sched decode calls", "h2d KB/tick", "train reward"],
                &rows);
    Ok(())
}
