//! Table 3 + Fig. 10 reproduction: GRPO on the DeepScaleR analog — per-task
//! Avg@K across the 6-family suite, plus long-horizon test-accuracy curves.
//!
//! Paper rows: Base, RL/BF16, {RL, FlashRL, QuRL w/o UAQ, QuRL w/ UAQ} on
//! INT8.  Expected ordering: Base < RL int8 < FlashRL < QuRL w/o UAQ <
//! QuRL w/ UAQ <= RL bf16, per family and on average.

use qurl::benchkit as bk;
use qurl::config;
use qurl::rl::{eval as rleval, ObjectiveKind};
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer, ALL_FAMILIES};
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(6, 160);
    let k = bk::env_usize("QURL_EVAL_K", 2);
    let n_eval = bk::env_usize("QURL_EVAL_N", 5);
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();

    let header: Vec<String> = std::iter::once("method".to_string())
        .chain(std::iter::once("bits".to_string()))
        .chain(ALL_FAMILIES.iter().map(|f| f.name().to_string()))
        .chain(std::iter::once("Avg".to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rows: Vec<Vec<String>> = Vec::new();

    let eval_row = |rt: &qurl::runtime::Runtime, params: &[f32],
                    label: &str, bits: &str|
                    -> anyhow::Result<Vec<String>> {
        let w = rt.engine_weights(QuantMode::Bf16, params)?;
        let per = rleval::per_family_accuracy(rt, &w, &tk, &suite, 99,
                                              n_eval, k, 0.6, 0.95)?;
        let mut row = vec![label.to_string(), bits.to_string()];
        let mut total = 0.0;
        for fam in ALL_FAMILIES {
            let (acc, _) = per[fam.name()];
            row.push(format!("{:.1}", acc * 100.0));
            total += acc;
        }
        row.push(format!("{:.1}", total / ALL_FAMILIES.len() as f64 * 100.0));
        Ok(row)
    };

    // Base model row
    rows.push(eval_row(&rt, &base.params, "Base", "bf16")?);

    let variants: [(&str, QuantMode, ObjectiveKind, f32); 5] = [
        ("RL", QuantMode::Bf16, ObjectiveKind::OnPolicy, 1.0),
        ("RL", QuantMode::Int8, ObjectiveKind::NaiveQuant, 1.0),
        ("FlashRL", QuantMode::Int8, ObjectiveKind::Tis, 1.0),
        ("QuRL w/o UAQ", QuantMode::Int8, ObjectiveKind::Acr, 1.0),
        ("QuRL w/ UAQ", QuantMode::Int8, ObjectiveKind::Acr, 1.5),
    ];
    for (label, mode, kind, uaq) in variants {
        let mut cfg = config::deepscaler_grpo();
        cfg.steps = steps;
        cfg.rollout_mode = mode;
        cfg.objective.kind = kind;
        cfg.uaq_scale = uaq;
        cfg.eval_every = (steps / 2).max(1); // Fig. 10 test-acc curve
        let run = format!("table3_{}_{}_uaq{uaq}", mode.tag(), kind.name());
        let (tr, _) = bk::run_variant(&rt, &base, cfg, &run)?;
        println!("== Fig 10 test-accuracy curve: {label} {} ==", mode.tag());
        bk::print_curve(label, &tr.rec, "eval_acc");
        tr.rec.write_csv(&bk::results_dir(), &["reward", "eval_acc"])?;
        rows.push(eval_row(&rt, &tr.ps.params, label, mode.tag())?);
    }

    print_table(&format!("Table 3 analog: DeepScaleR Avg@{k} per family (%)"),
                &header_refs, &rows);
    println!("\npaper reference (1.5B, avg): Base 48.8 | RL bf16 56.4 | RL \
              int8 52.3 | FlashRL 53.8 | QuRL w/o UAQ 54.8 | QuRL w/ UAQ \
              55.5");
    Ok(())
}
