//! Table 4 reproduction: UAQ scale ablation vs learning-rate scaling.
//!
//! Paper: DAPO INT8, comparing s in {1, 1.5, 2} at lr=1e-6 against lr in
//! {1.5x, 2x} at s=1.  Expected shape: s=1.5 best; s=2 and raw lr scaling
//! overshoot (less stable RL, lower accuracy).

use qurl::benchkit as bk;
use qurl::config;
use qurl::rl::eval as rleval;
use qurl::runtime::QuantMode;
use qurl::tasks::{Suite, Tokenizer};
use qurl::util::timer::print_table;

fn main() -> anyhow::Result<()> {
    let (rt, base) = bk::setup()?;
    let steps = bk::bench_steps(5, 100);
    let k = bk::env_usize("QURL_EVAL_K", 4);
    let n_eval = bk::env_usize("QURL_EVAL_N", 12);
    let base_lr = config::dapo_aime().objective.lr;
    let variants: [(&str, f32, f32); 5] = [
        ("s=1.0, lr=1x", 1.0, 1.0),
        ("s=1.5, lr=1x", 1.5, 1.0),
        ("s=2.0, lr=1x", 2.0, 1.0),
        ("s=1.0, lr=1.5x", 1.0, 1.5),
        ("s=1.0, lr=2x", 1.0, 2.0),
    ];
    let tk = Tokenizer::new();
    let suite = Suite::by_name("aime").unwrap();
    let mut rows = Vec::new();
    for (label, s, lr_mult) in variants {
        let mut cfg = config::dapo_aime();
        cfg.steps = steps;
        cfg.rollout_mode = QuantMode::Int8;
        cfg.uaq_scale = s;
        cfg.objective.lr = base_lr * lr_mult;
        cfg.eval_every = 0;
        let run = format!("table4_s{s}_lr{lr_mult}");
        let (tr, reward) = bk::run_variant(&rt, &base, cfg, &run)?;
        let w = rt.engine_weights(QuantMode::Bf16, &tr.ps.params)?;
        let avgk = rleval::avg_at_k(&rt, &w, &tk, &suite, 77, n_eval, k,
                                    1.0, 0.7)?;
        let clip = tr.rec.tail_mean("clip_frac", 8).unwrap_or(0.0);
        rows.push(vec![label.to_string(),
                       format!("{:.2}", avgk * 100.0),
                       format!("{reward:.3}"),
                       format!("{clip:.4}")]);
    }
    print_table(&format!("Table 4 analog: UAQ scale vs lr (Avg@{k}, %)"),
                &["config", &format!("Avg@{k}"), "train reward",
                  "clip_frac"], &rows);
    println!("\npaper reference: s=1 30.6 | s=1.5 31.3 (best) | s=2 29.2 | \
              lr=1.5x 29.1 | lr=2x 26.7");
    Ok(())
}
