//! Hand-rolled Rust lexer for the repo lint passes (no dependencies, the
//! `util/json.rs` idiom).  It is not a full Rust front end — it produces
//! exactly what the passes in [`super::passes`] consume:
//!
//! * a token stream (identifiers, punctuation with maximal munch, string /
//!   char / number literals, lifetimes) with 1-based line numbers,
//! * the comments, separately (text + line) — annotation comments like
//!   `// lint: allow(panic, <reason>)` and the recorder's `//!` field
//!   catalog are read from here, never from the token stream,
//! * `#[cfg(test)]` item spans, so test-only code is exempt from the
//!   panic wall and Send-safety checks.
//!
//! The classic false-positive sources for textual Rust lints are handled
//! structurally: raw strings (`r"…"`, `r#"…"#`), nested block comments,
//! char literals vs. lifetimes, and multi-char operators (`::`, `=>`,
//! `..=`, compound assignment) lex as single tokens, so a `.unwrap()`
//! inside a string or comment can never trip a pass.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    /// string literal; `text` holds the raw content between the quotes
    /// (escape sequences unprocessed — the passes only match plain keys)
    Str,
    Char,
    Lifetime,
    Num,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line the token starts on
    pub line: u32,
}

#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-based line the comment starts on
    pub line: u32,
    /// full comment text including the `//` / `/*` markers
    pub text: String,
}

/// One lexed source file: tokens, comments, and `#[cfg(test)]` spans.
#[derive(Clone, Debug)]
pub struct LexedFile {
    /// path relative to the scanned source root, `/`-separated
    /// (e.g. `coordinator/scheduler.rs`)
    pub path: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// token-index ranges `[start, end)` covered by a `#[cfg(test)]` item
    test_spans: Vec<(usize, usize)>,
}

/// Multi-char punctuation, longest first (maximal munch).
const PUNCT3: [&str; 4] = ["<<=", ">>=", "..=", "..."];
const PUNCT2: [&str; 19] = ["::", "->", "=>", "==", "!=", "<=", ">=",
                            "&&", "||", "+=", "-=", "*=", "/=", "%=",
                            "^=", "&=", "|=", "<<", ".."];
// NB: ">>" is intentionally absent from PUNCT2 — nested generic closers
// (`Vec<Vec<u64>>`) are far more common in this codebase than shifts, and
// the angle-depth tracking in the passes wants two `>` tokens there.
// Shift expressions still lex fine as two adjacent `>` puncts.

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// If `b[i]` starts a raw string (`r"`, `r#"`, `br"`, …), return
/// `(open_quote_index, n_hashes)`.  `r#ident` (raw identifier) does not
/// match — the char after the hashes must be `"`.
fn raw_string_start(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if j < b.len() && b[j] == 'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != 'r' {
        return None;
    }
    let mut k = j + 1;
    let mut hashes = 0;
    while k < b.len() && b[k] == '#' {
        hashes += 1;
        k += 1;
    }
    if k < b.len() && b[k] == '"' {
        Some((k, hashes))
    } else {
        None
    }
}

impl LexedFile {
    pub fn lex(path: &str, src: &str) -> LexedFile {
        let b: Vec<char> = src.chars().collect();
        let mut toks: Vec<Tok> = Vec::new();
        let mut comments: Vec<Comment> = Vec::new();
        let mut i = 0usize;
        let mut line: u32 = 1;
        while i < b.len() {
            let c = b[i];
            if c == '\n' {
                line += 1;
                i += 1;
                continue;
            }
            if c.is_whitespace() {
                i += 1;
                continue;
            }
            // line comment (also doc comments `///` and `//!`)
            if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
                let start = i;
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
                comments.push(Comment {
                    line,
                    text: b[start..i].iter().collect(),
                });
                continue;
            }
            // block comment, nesting tracked (Rust block comments nest)
            if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                let start = i;
                let start_line = line;
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < b.len()
                        && b[i + 1] == '/'
                    {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == '\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                comments.push(Comment {
                    line: start_line,
                    text: b[start..i].iter().collect(),
                });
                continue;
            }
            // raw string: r"…", r#"…"#, br"…" — no escapes inside
            if let Some((open, hashes)) = raw_string_start(&b, i) {
                let tline = line;
                let mut j = open + 1;
                let mut end = b.len();
                while j < b.len() {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if b[j] == '"' {
                        let mut h = 0usize;
                        while h < hashes
                            && j + 1 + h < b.len()
                            && b[j + 1 + h] == '#'
                        {
                            h += 1;
                        }
                        if h == hashes {
                            end = j;
                            j += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: b[open + 1..end.min(b.len())].iter().collect(),
                    line: tline,
                });
                i = j;
                continue;
            }
            // plain (or byte) string literal with escapes
            if c == '"'
                || (c == 'b' && i + 1 < b.len() && b[i + 1] == '"')
            {
                if c == 'b' {
                    i += 1;
                }
                let tline = line;
                i += 1; // opening quote
                let mut text = String::new();
                while i < b.len() {
                    match b[i] {
                        '\\' if i + 1 < b.len() => {
                            if b[i + 1] == '\n' {
                                line += 1;
                            }
                            text.push(b[i]);
                            text.push(b[i + 1]);
                            i += 2;
                        }
                        '"' => break,
                        ch => {
                            if ch == '\n' {
                                line += 1;
                            }
                            text.push(ch);
                            i += 1;
                        }
                    }
                }
                i += 1; // closing quote
                toks.push(Tok { kind: TokKind::Str, text, line: tline });
                continue;
            }
            // lifetime vs. char literal
            if c == '\'' {
                let next_is_name = i + 1 < b.len()
                    && is_ident_start(b[i + 1]);
                let closes = i + 2 < b.len() && b[i + 2] == '\'';
                if next_is_name && !closes {
                    // lifetime or loop label: 'a, '_, 'outer
                    let mut j = i + 1;
                    while j < b.len() && is_ident_continue(b[j]) {
                        j += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: b[i..j].iter().collect(),
                        line,
                    });
                    i = j;
                    continue;
                }
                // char literal: 'x', '\n', '\'', '\u{1F600}'
                let tline = line;
                let mut j = i + 1;
                if j < b.len() && b[j] == '\\' {
                    j += 2;
                } else {
                    j += 1;
                }
                while j < b.len() && b[j] != '\'' {
                    j += 1;
                }
                let end = (j + 1).min(b.len());
                toks.push(Tok {
                    kind: TokKind::Char,
                    text: b[i..end].iter().collect(),
                    line: tline,
                });
                i = end;
                continue;
            }
            // number (good-enough: passes never inspect numeric values)
            if c.is_ascii_digit() {
                let start = i;
                i += 1;
                while i < b.len() {
                    let ch = b[i];
                    if is_ident_continue(ch) {
                        i += 1;
                    } else if ch == '.'
                        && i + 1 < b.len()
                        && b[i + 1].is_ascii_digit()
                    {
                        // 1.5 is one token; 0..n keeps the range punct
                        i += 1;
                    } else if (ch == '+' || ch == '-')
                        && matches!(b[i - 1], 'e' | 'E')
                        && i + 1 < b.len()
                        && b[i + 1].is_ascii_digit()
                    {
                        // exponent sign: 1e-6
                        i += 1;
                    } else {
                        break;
                    }
                }
                toks.push(Tok {
                    kind: TokKind::Num,
                    text: b[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // identifier / keyword
            if is_ident_start(c) {
                let start = i;
                while i < b.len() && is_ident_continue(b[i]) {
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Ident,
                    text: b[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // punctuation, longest match first
            let rest: String =
                b[i..b.len().min(i + 3)].iter().collect();
            let mut matched: Option<&str> = None;
            for p in PUNCT3 {
                if rest.starts_with(p) {
                    matched = Some(p);
                    break;
                }
            }
            if matched.is_none() {
                for p in PUNCT2 {
                    if rest.starts_with(p) {
                        matched = Some(p);
                        break;
                    }
                }
            }
            let text = match matched {
                Some(p) => p.to_string(),
                None => c.to_string(),
            };
            i += text.chars().count();
            toks.push(Tok { kind: TokKind::Punct, text, line });
        }
        let test_spans = compute_test_spans(&toks);
        LexedFile {
            path: path.to_string(),
            toks,
            comments,
            test_spans,
        }
    }

    /// Is token index `ti` inside a `#[cfg(test)]` item?
    pub fn in_test(&self, ti: usize) -> bool {
        self.test_spans
            .iter()
            .any(|&(s, e)| ti >= s && ti < e)
    }

    pub fn is_ident(&self, ti: usize, text: &str) -> bool {
        self.toks.get(ti).is_some_and(
            |t| t.kind == TokKind::Ident && t.text == text)
    }

    pub fn is_punct(&self, ti: usize, text: &str) -> bool {
        self.toks.get(ti).is_some_and(
            |t| t.kind == TokKind::Punct && t.text == text)
    }

    /// Index of the `}` / `)` / `]` matching the opener at `open` (which
    /// must be `{`, `(` or `[`), or `toks.len()` when unbalanced.
    pub fn matching_close(&self, open: usize) -> usize {
        let (o, c) = match self.toks[open].text.as_str() {
            "{" => ("{", "}"),
            "(" => ("(", ")"),
            "[" => ("[", "]"),
            _ => return self.toks.len(),
        };
        let mut depth = 0usize;
        let mut j = open;
        while j < self.toks.len() {
            let t = &self.toks[j];
            if t.kind == TokKind::Punct {
                if t.text == o {
                    depth += 1;
                } else if t.text == c {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
            }
            j += 1;
        }
        self.toks.len()
    }
}

/// Find `#[cfg(test)]` (and `#[cfg(all(test, …))]`) item spans.
/// `#[cfg(not(test))]` is NOT a test span — the `not` guard rejects it.
/// The span runs from the attribute's `#` through the end of the
/// annotated item: its matching `}` for brace items (`mod tests { … }`,
/// fns), or the terminating `;` for semicolon items (`use`, statics).
fn compute_test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if !(toks[i].kind == TokKind::Punct && toks[i].text == "#"
            && toks[i + 1].kind == TokKind::Punct
            && toks[i + 1].text == "[")
        {
            i += 1;
            continue;
        }
        // collect the attribute tokens up to the matching `]`
        let attr_start = i;
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_cfg = false;
        let mut has_test = false;
        let mut has_not = false;
        while j < toks.len() && depth > 0 {
            let t = &toks[j];
            if t.kind == TokKind::Punct && t.text == "[" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "]" {
                depth -= 1;
            } else if t.kind == TokKind::Ident {
                match t.text.as_str() {
                    "cfg" => has_cfg = true,
                    "test" => has_test = true,
                    "not" => has_not = true,
                    _ => {}
                }
            }
            j += 1;
        }
        if !(has_cfg && has_test && !has_not) {
            i = j;
            continue;
        }
        // skip any further attributes on the same item
        while j + 1 < toks.len()
            && toks[j].kind == TokKind::Punct
            && toks[j].text == "#"
            && toks[j + 1].text == "["
        {
            let mut d = 1usize;
            j += 2;
            while j < toks.len() && d > 0 {
                if toks[j].kind == TokKind::Punct {
                    if toks[j].text == "[" {
                        d += 1;
                    } else if toks[j].text == "]" {
                        d -= 1;
                    }
                }
                j += 1;
            }
        }
        // scan to the item's end: first depth-0 `{` (then its match) or
        // a depth-0 `;` before any brace
        let mut d = 0i64;
        let mut end = toks.len();
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" => d += 1,
                    ")" | "]" => d -= 1,
                    "{" if d == 0 => {
                        // matching close from here
                        let mut bd = 0usize;
                        let mut k = j;
                        while k < toks.len() {
                            let u = &toks[k];
                            if u.kind == TokKind::Punct {
                                if u.text == "{" {
                                    bd += 1;
                                } else if u.text == "}" {
                                    bd -= 1;
                                    if bd == 0 {
                                        break;
                                    }
                                }
                            }
                            k += 1;
                        }
                        end = (k + 1).min(toks.len());
                        break;
                    }
                    ";" if d == 0 => {
                        end = j + 1;
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        spans.push((attr_start, end));
        i = end;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex(src: &str) -> LexedFile {
        LexedFile::lex("test.rs", src)
    }

    fn idents(f: &LexedFile) -> Vec<&str> {
        f.toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn raw_strings_do_not_leak_tokens() {
        // the classic false positive: panic-looking text inside a raw
        // string (even one holding quotes and hashes) must stay a single
        // Str token
        let f = lex(r##"let x = r"a.unwrap()"; let y = r#"b "q" panic!"#;"##);
        let strs: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, vec!["a.unwrap()", r#"b "q" panic!"#]);
        assert!(!idents(&f).contains(&"unwrap"));
        assert!(!idents(&f).contains(&"panic"));
    }

    #[test]
    fn nested_block_comments() {
        let f = lex("a /* outer /* inner unwrap() */ still comment */ b");
        assert_eq!(idents(&f), vec!["a", "b"]);
        assert_eq!(f.comments.len(), 1);
        assert!(f.comments[0].text.contains("inner unwrap()"));
    }

    #[test]
    fn line_comments_recorded_with_lines() {
        let f = lex("x\n// lint: allow(panic, reason here)\ny");
        assert_eq!(f.comments.len(), 1);
        assert_eq!(f.comments[0].line, 2);
        assert!(f.comments[0].text.contains("allow(panic"));
        assert_eq!(f.toks[1].line, 3); // y
    }

    #[test]
    fn cfg_test_spans_cover_mod_and_fn() {
        let src = "fn live() { x.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n  fn t() { y.unwrap(); }\n}\n\
                   fn live2() {}";
        let f = lex(src);
        // the unwrap inside mod tests is in a test span; the first is not
        let unwraps: Vec<usize> = f
            .toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.text == "unwrap")
            .map(|(i, _)| i)
            .collect();
        assert_eq!(unwraps.len(), 2);
        assert!(!f.in_test(unwraps[0]));
        assert!(f.in_test(unwraps[1]));
        // code after the test mod is live again
        let live2 = f
            .toks
            .iter()
            .position(|t| t.text == "live2")
            .unwrap();
        assert!(!f.in_test(live2));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_span() {
        let f = lex("#[cfg(not(test))]\nfn live() { x.unwrap(); }");
        let u = f.toks.iter().position(|t| t.text == "unwrap").unwrap();
        assert!(!f.in_test(u));
    }

    #[test]
    fn cfg_test_attr_on_use_item_ends_at_semicolon() {
        let f = lex("#[cfg(test)]\nuse foo::bar;\nfn live() { b.expect(\"x\"); }");
        let e = f.toks.iter().position(|t| t.text == "expect").unwrap();
        assert!(!f.in_test(e));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let f = lex("fn f<'a>(x: &'a str) -> char { 'x' }\nlet nl = '\\n';");
        let lifetimes: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let chars: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, vec!["'x'", "'\\n'"]);
    }

    #[test]
    fn maximal_munch_puncts() {
        let f = lex("a::b => c == d; e += 0..=9; g -> h");
        let puncts: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| t.text.as_str())
            .collect();
        assert!(puncts.contains(&"::"));
        assert!(puncts.contains(&"=>"));
        assert!(puncts.contains(&"=="));
        assert!(puncts.contains(&"+="));
        assert!(puncts.contains(&"..="));
        assert!(puncts.contains(&"->"));
        // `=>`/`==` never split into bare `=`
        assert!(!puncts.contains(&"="));
    }

    #[test]
    fn nested_generics_close_as_two_angle_tokens() {
        let f = lex("let x: Vec<Vec<u64>> = v;");
        let n = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct && t.text == ">")
            .count();
        assert_eq!(n, 2);
    }

    #[test]
    fn strings_with_escapes_and_numbers() {
        let f = lex(r#"call("a \"quoted\" key", 1.5, 1e-6, 0x5eed)"#);
        let strs: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].contains("quoted"));
        let nums: Vec<&str> = f
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5", "1e-6", "0x5eed"]);
    }

    #[test]
    fn matching_close_walks_nested_braces() {
        let f = lex("{ a { b } c ( d ) }");
        assert_eq!(f.matching_close(0), f.toks.len() - 1);
    }
}
