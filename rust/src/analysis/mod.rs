//! Repo-aware static analysis: `qurl lint`.
//!
//! Seven PRs of growth created hand-maintained correctness contracts
//! that nothing machine-checked — "field catalog updated" lived in
//! changelog prose.  This module is a dependency-free Rust source
//! scanner (hand-rolled lexer in [`lexer`], no syn/proc-macro — the
//! `util/json.rs` idiom) that turns those contracts into build
//! failures.  It runs two ways with identical semantics:
//!
//! * `qurl lint` — prints the per-pass findings table, exits nonzero on
//!   any finding (CI runs this in deny mode before clippy),
//! * tier-1 unit tests — `tests/lint.rs` runs [`run_all`] over `src/`,
//!   so drift fails `cargo test -q` without the subcommand being
//!   invoked, and the fixture tests in [`passes`] prove each pass fires
//!   on seeded violations and stays quiet on clean input.
//!
//! # Lint catalog
//!
//! | pass | contract | escape hatch |
//! |------|----------|--------------|
//! | `stats-catalog` | every `SchedulerStats` field (coordinator/request.rs) is accumulated in `SchedulerStats::merge`, documented in the `sched_*` field catalog (metrics/recorder.rs module docs), and written to a Recorder row in rl/trainer.rs.  Derived-key aliases: `occupancy_sum`→`sched_occupancy`, `queue_wait_sum_s`→`sched_queue_wait_s`, `wall_s`→`sched_tokens_per_s`. | none — merge, document, and emit the field |
//! | `config-drift` | every `TrainerConfig` field (rl/trainer.rs) round-trips `config::to_json` **and** `config::from_json`, and registers a `--` flag in `train_cli` (main.rs).  Same contract for every `CheckpointManifest` field (rl/checkpoint.rs) against `CheckpointManifest::to_json`/`from_json` — a field captured on save but not restored on load silently breaks deterministic resume. | `CONFIG_ONLY` list in passes.rs for preset-level fields that deliberately have no flag; stale entries (field gains a flag) are themselves findings.  No hatch for manifest fields |
//! | `protocol` | every `Command`/`Event` variant in coordinator/service.rs is both constructed and matched outside tests — no dead and no unhandled protocol variants. | none — delete the variant or handle it |
//! | `panic-wall` | no `unwrap()` / `expect(` / `panic!` / `unreachable!` / `todo!` / `unimplemented!` outside `#[cfg(test)]` in the hot-path modules: coordinator/{scheduler,service,kv,engine}.rs, rl/{trainer,checkpoint}.rs and `runtime/*`.  (`assert!` stays legal — invariant checks are welcome; what's banned is panicking *recovery paths*.) | `// lint: allow(panic, <reason>)` on or directly above the line; the reason must state the invariant that makes the panic unreachable |
//! | `send-safety` | `StepEngine::new` (and so `EngineFactory` realization) only inside `StepEngine::factory` — the closure workers run on their own thread — encoding PR 3's "PJRT state never crosses a thread" rule. | `// lint: allow(send, <reason>)` for provably same-thread construction (the inline backend) |
//!
//! Passes 1–3 also emit findings when their anchor files are missing
//! from the scanned set, so renaming `request.rs` (say) surfaces as a
//! lint failure instead of silently disabling the check.  Malformed
//! annotations (unknown kind, empty reason) are findings too: an escape
//! hatch without a recorded invariant is a violation in its own right.
//!
//! Checkpoint/resume (ROADMAP item 3) landed: the `CheckpointManifest`
//! field set is covered by `config-drift` the same way `TrainerConfig`
//! is, and rl/checkpoint.rs sits on the panic wall — recovery-path
//! failures must be typed `CheckpointError`s, never panics.

pub mod lexer;
pub mod passes;

use std::collections::HashSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::LexedFile;

/// The five lint passes, in report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    StatsCatalog,
    ConfigDrift,
    Protocol,
    PanicWall,
    SendSafety,
}

pub const PASSES: [Pass; 5] = [
    Pass::StatsCatalog,
    Pass::ConfigDrift,
    Pass::Protocol,
    Pass::PanicWall,
    Pass::SendSafety,
];

impl Pass {
    pub fn name(self) -> &'static str {
        match self {
            Pass::StatsCatalog => "stats-catalog",
            Pass::ConfigDrift => "config-drift",
            Pass::Protocol => "protocol",
            Pass::PanicWall => "panic-wall",
            Pass::SendSafety => "send-safety",
        }
    }

    /// One-line contract, shown in the report header.
    pub fn contract(self) -> &'static str {
        match self {
            Pass::StatsCatalog => {
                "SchedulerStats fields merged, cataloged, and emitted"
            }
            Pass::ConfigDrift => {
                "TrainerConfig fields round-trip JSON and carry a flag"
            }
            Pass::Protocol => {
                "Command/Event variants constructed and matched"
            }
            Pass::PanicWall => {
                "no panicking calls on hot paths outside #[cfg(test)]"
            }
            Pass::SendSafety => {
                "StepEngine built only inside worker-thread closures"
            }
        }
    }
}

impl fmt::Display for Pass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One lint violation.  `line == 0` means the finding is about the file
/// as a whole (missing anchor, missing struct).
#[derive(Clone, Debug)]
pub struct Finding {
    pub pass: Pass,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

/// The lexed source files a lint run scans.  Paths are relative to the
/// source root and `/`-separated (`coordinator/scheduler.rs`), so the
/// passes address anchor files the same way from `qurl lint`, the
/// repo-clean test, and the in-memory fixture sets.
pub struct SourceSet {
    files: Vec<LexedFile>,
}

impl SourceSet {
    /// Build a set from in-memory `(path, source)` pairs — the fixture
    /// tests use this to seed violations without touching disk layout.
    pub fn from_memory(files: &[(&str, &str)]) -> SourceSet {
        SourceSet {
            files: files
                .iter()
                .map(|(p, s)| LexedFile::lex(p, s))
                .collect(),
        }
    }

    /// Lex every `*.rs` under `root` (recursively), sorted by relative
    /// path for deterministic reports.
    pub fn load(root: &Path) -> io::Result<SourceSet> {
        let mut paths: Vec<PathBuf> = Vec::new();
        walk(root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for p in &paths {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let src = fs::read_to_string(p)?;
            files.push(LexedFile::lex(&rel, &src));
        }
        Ok(SourceSet { files })
    }

    pub fn file(&self, path: &str) -> Option<&LexedFile> {
        self.files.iter().find(|f| f.path == path)
    }

    pub fn files(&self) -> &[LexedFile] {
        &self.files
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

pub fn run_pass(pass: Pass, set: &SourceSet) -> Vec<Finding> {
    match pass {
        Pass::StatsCatalog => passes::stats_catalog(set),
        Pass::ConfigDrift => passes::config_drift(set),
        Pass::Protocol => passes::protocol(set),
        Pass::PanicWall => passes::panic_wall(set),
        Pass::SendSafety => passes::send_safety(set),
    }
}

/// Run all five passes.  Findings both the panic-wall and send-safety
/// passes raise (malformed annotations are parsed by each) are deduped
/// by `(file, line, msg)`.
pub fn run_all(set: &SourceSet) -> Vec<Finding> {
    let mut out: Vec<Finding> = Vec::new();
    let mut seen: HashSet<(String, u32, String)> = HashSet::new();
    for pass in PASSES {
        for f in run_pass(pass, set) {
            if seen.insert((f.file.clone(), f.line, f.msg.clone())) {
                out.push(f);
            }
        }
    }
    out
}

/// Render the per-pass findings table `qurl lint` prints (and CI uploads
/// as an artifact).
pub fn report(findings: &[Finding]) -> String {
    let mut s = String::new();
    s.push_str("qurl lint — repo contract checks\n\n");
    s.push_str(&format!(
        "{:<14} {:>8}  {}\n", "pass", "findings", "contract"));
    for pass in PASSES {
        let n = findings.iter().filter(|f| f.pass == pass).count();
        let status = if n == 0 { "ok".to_string() } else { n.to_string() };
        s.push_str(&format!(
            "{:<14} {:>8}  {}\n", pass.name(), status, pass.contract()));
    }
    for pass in PASSES {
        let of_pass: Vec<&Finding> =
            findings.iter().filter(|f| f.pass == pass).collect();
        if of_pass.is_empty() {
            continue;
        }
        s.push_str(&format!("\n[{}]\n", pass.name()));
        for f in of_pass {
            if f.line == 0 {
                s.push_str(&format!("  {}: {}\n", f.file, f.msg));
            } else {
                s.push_str(&format!(
                    "  {}:{}: {}\n", f.file, f.line, f.msg));
            }
        }
    }
    let total = findings.len();
    if total == 0 {
        s.push_str("\nall passes clean\n");
    } else {
        s.push_str(&format!("\n{total} finding(s)\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_lists_passes_and_counts() {
        let f = vec![Finding {
            pass: Pass::PanicWall,
            file: "coordinator/scheduler.rs".to_string(),
            line: 7,
            msg: "`unwrap` on a hot path".to_string(),
        }];
        let r = report(&f);
        assert!(r.contains("stats-catalog"));
        assert!(r.contains("send-safety"));
        assert!(r.contains("[panic-wall]"));
        assert!(r.contains("coordinator/scheduler.rs:7"));
        assert!(r.contains("1 finding(s)"));
        let clean = report(&[]);
        assert!(clean.contains("all passes clean"));
    }

    #[test]
    fn run_all_dedups_shared_annotation_findings() {
        // a malformed annotation is parsed by both panic-wall and
        // send-safety; run_all must report it once
        let set = SourceSet::from_memory(&[
            (
                "coordinator/scheduler.rs",
                "// lint: allow(panic, )\nfn f() {}\n",
            ),
            ("coordinator/service.rs", ""),
            ("coordinator/kv.rs", ""),
            ("coordinator/engine.rs", ""),
        ]);
        let all = run_all(&set);
        let malformed: Vec<&Finding> = all
            .iter()
            .filter(|f| f.msg.contains("non-empty reason"))
            .collect();
        assert_eq!(malformed.len(), 1);
    }

    #[test]
    fn from_memory_paths_resolve() {
        let set = SourceSet::from_memory(&[("a/b.rs", "fn x() {}")]);
        assert!(set.file("a/b.rs").is_some());
        assert!(set.file("a/c.rs").is_none());
        assert_eq!(set.files().len(), 1);
    }
}
