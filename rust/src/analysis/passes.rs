//! The five repo-contract lint passes.  Each takes a [`SourceSet`] so the
//! unit tests drive them over seeded-violation fixtures exactly the way
//! `qurl lint` drives them over `src/`.  See the Lint catalog in
//! [`super`] (src/analysis/mod.rs) for each pass's contract and escape
//! hatch.

use std::collections::{BTreeSet, HashSet};

use super::lexer::{LexedFile, TokKind};
use super::{Finding, Pass, SourceSet};

// ---- shared structural helpers ---------------------------------------------

/// Fields of `struct <name> { … }`: the identifier before every `:` at
/// body depth 0 (angle depth tracked so generic bounds never split a
/// field).  Returns `(field, line)` pairs in declaration order.
fn struct_fields(f: &LexedFile, name: &str) -> Option<Vec<(String, u32)>> {
    let decl = (0..f.toks.len()).find(|&i| {
        f.is_ident(i, "struct") && f.is_ident(i + 1, name)
    })?;
    let open = (decl + 2..f.toks.len())
        .find(|&i| f.is_punct(i, "{"))?;
    let close = f.matching_close(open);
    let mut depth = 0i64;
    let mut angle = 0i64;
    let mut out: Vec<(String, u32)> = Vec::new();
    for j in open + 1..close {
        let t = &f.toks[j];
        if t.kind != TokKind::Punct {
            continue;
        }
        match t.text.as_str() {
            "{" | "(" | "[" => depth += 1,
            "}" | ")" | "]" => depth -= 1,
            "<" => angle += 1,
            ">" => angle -= 1,
            ":" if depth == 0 && angle <= 0 => {
                if f.toks[j - 1].kind == TokKind::Ident {
                    out.push((
                        f.toks[j - 1].text.clone(),
                        f.toks[j - 1].line,
                    ));
                }
                angle = 0;
            }
            _ => {}
        }
    }
    Some(out)
}

/// Token span `(open_brace, close_brace)` of the body of the first
/// `fn <name>` in the file.
fn fn_body(f: &LexedFile, name: &str) -> Option<(usize, usize)> {
    let decl = (0..f.toks.len()).find(|&i| {
        f.is_ident(i, "fn") && f.is_ident(i + 1, name)
    })?;
    let mut d = 0i64;
    for j in decl + 2..f.toks.len() {
        let t = &f.toks[j];
        if t.kind != TokKind::Punct {
            continue;
        }
        match t.text.as_str() {
            "(" | "[" => d += 1,
            ")" | "]" => d -= 1,
            "{" if d == 0 => return Some((j, f.matching_close(j))),
            _ => {}
        }
    }
    None
}

/// Variants of `enum <name> { … }` plus the declaration token span.
fn enum_variants(
    f: &LexedFile,
    name: &str,
) -> Option<(Vec<(String, u32)>, (usize, usize))> {
    let decl = (0..f.toks.len()).find(|&i| {
        f.is_ident(i, "enum") && f.is_ident(i + 1, name)
    })?;
    let open = (decl + 2..f.toks.len())
        .find(|&i| f.is_punct(i, "{"))?;
    let close = f.matching_close(open);
    let mut depth = 0i64;
    let mut out: Vec<(String, u32)> = Vec::new();
    for j in open + 1..close {
        let t = &f.toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" | "(" | "[" => depth += 1,
                "}" | ")" | "]" => depth -= 1,
                _ => {}
            }
            continue;
        }
        if t.kind == TokKind::Ident
            && depth == 0
            && (f.is_punct(j - 1, "{") || f.is_punct(j - 1, ","))
        {
            out.push((t.text.clone(), t.line));
        }
    }
    Some((out, (decl, close + 1)))
}

/// String-literal contents inside a token range.
fn strings_in(f: &LexedFile, range: (usize, usize)) -> Vec<&str> {
    f.toks[range.0..range.1.min(f.toks.len())]
        .iter()
        .filter(|t| t.kind == TokKind::Str)
        .map(|t| t.text.as_str())
        .collect()
}

/// Token spans that are *patterns*: every match-arm pattern (including
/// its guard, up to the `=>`) and every `let`-binding pattern (covers
/// `if let`, `while let` and `let … else`).  An `Enum::Variant` path
/// inside one of these spans is a *match* of the variant; outside (and
/// outside the enum declaration) it is a *construction*.
fn pattern_spans(f: &LexedFile) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let n = f.toks.len();
    for i in 0..n {
        if f.is_ident(i, "match") {
            // body opens at the first `{` outside the scrutinee's parens
            let mut d = 0i64;
            let mut open = None;
            for j in i + 1..n {
                let t = &f.toks[j];
                if t.kind != TokKind::Punct {
                    continue;
                }
                match t.text.as_str() {
                    "(" | "[" => d += 1,
                    ")" | "]" => d -= 1,
                    "{" if d == 0 => {
                        open = Some(j);
                        break;
                    }
                    "{" => d += 1,
                    "}" => d -= 1,
                    ";" if d == 0 => break, // not a match expression
                    _ => {}
                }
            }
            let Some(open) = open else { continue };
            let close = f.matching_close(open);
            // walk the arms: pattern (+ guard) runs to the depth-0 `=>`
            let mut k = open + 1;
            while k < close {
                let arm_start = k;
                let mut d2 = 0i64;
                let mut arrow = None;
                while k < close {
                    let t = &f.toks[k];
                    if t.kind == TokKind::Punct {
                        match t.text.as_str() {
                            "(" | "[" | "{" => d2 += 1,
                            ")" | "]" | "}" => d2 -= 1,
                            "=>" if d2 == 0 => {
                                arrow = Some(k);
                                break;
                            }
                            _ => {}
                        }
                    }
                    k += 1;
                }
                let Some(arrow) = arrow else { break };
                spans.push((arm_start, arrow));
                // skip the arm body: braced block or up to a depth-0 `,`
                k = arrow + 1;
                if k < close && f.is_punct(k, "{") {
                    k = f.matching_close(k) + 1;
                    if k < close && f.is_punct(k, ",") {
                        k += 1;
                    }
                } else {
                    let mut d3 = 0i64;
                    while k < close {
                        let t = &f.toks[k];
                        if t.kind == TokKind::Punct {
                            match t.text.as_str() {
                                "(" | "[" | "{" => d3 += 1,
                                ")" | "]" | "}" => d3 -= 1,
                                "," if d3 == 0 => {
                                    k += 1;
                                    break;
                                }
                                _ => {}
                            }
                        }
                        k += 1;
                    }
                }
            }
        } else if f.is_ident(i, "let") {
            // pattern runs to the depth-0 `=` (or `;` for plain decls)
            let mut d = 0i64;
            for j in i + 1..n {
                let t = &f.toks[j];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" => d += 1,
                        ")" | "]" | "}" => d -= 1,
                        "=" | ";" if d == 0 => {
                            spans.push((i + 1, j));
                            break;
                        }
                        _ => {}
                    }
                }
            }
        }
    }
    spans
}

/// Parse a file's escape-hatch annotations — `// lint: allow(panic, why)` or with the `send` kind — one physical line each.
/// Returns the source lines covered for `kind` (the comment's line and
/// the next, so the annotation sits above or beside the site) plus
/// findings for malformed annotations (missing kind/reason — an escape
/// hatch without a recorded invariant is itself a violation).
/// Self-referential caveat: this file is scanned by its own passes, so
/// these docs must themselves parse as well-formed annotations.
fn allow_lines(
    f: &LexedFile,
    kind: &str,
    pass: Pass,
) -> (HashSet<u32>, Vec<Finding>) {
    let mut lines: HashSet<u32> = HashSet::new();
    let mut bad: Vec<Finding> = Vec::new();
    for c in &f.comments {
        let Some(at) = c.text.find("lint: allow") else { continue };
        let rest = &c.text[at..];
        let parsed = rest.find('(').and_then(|po| {
            let inner = &rest[po + 1..];
            let ci = inner.find(',')?;
            let k = inner[..ci].trim().to_string();
            let pe = inner.rfind(')')?;
            if pe <= ci {
                return None;
            }
            let reason = inner[ci + 1..pe].trim().to_string();
            Some((k, reason))
        });
        match parsed {
            Some((k, reason)) => {
                if k != "panic" && k != "send" {
                    bad.push(Finding {
                        pass,
                        file: f.path.clone(),
                        line: c.line,
                        msg: format!(
                            "unknown lint annotation kind {k:?} \
                             (expected panic or send)"),
                    });
                } else if reason.is_empty() {
                    bad.push(Finding {
                        pass,
                        file: f.path.clone(),
                        line: c.line,
                        msg: format!(
                            "lint: allow({k}, …) needs a non-empty \
                             reason stating the invariant"),
                    });
                } else if k == kind {
                    lines.insert(c.line);
                    lines.insert(c.line + 1);
                }
            }
            None => bad.push(Finding {
                pass,
                file: f.path.clone(),
                line: c.line,
                msg: "malformed lint annotation — expected \
                      `lint: allow(<kind>, <reason>)`"
                    .to_string(),
            }),
        }
    }
    (lines, bad)
}

fn missing_anchor(pass: Pass, path: &str) -> Finding {
    Finding {
        pass,
        file: path.to_string(),
        line: 0,
        msg: format!(
            "anchor file {path} not found in the scanned set — the \
             pass cannot verify its contract (was the file moved? \
             update src/analysis/passes.rs)"),
    }
}

// ---- pass 1: stats-catalog drift -------------------------------------------

const STATS_FILE: &str = "coordinator/request.rs";
const CATALOG_FILE: &str = "metrics/recorder.rs";
const EMIT_FILE: &str = "rl/trainer.rs";

/// The recorder-row key a `SchedulerStats` field surfaces as.  Sum-style
/// counters map 1:1 to `sched_<field>`; the three accumulators that only
/// reach the row through a derived method map to that method's key.
fn stat_row_key(field: &str) -> String {
    match field {
        "occupancy_sum" => "sched_occupancy".to_string(),
        "queue_wait_sum_s" => "sched_queue_wait_s".to_string(),
        "wall_s" => "sched_tokens_per_s".to_string(),
        _ => format!("sched_{field}"),
    }
}

pub fn stats_catalog(set: &SourceSet) -> Vec<Finding> {
    let pass = Pass::StatsCatalog;
    let Some(req) = set.file(STATS_FILE) else {
        return vec![missing_anchor(pass, STATS_FILE)];
    };
    let Some(fields) = struct_fields(req, "SchedulerStats") else {
        return vec![Finding {
            pass,
            file: STATS_FILE.to_string(),
            line: 0,
            msg: "struct SchedulerStats not found".to_string(),
        }];
    };
    let mut out: Vec<Finding> = Vec::new();
    // merge coverage: `self.<field>` inside fn merge
    let merge = fn_body(req, "merge");
    if merge.is_none() {
        out.push(Finding {
            pass,
            file: STATS_FILE.to_string(),
            line: 0,
            msg: "SchedulerStats::merge not found".to_string(),
        });
    }
    let catalog: Option<String> = set.file(CATALOG_FILE).map(|f| {
        f.comments
            .iter()
            .map(|c| c.text.as_str())
            .collect::<Vec<_>>()
            .join("\n")
    });
    if catalog.is_none() {
        out.push(missing_anchor(pass, CATALOG_FILE));
    }
    let emitted: Option<Vec<&str>> = set
        .file(EMIT_FILE)
        .map(|f| strings_in(f, (0, f.toks.len())));
    if emitted.is_none() {
        out.push(missing_anchor(pass, EMIT_FILE));
    }
    for (field, line) in &fields {
        if let Some((open, close)) = merge {
            let merged = (open..close).any(|i| {
                req.is_ident(i, "self")
                    && req.is_punct(i + 1, ".")
                    && req.is_ident(i + 2, field)
            });
            if !merged {
                out.push(Finding {
                    pass,
                    file: STATS_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "SchedulerStats.{field} is not accumulated in \
                         SchedulerStats::merge — multi-run steps would \
                         silently drop it"),
                });
            }
        }
        let key = stat_row_key(field);
        if let Some(cat) = &catalog {
            if !cat.contains(&key) {
                out.push(Finding {
                    pass,
                    file: CATALOG_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "`{key}` (SchedulerStats.{field}) is missing \
                         from the sched_* field catalog in \
                         {CATALOG_FILE}"),
                });
            }
        }
        if let Some(em) = &emitted {
            if !em.iter().any(|s| s.contains(&key)) {
                out.push(Finding {
                    pass,
                    file: EMIT_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "`{key}` (SchedulerStats.{field}) is never \
                         written to a Recorder row in {EMIT_FILE}"),
                });
            }
        }
    }
    out
}

// ---- pass 2: config drift --------------------------------------------------

const CFG_FILE: &str = "rl/trainer.rs";
const JSON_FILE: &str = "config/mod.rs";
const CLI_FILE: &str = "main.rs";
const CKPT_FILE: &str = "rl/checkpoint.rs";

/// Fields that deliberately have no `qurl train` flag: they define the
/// preset itself (algo, suite, batch geometry, eval/analysis cadence) and
/// are overridden by editing a preset JSON, not per-run.  A field listed
/// here that *gains* a flag must be removed — the pass flags stale
/// entries.
const CONFIG_ONLY: [&str; 15] = [
    "algo", "suite", "prompts_per_step", "group_size", "temp", "top_p",
    "eval_every", "eval_problems_per_family", "inner_epochs", "gamma",
    "gae_lambda", "whiten_adv", "dynamic_sampling", "requantize_every",
    "analyze_every",
];

/// Field → flag names that are not the mechanical `_`→`-` rewrite.
const FLAG_ALIASES: [(&str, &str); 6] = [
    ("rollout_mode", "rollout"),
    ("rollout_stripe", "stripe"),
    ("rollout_steal", "steal"),
    ("kv_layout", "kv"),
    ("uaq_scale", "uaq"),
    ("prune_rollouts", "prune"),
];

pub fn config_drift(set: &SourceSet) -> Vec<Finding> {
    let pass = Pass::ConfigDrift;
    let Some(tr) = set.file(CFG_FILE) else {
        return vec![missing_anchor(pass, CFG_FILE)];
    };
    let Some(fields) = struct_fields(tr, "TrainerConfig") else {
        return vec![Finding {
            pass,
            file: CFG_FILE.to_string(),
            line: 0,
            msg: "struct TrainerConfig not found".to_string(),
        }];
    };
    let mut out: Vec<Finding> = Vec::new();
    let json_keys = |fun: &str| -> Option<BTreeSet<String>> {
        let f = set.file(JSON_FILE)?;
        let body = fn_body(f, fun)?;
        Some(strings_in(f, body).iter().map(|s| s.to_string()).collect())
    };
    let to_json = json_keys("to_json");
    let from_json = json_keys("from_json");
    if to_json.is_none() || from_json.is_none() {
        out.push(missing_anchor(pass, JSON_FILE));
    }
    // flags registered by `fn train_cli`: the string after each `.opt(`
    let flags: Option<BTreeSet<String>> = set.file(CLI_FILE).and_then(|f| {
        let (open, close) = fn_body(f, "train_cli")?;
        let mut fl = BTreeSet::new();
        for i in open..close {
            if f.is_punct(i, ".")
                && f.is_ident(i + 1, "opt")
                && f.is_punct(i + 2, "(")
                && f.toks.get(i + 3).map(|t| t.kind) == Some(TokKind::Str)
            {
                fl.insert(f.toks[i + 3].text.clone());
            }
        }
        Some(fl)
    });
    if flags.is_none() {
        out.push(missing_anchor(pass, CLI_FILE));
    }
    for (field, line) in &fields {
        for (fun, keys) in
            [("to_json", &to_json), ("from_json", &from_json)]
        {
            if let Some(keys) = keys {
                if !keys.contains(field) {
                    out.push(Finding {
                        pass,
                        file: JSON_FILE.to_string(),
                        line: *line,
                        msg: format!(
                            "TrainerConfig.{field} does not round-trip: \
                             no \"{field}\" key in config::{fun}"),
                    });
                }
            }
        }
        let Some(flags) = &flags else { continue };
        let flag = FLAG_ALIASES
            .iter()
            .find(|(f, _)| f == field)
            .map(|(_, v)| v.to_string())
            .unwrap_or_else(|| field.replace('_', "-"));
        let config_only = CONFIG_ONLY.contains(&field.as_str());
        let has_flag = flags.contains(&flag);
        if !config_only && !has_flag {
            out.push(Finding {
                pass,
                file: CLI_FILE.to_string(),
                line: *line,
                msg: format!(
                    "TrainerConfig.{field} has no --{flag} flag in \
                     train_cli (add one, or list the field in \
                     CONFIG_ONLY with a rationale)"),
            });
        }
        if config_only && has_flag {
            out.push(Finding {
                pass,
                file: CLI_FILE.to_string(),
                line: *line,
                msg: format!(
                    "TrainerConfig.{field} is listed CONFIG_ONLY but \
                     train_cli registers --{flag} — remove the stale \
                     allow-list entry"),
            });
        }
    }
    // checkpoint manifest: the same save/load shape contract, applied to
    // CheckpointManifest::to_json/from_json in rl/checkpoint.rs — a field
    // captured on save but never restored on load (or vice versa)
    // silently breaks the deterministic-resume guarantee, the exact drift
    // class this pass exists for
    let Some(ck) = set.file(CKPT_FILE) else {
        out.push(missing_anchor(pass, CKPT_FILE));
        return out;
    };
    let Some(mfields) = struct_fields(ck, "CheckpointManifest") else {
        out.push(Finding {
            pass,
            file: CKPT_FILE.to_string(),
            line: 0,
            msg: "struct CheckpointManifest not found".to_string(),
        });
        return out;
    };
    for fun in ["to_json", "from_json"] {
        let Some(body) = fn_body(ck, fun) else {
            out.push(Finding {
                pass,
                file: CKPT_FILE.to_string(),
                line: 0,
                msg: format!("CheckpointManifest::{fun} not found"),
            });
            continue;
        };
        let keys: BTreeSet<String> =
            strings_in(ck, body).iter().map(|s| s.to_string()).collect();
        for (field, line) in &mfields {
            if !keys.contains(field) {
                out.push(Finding {
                    pass,
                    file: CKPT_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "CheckpointManifest.{field} does not round-trip: \
                         no \"{field}\" key in CheckpointManifest::{fun} \
                         — a resumed run would silently lose it"),
                });
            }
        }
    }
    out
}

// ---- pass 3: protocol exhaustiveness ---------------------------------------

const PROTO_FILE: &str = "coordinator/service.rs";

pub fn protocol(set: &SourceSet) -> Vec<Finding> {
    let pass = Pass::Protocol;
    let Some(svc) = set.file(PROTO_FILE) else {
        return vec![missing_anchor(pass, PROTO_FILE)];
    };
    let spans = pattern_spans(svc);
    let in_pattern =
        |i: usize| spans.iter().any(|&(s, e)| i >= s && i < e);
    let mut out: Vec<Finding> = Vec::new();
    for enum_name in ["Command", "Event"] {
        let Some((variants, decl)) = enum_variants(svc, enum_name)
        else {
            out.push(Finding {
                pass,
                file: PROTO_FILE.to_string(),
                line: 0,
                msg: format!("enum {enum_name} not found"),
            });
            continue;
        };
        let names: BTreeSet<&str> =
            variants.iter().map(|(v, _)| v.as_str()).collect();
        let mut constructed: BTreeSet<String> = BTreeSet::new();
        let mut matched: BTreeSet<String> = BTreeSet::new();
        for i in 0..svc.toks.len() {
            if !(svc.is_ident(i, enum_name) && svc.is_punct(i + 1, "::"))
            {
                continue;
            }
            let Some(v) = svc.toks.get(i + 2) else { continue };
            if v.kind != TokKind::Ident || !names.contains(v.text.as_str())
            {
                continue;
            }
            if (i >= decl.0 && i < decl.1) || svc.in_test(i) {
                continue;
            }
            if in_pattern(i) {
                matched.insert(v.text.clone());
            } else {
                constructed.insert(v.text.clone());
            }
        }
        for (v, line) in &variants {
            if !constructed.contains(v) {
                out.push(Finding {
                    pass,
                    file: PROTO_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "{enum_name}::{v} is never constructed — dead \
                         protocol variant"),
                });
            }
            if !matched.contains(v) {
                out.push(Finding {
                    pass,
                    file: PROTO_FILE.to_string(),
                    line: *line,
                    msg: format!(
                        "{enum_name}::{v} is never matched — the \
                         service loops would drop or wedge on it"),
                });
            }
        }
    }
    out
}

// ---- pass 4: panic-freedom wall --------------------------------------------

/// Hot-path modules where a panic poisons a worker thread or aborts a
/// serving loop.  `runtime/*` joins by prefix below.  `rl/trainer.rs` is
/// on the wall because the training loop drives the threaded rollout
/// service: a trainer panic strands worker threads mid-decode instead of
/// unwinding the run as an error.  `rl/checkpoint.rs` is on the wall
/// because it runs on the crash-*recovery* path: a panic while reading a
/// torn snapshot would turn recoverable corruption into an abort, and
/// every failure there must instead surface as a typed
/// `CheckpointError` so the loader can fall back to the previous good
/// checkpoint.
const HOT_FILES: [&str; 6] = [
    "coordinator/scheduler.rs",
    "coordinator/service.rs",
    "coordinator/kv.rs",
    "coordinator/engine.rs",
    "rl/trainer.rs",
    "rl/checkpoint.rs",
];

const DENY_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];
const DENY_METHODS: [&str; 2] = ["unwrap", "expect"];

pub fn panic_wall(set: &SourceSet) -> Vec<Finding> {
    let pass = Pass::PanicWall;
    let mut out: Vec<Finding> = Vec::new();
    let mut scope: Vec<&LexedFile> = Vec::new();
    for path in HOT_FILES {
        match set.file(path) {
            Some(f) => scope.push(f),
            None => out.push(missing_anchor(pass, path)),
        }
    }
    for f in set.files() {
        if f.path.starts_with("runtime/") {
            scope.push(f);
        }
    }
    for f in scope {
        let (allowed, bad) = allow_lines(f, "panic", pass);
        out.extend(bad);
        for i in 0..f.toks.len() {
            let t = &f.toks[i];
            if t.kind != TokKind::Ident || f.in_test(i) {
                continue;
            }
            let name = t.text.as_str();
            let hit = (DENY_MACROS.contains(&name)
                && f.is_punct(i + 1, "!"))
                || (DENY_METHODS.contains(&name)
                    && i > 0
                    && f.is_punct(i - 1, ".")
                    && f.is_punct(i + 1, "("));
            if !hit || allowed.contains(&t.line) {
                continue;
            }
            out.push(Finding {
                pass,
                file: f.path.clone(),
                line: t.line,
                msg: format!(
                    "`{name}` on a hot path outside #[cfg(test)] — \
                     return a typed error, or annotate the invariant \
                     with `// lint: allow(panic, <reason>)`"),
            });
        }
    }
    out
}

// ---- pass 5: Send-safety ---------------------------------------------------

const ENGINE_FILE: &str = "coordinator/engine.rs";

pub fn send_safety(set: &SourceSet) -> Vec<Finding> {
    let pass = Pass::SendSafety;
    let mut out: Vec<Finding> = Vec::new();
    for f in set.files() {
        let factory = if f.path == ENGINE_FILE {
            fn_body(f, "factory")
        } else {
            None
        };
        let (allowed, bad) = allow_lines(f, "send", pass);
        out.extend(bad);
        for i in 0..f.toks.len() {
            if !(f.is_ident(i, "StepEngine")
                && f.is_punct(i + 1, "::")
                && f.is_ident(i + 2, "new")
                && f.is_punct(i + 3, "("))
            {
                continue;
            }
            if f.in_test(i) {
                continue;
            }
            if let Some((open, close)) = factory {
                if i > open && i < close {
                    // the worker-thread closure in StepEngine::factory —
                    // the one blessed construction site
                    continue;
                }
            }
            if allowed.contains(&f.toks[i].line) {
                continue;
            }
            out.push(Finding {
                pass,
                file: f.path.clone(),
                line: f.toks[i].line,
                msg: "StepEngine::new outside StepEngine::factory — \
                      PJRT state must not cross threads; construct via \
                      the factory inside the worker thread, or annotate \
                      `// lint: allow(send, <reason>)` if the engine \
                      provably stays on this thread"
                    .to_string(),
            });
        }
    }
    out
}

// ---- fixture-driven tests ---------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn set(files: &[(&str, &str)]) -> SourceSet {
        SourceSet::from_memory(files)
    }

    fn msgs(fs: &[Finding]) -> String {
        fs.iter()
            .map(|f| format!("{}:{} {}", f.file, f.line, f.msg))
            .collect::<Vec<_>>()
            .join("\n")
    }

    // ---- pass 1 ----

    #[test]
    fn stats_catalog_fires_on_each_drift_axis_and_stays_quiet_on_clean() {
        let s = set(&[
            (
                "coordinator/request.rs",
                include_str!(
                    "../../tests/fixtures/lint/stats_drift_request.rs"),
            ),
            (
                "metrics/recorder.rs",
                include_str!(
                    "../../tests/fixtures/lint/stats_drift_recorder.rs"),
            ),
            (
                "rl/trainer.rs",
                include_str!(
                    "../../tests/fixtures/lint/stats_drift_trainer.rs"),
            ),
        ]);
        let f = stats_catalog(&s);
        let m = msgs(&f);
        // `completed` is fully wired in the fixture: no finding names it
        assert!(!m.contains("completed"), "false positive:\n{m}");
        // the three seeded drift axes all fire
        assert!(m.contains("SchedulerStats.submitted is not accumulated"),
                "missing merge finding:\n{m}");
        assert!(m.contains("`sched_decode_steps` (SchedulerStats.\
                            decode_steps) is missing from the sched_*"),
                "missing catalog finding:\n{m}");
        assert!(m.contains("`sched_decode_steps` (SchedulerStats.\
                            decode_steps) is never written"),
                "missing emit finding:\n{m}");
        // alias: occupancy_sum is documented+emitted as sched_occupancy
        assert!(!m.contains("occupancy_sum"), "alias broke:\n{m}");
        assert_eq!(f.len(), 3, "unexpected findings:\n{m}");
    }

    // ---- pass 2 ----

    #[test]
    fn config_drift_fires_on_json_and_cli_gaps() {
        let s = set(&[
            (
                "rl/trainer.rs",
                include_str!(
                    "../../tests/fixtures/lint/config_drift_trainer.rs"),
            ),
            (
                "config/mod.rs",
                include_str!(
                    "../../tests/fixtures/lint/config_drift_config.rs"),
            ),
            (
                "main.rs",
                include_str!(
                    "../../tests/fixtures/lint/config_drift_main.rs"),
            ),
            (
                "rl/checkpoint.rs",
                include_str!(
                    "../../tests/fixtures/lint/ckpt_drift_checkpoint.rs"),
            ),
        ]);
        let f = config_drift(&s);
        let m = msgs(&f);
        // steps: fully wired — quiet
        assert!(!m.contains("TrainerConfig.steps "), "false positive:\n{m}");
        // kv_layout: alias --kv registered — quiet on the CLI axis,
        // but missing from from_json — one finding
        assert!(m.contains("TrainerConfig.kv_layout does not round-trip: \
                            no \"kv_layout\" key in config::from_json"),
                "missing from_json finding:\n{m}");
        // seed: no flag registered
        assert!(m.contains("TrainerConfig.seed has no --seed flag"),
                "missing cli finding:\n{m}");
        // temp: CONFIG_ONLY but the fixture registers --temp → stale
        assert!(m.contains("TrainerConfig.temp is listed CONFIG_ONLY"),
                "missing stale-allowlist finding:\n{m}");
        // checkpoint manifest: step/rng_state round-trip — quiet;
        // rng_inc is written by to_json but never read back in from_json
        assert!(!m.contains("CheckpointManifest.step"),
                "false positive:\n{m}");
        assert!(m.contains("CheckpointManifest.rng_inc does not \
                            round-trip: no \"rng_inc\" key in \
                            CheckpointManifest::from_json"),
                "missing manifest drift finding:\n{m}");
        assert_eq!(f.len(), 4, "unexpected findings:\n{m}");
    }

    // ---- pass 3 ----

    #[test]
    fn protocol_finds_dead_and_unhandled_variants() {
        let s = set(&[(
            "coordinator/service.rs",
            include_str!(
                "../../tests/fixtures/lint/protocol_service.rs"),
        )]);
        let f = protocol(&s);
        let m = msgs(&f);
        // Submit: constructed + matched — quiet
        assert!(!m.contains("Submit"), "false positive:\n{m}");
        // Finished: constructed + matched via `if let` — quiet
        assert!(!m.contains("Finished"), "false positive:\n{m}");
        assert!(m.contains("Command::Dead is never constructed"),
                "missing dead finding:\n{m}");
        assert!(m.contains("Command::Unhandled is never matched"),
                "missing unhandled finding:\n{m}");
        assert_eq!(f.len(), 2, "unexpected findings:\n{m}");
    }

    // ---- pass 4 ----

    #[test]
    fn panic_wall_fires_denies_and_honors_the_escape_hatch() {
        let hot = include_str!(
            "../../tests/fixtures/lint/panic_wall_hot.rs");
        let s = set(&[
            ("coordinator/scheduler.rs", hot),
            ("coordinator/service.rs", ""),
            ("coordinator/kv.rs", ""),
            ("coordinator/engine.rs", ""),
            ("rl/trainer.rs", ""),
            ("rl/checkpoint.rs", ""),
        ]);
        let f = panic_wall(&s);
        let m = msgs(&f);
        assert!(m.contains("`unwrap` on a hot path"),
                "missing unwrap finding:\n{m}");
        assert!(m.contains("`unreachable` on a hot path"),
                "missing unreachable finding:\n{m}");
        assert!(m.contains("needs a non-empty reason"),
                "missing malformed-annotation finding:\n{m}");
        // annotated expect, cfg(test) unwrap, and panic-looking text in
        // comments / strings / raw strings stay quiet
        assert!(!m.contains("`expect` on a hot path"),
                "annotation not honored:\n{m}");
        assert!(!m.contains("`panic` on a hot path"),
                "comment/string text leaked into the wall:\n{m}");
        assert_eq!(f.len(), 3, "unexpected findings:\n{m}");
    }

    #[test]
    fn panic_wall_reports_missing_hot_files() {
        let s = set(&[("coordinator/scheduler.rs", "fn ok() {}")]);
        let f = panic_wall(&s);
        // service, kv, engine, trainer, checkpoint anchors missing
        assert_eq!(f.len(), 5);
        assert!(msgs(&f).contains("anchor file coordinator/service.rs"));
    }

    // ---- pass 5 ----

    #[test]
    fn send_safety_blesses_factory_and_annotations_only() {
        let s = set(&[
            (
                "coordinator/engine.rs",
                include_str!(
                    "../../tests/fixtures/lint/send_safety_engine.rs"),
            ),
            (
                "main.rs",
                include_str!(
                    "../../tests/fixtures/lint/send_safety_main.rs"),
            ),
        ]);
        let f = send_safety(&s);
        let m = msgs(&f);
        assert_eq!(f.len(), 1, "expected exactly one finding:\n{m}");
        assert_eq!(f[0].file, "main.rs");
        assert!(m.contains("StepEngine::new outside StepEngine::factory"));
    }
}
