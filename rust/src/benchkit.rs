//! Shared plumbing for the `cargo bench` reproduction harnesses (one bench
//! target per paper table/figure — see DESIGN.md §4).
//!
//! Benches honor environment knobs so CI smoke runs stay short while
//! `QURL_FULL=1` regenerates paper-scale curves:
//!   QURL_STEPS   — RL steps per variant (default: per-bench small value)
//!   QURL_FULL    — 1: use the preset's full step counts
//!   QURL_SFT     — SFT steps when the base checkpoint is missing
//!   QURL_EVAL_K  — samples for Avg@K evaluations

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::metrics::Recorder;
use crate::rl::{self, Trainer, TrainerConfig};
use crate::runtime::{ParamStore, Runtime};
use crate::tasks::Suite;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub fn full_run() -> bool {
    std::env::var("QURL_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Steps for a bench variant: QURL_STEPS > QURL_FULL=preset > default.
pub fn bench_steps(default_small: usize, preset_steps: usize) -> usize {
    if let Ok(s) = std::env::var("QURL_STEPS") {
        if let Ok(v) = s.parse() {
            return v;
        }
    }
    if full_run() {
        preset_steps
    } else {
        default_small
    }
}

pub fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn results_dir() -> PathBuf {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Open the runtime + shared SFT base checkpoint (pretraining on demand).
/// The runtime comes back in an `Arc` — the trainer and `StepEngine` share
/// it by handle since the threaded-rollout refactor.
pub fn setup() -> Result<(Arc<Runtime>, ParamStore)> {
    let rt = Arc::new(Runtime::open(&artifacts_dir())?);
    let path = results_dir().join("base_model.bin");
    let ps = if path.exists() {
        let ps = ParamStore::load(&path)?;
        anyhow::ensure!(ps.params.len() == rt.manifest().n_params,
                        "stale base checkpoint — rerun `qurl pretrain`");
        ps
    } else {
        let steps = env_usize("QURL_SFT", 600);
        eprintln!("[benchkit] pretraining base model ({steps} SFT steps)...");
        let init = rt.init_params(0)?;
        let mut ps = ParamStore::new(rt.manifest(), init);
        let suite = Suite::by_name("deepscaler").unwrap();
        let mut rec = Recorder::ephemeral("sft");
        rl::pretrain_sft(&rt, &mut ps, &suite, steps, 3e-4, 0, &mut rec)?;
        ps.reset_optimizer();
        ps.save(&path)?;
        ps
    };
    Ok((rt, ps))
}

/// Train one experiment variant, recording to results/<run>.jsonl.
pub fn run_variant(rt: &Arc<Runtime>, base: &ParamStore,
                   cfg: TrainerConfig, run: &str)
                   -> Result<(Trainer, f64)> {
    eprintln!("[benchkit] variant {run}: {} steps, obj={}, rollout={}, \
               uaq={}", cfg.steps, cfg.objective.kind.name(),
              cfg.rollout_mode.tag(), cfg.uaq_scale);
    let rec = Recorder::create(&results_dir(), run)?;
    let mut tr = Trainer::new(rt, cfg, base.clone(), rec)?;
    let final_reward = tr.run()?;
    Ok((tr, final_reward))
}

/// Mean bytes newly staged host→device-format per decode call over a
/// run's scheduler-path rollouts — the fused-vs-service copy-tax column
/// (`sched_bytes_h2d / sched_decode_calls` summed over the run).  `None`
/// when the run logged no scheduler rows (fused path).
pub fn h2d_per_decode(tr: &Trainer) -> Option<f64> {
    let sum = |key: &str| -> f64 {
        tr.rec.series(key).iter().map(|&(_, v)| v).sum()
    };
    let calls = sum("sched_decode_calls");
    if calls <= 0.0 {
        None
    } else {
        Some(sum("sched_bytes_h2d") / calls)
    }
}

/// Render a (step, value) series as a compact sparkline + endpoints.
pub fn sparkline(series: &[(u64, f64)], width: usize) -> String {
    if series.is_empty() {
        return "(empty)".into();
    }
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let vals: Vec<f64> = series.iter().map(|&(_, v)| v).collect();
    let (mn, mx) = vals.iter().fold((f64::INFINITY, f64::NEG_INFINITY),
                                    |(a, b), &v| (a.min(v), b.max(v)));
    let span = (mx - mn).max(1e-12);
    let n = vals.len();
    let w = width.min(n).max(1);
    let mut out = String::new();
    for i in 0..w {
        let lo = i * n / w;
        let hi = ((i + 1) * n / w).max(lo + 1);
        let m = vals[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
        let idx = (((m - mn) / span) * 7.0).round() as usize;
        out.push(GLYPHS[idx.min(7)]);
    }
    format!("{out}  [{mn:.3} → {:.3}, max {mx:.3}]", vals[n - 1])
}

/// Print one metric curve for a finished run.
pub fn print_curve(label: &str, rec: &Recorder, key: &str) {
    let s = rec.series(key);
    println!("  {label:34} {key:18} {}", sparkline(&s, 48));
}
