//! Experiment configuration: named presets mirroring the paper's three
//! setups (§5) plus JSON file round-tripping so runs are reproducible.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{KvLayout, StealPolicy, StripePolicy};
use crate::rl::{Algo, Objective, ObjectiveKind, RolloutExec, RolloutPath,
                TrainerConfig};
use crate::runtime::QuantMode;
use crate::util::json::Json;

/// Paper §5.1 "PPO on GSM8K": Qwen2.5-0.5B, 435 steps, lr 1e-5 (high enough
/// that UAQ is disabled), greedy eval.  Scaled: arith-chain suite.
pub fn gsm8k_ppo() -> TrainerConfig {
    TrainerConfig {
        algo: Algo::Ppo,
        objective: Objective {
            kind: ObjectiveKind::Acr,
            eps_low: 0.2,
            eps_high: 0.2,
            tis_cap: 2.0,
            kl_coef: 0.0,
            vf_coef: 0.5,
            ent_coef: 0.0,
            token_mean: false,
            lr: 1e-4, // paper: 1e-5 at 0.5B; scaled for the 0.8M testbed
            ..Objective::default()
        },
        rollout_mode: QuantMode::Int8,
        suite: "gsm8k".into(),
        uaq_scale: 1.0, // paper: UAQ off for this experiment (high lr)
        steps: 120,
        prompts_per_step: 16,
        group_size: 4,
        temp: 1.0,
        top_p: 1.0,
        inner_epochs: 2,
        gamma: 1.0,
        gae_lambda: 0.95,
        whiten_adv: true,
        dynamic_sampling: false,
        eval_every: 10,
        eval_problems_per_family: 64,
        ..TrainerConfig::default()
    }
}

/// Paper §5.1 "DAPO on AIME": Qwen2.5-7B-Math, eps_hi 0.28 / eps_lo 0.2,
/// no KL, 512 prompts x 16 rollouts, lr 1e-6.  Scaled: modular suite.
pub fn dapo_aime() -> TrainerConfig {
    TrainerConfig {
        algo: Algo::Dapo,
        objective: Objective {
            kind: ObjectiveKind::Acr,
            eps_low: 0.2,
            eps_high: 0.28, // DAPO decoupled clip
            tis_cap: 2.0,
            kl_coef: 0.0,   // DAPO drops the KL term
            vf_coef: 0.0,
            token_mean: true,
            lr: 5e-5,       // paper 1e-6, scaled with model size
            ..Objective::default()
        },
        rollout_mode: QuantMode::Int8,
        suite: "aime".into(),
        uaq_scale: 1.5,
        steps: 100,
        prompts_per_step: 8,
        group_size: 8,
        temp: 1.0,
        top_p: 1.0,
        inner_epochs: 2,
        dynamic_sampling: true,
        eval_every: 10,
        eval_problems_per_family: 64,
        ..TrainerConfig::default()
    }
}

/// Paper §5.1 "GRPO on DeepScaleR": DeepSeek-Distill-1.5B, 3 stages,
/// KL coef 1e-3 (k3), temp 0.6, batch 256.  Scaled: 6-family suite.
pub fn deepscaler_grpo() -> TrainerConfig {
    TrainerConfig {
        algo: Algo::Grpo,
        objective: Objective {
            kind: ObjectiveKind::Acr,
            eps_low: 0.2,
            eps_high: 0.2,
            tis_cap: 2.0,
            kl_coef: 1e-3,
            vf_coef: 0.0,
            token_mean: false,
            lr: 5e-5,
            ..Objective::default()
        },
        rollout_mode: QuantMode::Int8,
        suite: "deepscaler".into(),
        uaq_scale: 1.5,
        steps: 160,
        prompts_per_step: 8,
        group_size: 8,
        temp: 1.0, // paper rollout temp 0.6 at eval; keep 1.0 for training
        top_p: 1.0,
        inner_epochs: 2,
        dynamic_sampling: false,
        eval_every: 20,
        eval_problems_per_family: 32,
        analyze_every: 8,
        ..TrainerConfig::default()
    }
}

pub fn preset(name: &str) -> Option<TrainerConfig> {
    match name {
        "gsm8k_ppo" => Some(gsm8k_ppo()),
        "dapo_aime" => Some(dapo_aime()),
        "deepscaler_grpo" => Some(deepscaler_grpo()),
        _ => None,
    }
}

pub const PRESETS: [&str; 3] = ["gsm8k_ppo", "dapo_aime", "deepscaler_grpo"];

// ---- JSON round-trip --------------------------------------------------------

pub fn to_json(cfg: &TrainerConfig) -> Json {
    Json::obj(vec![
        ("algo", Json::str(cfg.algo.name())),
        ("objective", Json::str(cfg.objective.kind.name())),
        ("eps_low", Json::num(cfg.objective.eps_low as f64)),
        ("eps_high", Json::num(cfg.objective.eps_high as f64)),
        ("tis_cap", Json::num(cfg.objective.tis_cap as f64)),
        ("kl_coef", Json::num(cfg.objective.kl_coef as f64)),
        ("vf_coef", Json::num(cfg.objective.vf_coef as f64)),
        ("ent_coef", Json::num(cfg.objective.ent_coef as f64)),
        ("token_mean", Json::Bool(cfg.objective.token_mean)),
        ("lr", Json::num(cfg.objective.lr as f64)),
        ("max_grad_norm", Json::num(cfg.objective.max_grad_norm as f64)),
        ("rollout_mode", Json::str(cfg.rollout_mode.tag())),
        ("rollout_path", Json::str(cfg.rollout_path.name())),
        ("suite", Json::str(&cfg.suite)),
        ("uaq_scale", Json::num(cfg.uaq_scale as f64)),
        ("steps", Json::num(cfg.steps as f64)),
        ("prompts_per_step", Json::num(cfg.prompts_per_step as f64)),
        ("group_size", Json::num(cfg.group_size as f64)),
        ("temp", Json::num(cfg.temp as f64)),
        ("top_p", Json::num(cfg.top_p as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("eval_every", Json::num(cfg.eval_every as f64)),
        ("eval_problems_per_family",
         Json::num(cfg.eval_problems_per_family as f64)),
        ("engine_noise", Json::num(cfg.engine_noise as f64)),
        ("inner_epochs", Json::num(cfg.inner_epochs as f64)),
        ("gamma", Json::num(cfg.gamma as f64)),
        ("gae_lambda", Json::num(cfg.gae_lambda as f64)),
        ("whiten_adv", Json::Bool(cfg.whiten_adv)),
        ("dynamic_sampling", Json::Bool(cfg.dynamic_sampling)),
        ("prune_rollouts", Json::Bool(cfg.prune_rollouts)),
        ("prune_min_finished", Json::num(cfg.prune_min_finished as f64)),
        ("rollout_engines", Json::num(cfg.rollout_engines as f64)),
        ("rollout_exec", Json::str(cfg.rollout_exec.name())),
        ("rollout_stripe", Json::str(cfg.rollout_stripe.name())),
        ("rollout_steal", Json::str(cfg.rollout_steal.name())),
        ("placement_log", Json::str(&cfg.placement_log)),
        ("min_prefill_batch", Json::num(cfg.min_prefill_batch as f64)),
        ("kv_layout", Json::str(cfg.kv_layout.name())),
        ("kv_page_size", Json::num(cfg.kv_page_size as f64)),
        ("prefill_chunk", Json::num(cfg.prefill_chunk as f64)),
        ("requantize_every", Json::num(cfg.requantize_every as f64)),
        ("analyze_every", Json::num(cfg.analyze_every as f64)),
        ("requant_delta", Json::Bool(cfg.requant_delta)),
        ("ckpt_every", Json::num(cfg.ckpt_every as f64)),
        ("ckpt_dir", Json::str(&cfg.ckpt_dir)),
        ("ckpt_keep", Json::num(cfg.ckpt_keep as f64)),
        ("resume", Json::Bool(cfg.resume)),
    ])
}

pub fn from_json(j: &Json) -> Result<TrainerConfig> {
    let mut cfg = TrainerConfig::default();
    let get_f = |k: &str, d: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
    let get_b = |k: &str, d: bool| j.get(k).and_then(|v| v.as_bool()).unwrap_or(d);
    if let Some(a) = j.get("algo").and_then(|v| v.as_str()) {
        cfg.algo = Algo::parse(a).context("bad algo")?;
    }
    if let Some(o) = j.get("objective").and_then(|v| v.as_str()) {
        cfg.objective.kind = ObjectiveKind::parse(o).context("bad objective")?;
    }
    if let Some(m) = j.get("rollout_mode").and_then(|v| v.as_str()) {
        cfg.rollout_mode = QuantMode::parse(m).context("bad rollout_mode")?;
    }
    if let Some(p) = j.get("rollout_path").and_then(|v| v.as_str()) {
        cfg.rollout_path = RolloutPath::parse(p).context("bad rollout_path")?;
    }
    if let Some(x) = j.get("rollout_exec").and_then(|v| v.as_str()) {
        cfg.rollout_exec = RolloutExec::parse(x).context("bad rollout_exec")?;
    }
    if let Some(s) = j.get("rollout_stripe").and_then(|v| v.as_str()) {
        cfg.rollout_stripe =
            StripePolicy::parse(s).context("bad rollout_stripe")?;
    }
    if let Some(s) = j.get("rollout_steal").and_then(|v| v.as_str()) {
        cfg.rollout_steal = StealPolicy::parse(s).context("bad rollout_steal")?;
    }
    if let Some(p) = j.get("placement_log").and_then(|v| v.as_str()) {
        cfg.placement_log = p.to_string();
    }
    if let Some(s) = j.get("suite").and_then(|v| v.as_str()) {
        cfg.suite = s.to_string();
    }
    cfg.objective.eps_low = get_f("eps_low", 0.2) as f32;
    cfg.objective.eps_high = get_f("eps_high", 0.2) as f32;
    cfg.objective.tis_cap = get_f("tis_cap", 2.0) as f32;
    cfg.objective.kl_coef = get_f("kl_coef", 0.0) as f32;
    cfg.objective.vf_coef = get_f("vf_coef", 0.0) as f32;
    cfg.objective.ent_coef = get_f("ent_coef", 0.0) as f32;
    cfg.objective.token_mean = get_b("token_mean", false);
    cfg.objective.lr = get_f("lr", 5e-5) as f32;
    cfg.objective.max_grad_norm = get_f("max_grad_norm", 1.0) as f32;
    cfg.uaq_scale = get_f("uaq_scale", 1.0) as f32;
    cfg.steps = get_f("steps", 100.0) as usize;
    cfg.prompts_per_step = get_f("prompts_per_step", 8.0) as usize;
    cfg.group_size = get_f("group_size", 8.0) as usize;
    cfg.temp = get_f("temp", 1.0) as f32;
    cfg.top_p = get_f("top_p", 1.0) as f32;
    cfg.seed = get_f("seed", 0.0) as u64;
    cfg.eval_every = get_f("eval_every", 0.0) as usize;
    cfg.eval_problems_per_family =
        get_f("eval_problems_per_family", 32.0) as usize;
    cfg.engine_noise = get_f("engine_noise", 0.0) as f32;
    cfg.inner_epochs = get_f("inner_epochs", 2.0) as usize;
    cfg.gamma = get_f("gamma", 1.0) as f32;
    cfg.gae_lambda = get_f("gae_lambda", 0.95) as f32;
    cfg.whiten_adv = get_b("whiten_adv", false);
    cfg.dynamic_sampling = get_b("dynamic_sampling", false);
    cfg.prune_rollouts = get_b("prune_rollouts", true);
    cfg.prune_min_finished = get_f("prune_min_finished", 0.0).max(0.0) as usize;
    cfg.rollout_engines = get_f("rollout_engines", 1.0).max(1.0) as usize;
    cfg.min_prefill_batch = get_f("min_prefill_batch", 1.0).max(1.0) as usize;
    if let Some(l) = j.get("kv_layout").and_then(|v| v.as_str()) {
        cfg.kv_layout = KvLayout::parse(l).context("bad kv_layout")?;
    }
    cfg.kv_page_size = get_f("kv_page_size", 16.0).max(1.0) as usize;
    cfg.prefill_chunk = get_f("prefill_chunk", 0.0).max(0.0) as usize;
    cfg.requantize_every = get_f("requantize_every", 1.0) as usize;
    cfg.analyze_every = get_f("analyze_every", 0.0) as usize;
    cfg.requant_delta = get_b("requant_delta", true);
    cfg.ckpt_every = get_f("ckpt_every", 0.0).max(0.0) as usize;
    if let Some(d) = j.get("ckpt_dir").and_then(|v| v.as_str()) {
        cfg.ckpt_dir = d.to_string();
    }
    cfg.ckpt_keep = get_f("ckpt_keep", 3.0).max(0.0) as usize;
    cfg.resume = get_b("resume", false);
    Ok(cfg)
}

pub fn load(path: &Path) -> Result<TrainerConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {path:?}"))?;
    from_json(&Json::parse(&text).context("parsing config json")?)
}

pub fn save(cfg: &TrainerConfig, path: &Path) -> Result<()> {
    std::fs::write(path, to_json(cfg).to_string()).context("writing config")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in PRESETS {
            let cfg = preset(name).unwrap();
            assert!(cfg.steps > 0);
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut cfg = dapo_aime();
        cfg.rollout_path = RolloutPath::Scheduler;
        cfg.rollout_engines = 3;
        cfg.rollout_exec = RolloutExec::Threaded;
        cfg.rollout_stripe = StripePolicy::LeastLoaded;
        cfg.rollout_steal = StealPolicy::Idle;
        cfg.placement_log = "runs/placement.json".to_string();
        cfg.min_prefill_batch = 4;
        cfg.kv_layout = KvLayout::Paged;
        cfg.kv_page_size = 32;
        cfg.prefill_chunk = 64;
        cfg.prune_rollouts = false;
        cfg.prune_min_finished = 5;
        cfg.requant_delta = false;
        cfg.ckpt_every = 4;
        cfg.ckpt_dir = "runs/ckpts".to_string();
        cfg.ckpt_keep = 7;
        cfg.resume = true;
        let j = to_json(&cfg);
        let back = from_json(&j).unwrap();
        assert_eq!(back.rollout_engines, 3);
        assert_eq!(back.rollout_exec, RolloutExec::Threaded);
        assert_eq!(back.rollout_stripe, StripePolicy::LeastLoaded);
        assert_eq!(back.rollout_steal, StealPolicy::Idle);
        assert_eq!(back.placement_log, "runs/placement.json");
        assert_eq!(back.min_prefill_batch, 4);
        assert_eq!(back.kv_layout, KvLayout::Paged);
        assert_eq!(back.kv_page_size, 32);
        assert_eq!(back.prefill_chunk, 64);
        // defaults stay inline/round-robin/dense (absent keys)
        let d = from_json(&Json::obj(vec![])).unwrap();
        assert_eq!(d.rollout_exec, RolloutExec::Inline);
        assert_eq!(d.rollout_stripe, StripePolicy::RoundRobin);
        assert_eq!(d.rollout_steal, StealPolicy::Off);
        assert!(d.placement_log.is_empty());
        assert_eq!(d.kv_layout, KvLayout::Dense);
        assert_eq!((d.kv_page_size, d.prefill_chunk), (16, 0));
        assert!(d.requant_delta, "delta requantization defaults on");
        assert!(!back.requant_delta,
                "explicit requant_delta=false round-trips");
        assert!(!back.prune_rollouts);
        assert_eq!(back.prune_min_finished, 5);
        assert_eq!(back.ckpt_every, 4);
        assert_eq!(back.ckpt_dir, "runs/ckpts");
        assert_eq!(back.ckpt_keep, 7);
        assert!(back.resume);
        assert_eq!((d.ckpt_every, d.ckpt_keep), (0, 3));
        assert!(d.ckpt_dir.is_empty());
        assert!(!d.resume, "resume defaults off");
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.objective.kind, cfg.objective.kind);
        assert_eq!(back.rollout_mode, cfg.rollout_mode);
        assert_eq!(back.rollout_path, cfg.rollout_path);
        assert_eq!(back.suite, cfg.suite);
        assert!((back.uaq_scale - cfg.uaq_scale).abs() < 1e-6);
        assert_eq!(back.dynamic_sampling, cfg.dynamic_sampling);
        assert!((back.objective.eps_high - 0.28).abs() < 1e-6);
        assert_eq!(back.inner_epochs, cfg.inner_epochs);
    }

    #[test]
    fn paper_hyperparams_encoded() {
        let d = dapo_aime();
        assert!(d.objective.token_mean);
        assert_eq!(d.objective.kl_coef, 0.0);
        assert!(d.dynamic_sampling);
        let g = deepscaler_grpo();
        assert!((g.objective.kl_coef - 1e-3).abs() < 1e-9);
        assert_eq!(g.algo, Algo::Grpo);
    }
}
