//! Step-wise rollout engine: persistent batched KV caches + the per-step
//! prefill/decode artifacts.  This is the serving-style execution path the
//! scheduler drives (continuous batching); bulk training rollouts use the
//! fused `generate_*` artifacts instead (runtime::exec::generate).

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{EngineWeights, HostTensor, Runtime};

/// What the [`Scheduler`](super::Scheduler) needs from an execution backend:
/// a fixed number of KV slots, batched prefill into chosen slots, one
/// lockstep decode step over (slot, pos, token) rows, and an in-flight
/// weight swap (hot requantization).
///
/// [`StepEngine`] is the production implementation (PJRT artifacts);
/// [`MockEngine`](super::mock::MockEngine) is the artifact-free stand-in the
/// property tests drive random request mixes through.
pub trait DecodeEngine {
    /// Weight payload [`DecodeEngine::swap_weights`] installs.  `Send +
    /// 'static` because the threaded [`RolloutService`](super::RolloutService)
    /// ships fresh weights to engine-owning worker threads over a channel;
    /// `Clone` because one requantization fans out to every replica.
    type Weights: Clone + Send + 'static;

    /// Number of concurrent KV slots (the continuous-batching width B).
    fn slot_count(&self) -> usize;

    /// Prefill `prompts[i]` into `slots[i]`; returns the last-position
    /// logits per slot (the distribution of the first generated token).
    fn prefill(&mut self, slots: &[usize], prompts: &[Vec<i32>])
               -> Result<Vec<Vec<f32>>>;

    /// One decode step: for each (slot, pos, token), write KV at `pos` and
    /// return next-token logits per row, in row order.
    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<Vec<f32>>>;

    /// Copy `src_slot`'s KV rows into every slot in `dst_slots` (group-
    /// shared prefix prefill): after prefilling one member of a group, the
    /// scheduler forks its prompt KV into the sibling slots instead of
    /// prefilling the same prompt `group_size` times.  Valid only while
    /// `src_slot` still holds exactly the prefilled prompt state (the
    /// scheduler forks within a single admission batch, before any decode
    /// tick advances the source).
    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize]) -> Result<()>;

    /// Install freshly (re)quantized weights without touching the KV caches
    /// or slot state — the in-flight requantization step (QuRL
    /// `requantize_every` at sub-step granularity).  Sequences already
    /// decoding continue under the new weights from their next step; their
    /// prompt KV stays as computed under the old weights, which is exactly
    /// the bounded off-policy drift the QuRL objectives (TIS/ACR) absorb.
    fn swap_weights(&mut self, w: Self::Weights);
}

/// Forward through mutable references so callers can keep owning an engine
/// while lending it to a [`Scheduler`](super::Scheduler) (which owns its
/// `E: DecodeEngine` — a borrowed engine is just `E = &mut Engine`).
impl<E: DecodeEngine> DecodeEngine for &mut E {
    type Weights = E::Weights;

    fn slot_count(&self) -> usize {
        (**self).slot_count()
    }

    fn prefill(&mut self, slots: &[usize], prompts: &[Vec<i32>])
               -> Result<Vec<Vec<f32>>> {
        (**self).prefill(slots, prompts)
    }

    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<Vec<f32>>> {
        (**self).decode(rows)
    }

    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize]) -> Result<()> {
        (**self).fork_kv(src_slot, dst_slots)
    }

    fn swap_weights(&mut self, w: Self::Weights) {
        (**self).swap_weights(w)
    }
}

/// Persistent decode state across steps.
///
/// Owns its runtime handle (`Arc<Runtime>`) rather than borrowing it, so an
/// engine is `'static` and a worker thread can build one around a runtime it
/// opened itself — the PJRT client and artifact cache never cross a thread
/// boundary (they are not `Send`); only plain weight/request data does.
pub struct StepEngine {
    rt: Arc<Runtime>,
    pub weights: EngineWeights,
    /// [L, B, H, S, Dh] caches, host-resident between artifact calls
    cache_k: Vec<f32>,
    cache_v: Vec<f32>,
    kv_shape: Vec<usize>,
    pub batch: usize,
}

impl StepEngine {
    /// Worker factory for the threaded
    /// [`RolloutService`](super::RolloutService): runs *inside* the worker
    /// thread, opening a private `Runtime` from `dir` (PJRT clients and
    /// compiled executables are not `Send`, so every worker must own its
    /// whole artifact stack) and wrapping `weights` in a fresh engine.
    /// This is the single definition of that invariant — the trainer and
    /// `qurl serve` both build their worker fleets from it.
    pub fn factory(dir: std::path::PathBuf, weights: EngineWeights)
                   -> super::service::EngineFactory<StepEngine> {
        Box::new(move || -> Result<StepEngine> {
            let rt = Arc::new(Runtime::open(&dir)?);
            Ok(StepEngine::new(&rt, weights))
        })
    }

    pub fn new(rt: &Arc<Runtime>, weights: EngineWeights) -> StepEngine {
        let m = rt.manifest();
        let kv_shape = vec![m.n_layers, m.rollout_batch, m.n_heads, m.max_seq,
                            m.head_dim];
        let n: usize = kv_shape.iter().product();
        StepEngine {
            rt: rt.clone(),
            weights,
            cache_k: vec![0.0; n],
            cache_v: vec![0.0; n],
            kv_shape,
            batch: m.rollout_batch,
        }
    }

    fn weight_inputs(&self) -> Vec<HostTensor> {
        let mut v = Vec::new();
        match &self.weights {
            EngineWeights::Bf16 { flat } => {
                v.push(HostTensor::f32(&[flat.len()], flat.clone()));
            }
            EngineWeights::Int8 { a, qw, qs } => {
                v.push(HostTensor::f32(&[a.len()], a.clone()));
                v.push(HostTensor::i8(&[qw.len()], qw.clone()));
                v.push(HostTensor::f32(&[qs.len()], qs.clone()));
            }
            EngineWeights::Fp8 { a, b_fq } => {
                v.push(HostTensor::f32(&[a.len()], a.clone()));
                v.push(HostTensor::f32(&[b_fq.len()], b_fq.clone()));
            }
        }
        v
    }

}

impl DecodeEngine for StepEngine {
    type Weights = EngineWeights;

    fn slot_count(&self) -> usize {
        self.batch
    }

    /// Prefill prompts into the given slots, merging only those rows into
    /// the persistent cache.  `prompts[i]` goes to `slots[i]`.
    fn prefill(&mut self, slots: &[usize], prompts: &[Vec<i32>])
               -> Result<Vec<Vec<f32>>> {
        assert_eq!(slots.len(), prompts.len());
        let m = self.rt.manifest();
        let (b, p, v) = (m.rollout_batch, m.max_prompt, m.vocab_size);
        let mut tokens = vec![0i32; b * p];
        let mut lens = vec![1i32; b];
        // inert rows: lone BOS
        for r in 0..b {
            tokens[r * p] = m.bos_id;
        }
        for (i, &slot) in slots.iter().enumerate() {
            let ids = &prompts[i];
            assert!(ids.len() <= p, "prompt longer than max_prompt");
            tokens[slot * p..slot * p + ids.len()].copy_from_slice(ids);
            lens[slot] = ids.len() as i32;
        }
        let mut inputs = self.weight_inputs();
        inputs.push(HostTensor::i32(&[b, p], tokens));
        inputs.push(HostTensor::i32(&[b], lens));
        let name = format!("prefill_{}", self.weights.mode().tag());
        let out = self.rt.store.call(&name, &inputs)?;
        let mut it = out.into_iter();
        let ck = it.next().unwrap().into_f32();
        let cv = it.next().unwrap().into_f32();
        let logits = it.next().unwrap().into_f32();
        // merge the new rows into the persistent cache
        let (l, _, h, s, dh) = (self.kv_shape[0], self.kv_shape[1],
                                self.kv_shape[2], self.kv_shape[3],
                                self.kv_shape[4]);
        let row_sz = h * s * dh;
        for &slot in slots {
            for layer in 0..l {
                let off = (layer * self.batch + slot) * row_sz;
                self.cache_k[off..off + row_sz]
                    .copy_from_slice(&ck[off..off + row_sz]);
                self.cache_v[off..off + row_sz]
                    .copy_from_slice(&cv[off..off + row_sz]);
            }
        }
        Ok(slots
            .iter()
            .map(|&slot| logits[slot * v..(slot + 1) * v].to_vec())
            .collect())
    }

    /// One decode step: for each (slot, pos, token), write KV at `pos` and
    /// return next-token logits per slot.  Inactive slots are fed an inert
    /// (pos=0, PAD) probe whose cache row is never merged back... but the
    /// artifact updates all rows, so inactive slots' caches are only safe
    /// because a future prefill overwrites them before reuse (tested).
    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<Vec<f32>>> {
        let m = self.rt.manifest();
        let (b, v) = (m.rollout_batch, m.vocab_size);
        let mut pos = vec![0i32; b];
        let mut tok = vec![m.pad_id; b];
        for &(slot, p, t) in rows {
            // KV capacity guard: the cache has exactly max_seq rows per
            // slot; a decode at p >= max_seq would write out of range in
            // the artifact's dynamic-update (silently clamped by XLA, which
            // would corrupt the last KV row instead of failing loudly).
            assert!((p as usize) < m.max_seq && slot < b,
                    "decode position {p} out of range (slot {slot}, \
                     max_seq {})", m.max_seq);
            pos[slot] = p;
            tok[slot] = t;
        }
        let mut inputs = self.weight_inputs();
        inputs.push(HostTensor::f32(&self.kv_shape, std::mem::take(&mut self.cache_k)));
        inputs.push(HostTensor::f32(&self.kv_shape, std::mem::take(&mut self.cache_v)));
        inputs.push(HostTensor::i32(&[b], pos));
        inputs.push(HostTensor::i32(&[b], tok));
        let name = format!("decode_{}", self.weights.mode().tag());
        let out = match self.rt.store.call(&name, &inputs) {
            Ok(out) => out,
            Err(e) => {
                // The caches were moved into `inputs` above (avoiding a copy
                // of the full KV tensors per decode), so a failed artifact
                // call would otherwise leave this engine with empty caches
                // and silently poison every later decode.  Reinstall them
                // before propagating: inputs end with [.., ck, cv, pos, tok].
                let _tok = inputs.pop();
                let _pos = inputs.pop();
                self.cache_v = inputs.pop().expect("cv input").into_f32();
                self.cache_k = inputs.pop().expect("ck input").into_f32();
                return Err(e);
            }
        };
        let mut it = out.into_iter();
        self.cache_k = it.next().unwrap().into_f32();
        self.cache_v = it.next().unwrap().into_f32();
        let logits = it.next().unwrap().into_f32();
        Ok(rows
            .iter()
            .map(|&(slot, _, _)| logits[slot * v..(slot + 1) * v].to_vec())
            .collect())
    }

    /// Host-side cache-row copy: duplicate `src_slot`'s K/V rows (every
    /// layer) into the destination slots.  Batched prefill writes identical
    /// KV for identical prompts regardless of slot index, so a fork is
    /// bit-for-bit equal to prefilling the prompt again (integration-tested
    /// against a fresh prefill).
    ///
    /// The copy spans the full `max_seq` row, not just the prompt prefix:
    /// that makes the destination byte-identical to a fresh prefill merge
    /// by construction, with no reliance on the attention mask zeroing
    /// stale tail positions exactly.  A prefix-limited copy (prompt_len
    /// per head) would cut host-copy cost ~max_seq/prompt_len x if that
    /// masking guarantee is ever established against the artifacts.
    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize]) -> Result<()> {
        let (l, b) = (self.kv_shape[0], self.kv_shape[1]);
        let row_sz = self.kv_shape[2] * self.kv_shape[3] * self.kv_shape[4];
        assert!(src_slot < b, "fork from bad slot {src_slot}");
        for layer in 0..l {
            let src = (layer * b + src_slot) * row_sz;
            for &dst_slot in dst_slots {
                assert!(dst_slot < b && dst_slot != src_slot,
                        "fork into bad slot {dst_slot}");
                let dst = (layer * b + dst_slot) * row_sz;
                self.cache_k.copy_within(src..src + row_sz, dst);
                self.cache_v.copy_within(src..src + row_sz, dst);
            }
        }
        Ok(())
    }

    /// Hot weight swap: replace only the weight tensors fed to the next
    /// prefill/decode artifact call.  KV caches and slot assignments are
    /// untouched, so a requantization no longer costs an engine rebuild (the
    /// pre-refactor `service = None` teardown re-allocated and re-zeroed
    /// every replica's caches).  The precision mode may change too — the
    /// artifact name is derived from the installed weights per call.
    fn swap_weights(&mut self, w: EngineWeights) {
        self.weights = w;
    }
}
