//! Step-wise rollout engine: persistent batched KV caches + the per-step
//! prefill/decode artifacts.  This is the serving-style execution path the
//! scheduler drives (continuous batching); bulk training rollouts use the
//! fused `generate_*` artifacts instead (runtime::exec::generate).
//!
//! # Residency boundary (what moves per tick)
//!
//! [`StepEngine`] keeps its state *resident* across artifact calls:
//!
//! * **weights** — converted to device-format literals **once per weight
//!   generation** ([`DecodeEngine::swap_weights`] bumps it), via
//!   [`InputHandle`]s cached in the engine; a decode tick stages zero
//!   weight bytes.  Swaps are delta-aware: a handle whose payload is
//!   pointer-identical in the incoming weights (delta requantization
//!   reuses the previous epoch's `Arc` for bit-identical tensors) is
//!   kept, cached conversion and all — only the payloads that actually
//!   changed re-stage, and a zero-change swap stages nothing
//!   ([`DecodeEngine::take_swap_h2d`] measures the remainder).
//! * **KV caches** — between decode ticks the `[L,B,H,S,Dh]` caches flow
//!   output→input as raw literals ([`KvBuf`]); they materialize into host
//!   vectors only when the engine must *mutate* rows (prefill-merge on
//!   admission, [`DecodeEngine::fork_kv`]) and re-stage on the next
//!   decode.  Steady-state decode moves no KV bytes host-side.  A
//!   [`KvPager`] books every prefill/decode/fork at page granularity over
//!   this tensor (see [`kv`](super::kv) module docs), gating admission
//!   and measuring prefix sharing/CoW — the physical rows stay dense
//!   because the compiled artifacts pin the cache shape.
//! * **logits** — one flat `[B, vocab]` block per call, exposed as
//!   [`LogitsRow`] views instead of per-slot copied vectors; block storage
//!   recycles through a [`F32Pool`] where the engine fills it itself.
//!
//! Only the per-tick control tensors (positions, tokens — a few bytes per
//! slot) convert every call.  The remaining copies are measured: every
//! engine drains `(bytes_h2d, bytes_d2h)` via
//! [`DecodeEngine::take_transfer`] into `SchedulerStats`.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;
use xla::Literal;

use crate::runtime::artifact::InputHandle;
use crate::runtime::{EngineWeights, HostTensor, Runtime};
use crate::util::pool::F32Pool;

use super::kv::{KvConfig, KvPageStats, KvPager};

/// Typed error for a KV cache taken twice without an intervening restore —
/// the engine was driven again after an earlier failed call left a cache
/// out.  Previously an `unreachable!` panic; as a plain error it propagates
/// through [`Scheduler::tick`](super::Scheduler::tick) like any engine
/// failure, so a threaded worker aborts cleanly (`abort_all` + slot
/// recycle + `TickError` event) instead of poisoning its thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvTakenError;

impl std::fmt::Display for KvTakenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV cache taken twice (engine left empty by an earlier \
                   failed call)")
    }
}

impl std::error::Error for KvTakenError {}

/// One flat `[rows, vocab]` logits tensor produced by a single engine
/// call.  Sequences hold [`LogitsRow`] views into it instead of per-slot
/// copies; when the last view drops, pooled storage returns to its
/// [`F32Pool`].
pub struct LogitsBlock {
    data: Vec<f32>,
    vocab: usize,
    pool: Option<Rc<F32Pool>>,
}

impl LogitsBlock {
    /// Block over an owned buffer (e.g. an artifact output vector).
    pub fn from_vec(data: Vec<f32>, vocab: usize) -> Rc<LogitsBlock> {
        assert!(vocab > 0 && data.len() % vocab == 0,
                "logits length {} not a multiple of vocab {vocab}",
                data.len());
        Rc::new(LogitsBlock { data, vocab, pool: None })
    }

    /// Block whose storage came from (and returns to) `pool` on drop.
    pub fn pooled(data: Vec<f32>, vocab: usize, pool: Rc<F32Pool>)
                  -> Rc<LogitsBlock> {
        assert!(vocab > 0 && data.len() % vocab == 0);
        Rc::new(LogitsBlock { data, vocab, pool: Some(pool) })
    }

    pub fn rows(&self) -> usize {
        self.data.len() / self.vocab
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.vocab..(r + 1) * self.vocab]
    }
}

impl Drop for LogitsBlock {
    fn drop(&mut self) {
        if let Some(pool) = &self.pool {
            pool.put(std::mem::take(&mut self.data));
        }
    }
}

/// Shared view of one row of a [`LogitsBlock`].  `Clone` is an `Rc` bump —
/// forked group siblings share their prefill row instead of cloning a
/// vocab-sized vector each.
#[derive(Clone)]
pub struct LogitsRow {
    block: Rc<LogitsBlock>,
    row: usize,
}

impl LogitsRow {
    pub fn new(block: Rc<LogitsBlock>, row: usize) -> LogitsRow {
        assert!(row < block.rows(), "row {row} out of block");
        LogitsRow { block, row }
    }

    pub fn as_slice(&self) -> &[f32] {
        self.block.row(self.row)
    }
}

/// What the [`Scheduler`](super::Scheduler) needs from an execution backend:
/// a fixed number of KV slots, batched prefill into chosen slots, one
/// lockstep decode step over (slot, pos, token) rows, and an in-flight
/// weight swap (hot requantization).
///
/// [`StepEngine`] is the production implementation (PJRT artifacts);
/// [`MockEngine`](super::mock::MockEngine) is the artifact-free stand-in the
/// property tests drive random request mixes through.
pub trait DecodeEngine {
    /// Weight payload [`DecodeEngine::swap_weights`] installs.  `Send +
    /// 'static` because the threaded [`RolloutService`](super::RolloutService)
    /// ships fresh weights to engine-owning worker threads over a channel;
    /// `Clone` because one requantization fans out to every replica.
    type Weights: Clone + Send + 'static;

    /// Number of concurrent KV slots (the continuous-batching width B).
    fn slot_count(&self) -> usize;

    /// Prefill `prompts[i]` into `slots[i]`; returns the last-position
    /// logits row per slot (the distribution of the first generated
    /// token), in argument order.
    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]])
               -> Result<Vec<LogitsRow>>;

    /// One decode step: for each (slot, pos, token), write KV at `pos` and
    /// return next-token logits per row, in row order.
    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<LogitsRow>>;

    /// Copy `src_slot`'s KV rows into every slot in `dst_slots` (group-
    /// shared prefix prefill): after prefilling one member of a group, the
    /// scheduler forks its prompt KV into the sibling slots instead of
    /// prefilling the same prompt `group_size` times.  Valid only while
    /// `src_slot` still holds exactly the prefilled prompt state (the
    /// scheduler forks within a single admission batch, before any decode
    /// tick advances the source).
    ///
    /// `prompt_len` is the prefilled prompt's length: only cache positions
    /// `< prompt_len` carry prompt state, so engines may copy just that
    /// prefix (causal masking guarantees positions `>= pos` are never read
    /// before the sequence's own decode writes them — artifact-parity
    /// tested against a fresh prefill).
    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize],
               prompt_len: usize) -> Result<()>;

    /// Install freshly (re)quantized weights without touching the KV caches
    /// or slot state — the in-flight requantization step (QuRL
    /// `requantize_every` at sub-step granularity).  Sequences already
    /// decoding continue under the new weights from their next step; their
    /// prompt KV stays as computed under the old weights, which is exactly
    /// the bounded off-policy drift the QuRL objectives (TIS/ACR) absorb.
    ///
    /// `epoch` is the service's [`WeightEpoch`](super::service::WeightEpoch)
    /// (surfaced in stats rows); independent of its value, engines with
    /// conversion caches must guarantee every *changed* weight payload is
    /// re-staged.  `StepEngine` keeps an existing resident handle only
    /// when the incoming payload is pointer-identical to the installed one
    /// (same allocation ⇒ same bytes ⇒ the cached conversion is still the
    /// truth) and builds a fresh unstaged handle for everything else — so
    /// serving stale bytes stays unrepresentable (bit-parity tested) while
    /// a delta requantization re-stages only what moved.
    fn swap_weights(&mut self, w: Self::Weights, epoch: u64);

    /// Drain the engine's accumulated `(bytes_h2d, bytes_d2h)` staging
    /// counters: bytes newly converted host→device-format per call, and
    /// bytes copied back out.  Resident inputs riding a cached conversion
    /// (and recycled output literals) contribute zero — so between weight
    /// swaps, decode-tick h2d collapses to the per-slot control tensors.
    /// Engines without a conversion boundary report zeros.
    fn take_transfer(&mut self) -> (u64, u64) {
        (0, 0)
    }

    /// Drain the weight bytes [`DecodeEngine::swap_weights`] scheduled for
    /// re-staging since the last drain: the total payload size of resident
    /// handles a swap replaced (pointer-unequal vs the installed weights).
    /// Under delta requantization this is the change-proportional swap
    /// cost — a swap whose weights all reuse the previous epoch's `Arc`s
    /// drains 0.  Engines without a conversion cache report 0.
    fn take_swap_h2d(&mut self) -> u64 {
        0
    }

    /// Install a KV layout ([`KvConfig`]) — rebuilds the engine's page
    /// ledger.  Call before serving begins; the scheduler's `set_kv`
    /// forwards here.  Engines without a pager ignore it.
    fn configure_kv(&mut self, _cfg: KvConfig) {}

    /// Return every page `slot` holds to the pager's free list.  The
    /// scheduler calls this on each slot release — completion, cancel
    /// (online pruning), and `abort_all` — so pruning reclaims KV memory,
    /// not just compute.  Idempotent; no-op without a pager.
    fn release_kv(&mut self, _slot: usize) {}

    /// Pages admission must find free before starting a sequence whose
    /// first prefill covers `prefill_len` positions (`forked` = admitted
    /// as a fork destination).  0 without a pager.
    fn kv_admit_cost(&self, _prefill_len: usize, _forked: bool) -> usize {
        0
    }

    /// `Some(free pages)` when a live admission gate (explicit page
    /// budget) is configured; `None` disables page-gated admission.
    fn kv_free_pages(&self) -> Option<usize> {
        None
    }

    /// Drain the page-ledger deltas and read the current levels
    /// ([`KvPageStats`]); zeros without a pager.
    fn take_kv_stats(&mut self) -> KvPageStats {
        KvPageStats::default()
    }
}

/// Forward through mutable references so callers can keep owning an engine
/// while lending it to a [`Scheduler`](super::Scheduler) (which owns its
/// `E: DecodeEngine` — a borrowed engine is just `E = &mut Engine`).
impl<E: DecodeEngine> DecodeEngine for &mut E {
    type Weights = E::Weights;

    fn slot_count(&self) -> usize {
        (**self).slot_count()
    }

    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]])
               -> Result<Vec<LogitsRow>> {
        (**self).prefill(slots, prompts)
    }

    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<LogitsRow>> {
        (**self).decode(rows)
    }

    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize],
               prompt_len: usize) -> Result<()> {
        (**self).fork_kv(src_slot, dst_slots, prompt_len)
    }

    fn swap_weights(&mut self, w: Self::Weights, epoch: u64) {
        (**self).swap_weights(w, epoch)
    }

    fn take_transfer(&mut self) -> (u64, u64) {
        (**self).take_transfer()
    }

    fn take_swap_h2d(&mut self) -> u64 {
        (**self).take_swap_h2d()
    }

    fn configure_kv(&mut self, cfg: KvConfig) {
        (**self).configure_kv(cfg)
    }

    fn release_kv(&mut self, slot: usize) {
        (**self).release_kv(slot)
    }

    fn kv_admit_cost(&self, prefill_len: usize, forked: bool) -> usize {
        (**self).kv_admit_cost(prefill_len, forked)
    }

    fn kv_free_pages(&self) -> Option<usize> {
        (**self).kv_free_pages()
    }

    fn take_kv_stats(&mut self) -> KvPageStats {
        (**self).take_kv_stats()
    }
}

/// One KV cache tensor, resident in whichever representation the last
/// operation left it: a raw device-format literal (decode output, recycled
/// straight into the next decode's input — zero host bytes) or a host
/// vector (after a mutation: prefill row-merge or fork).  `Empty` exists
/// only transiently while a call owns the payload.
enum KvBuf {
    Host(Vec<f32>),
    Device(Literal),
    Empty,
}

impl KvBuf {
    fn zeros(n: usize) -> KvBuf {
        KvBuf::Host(vec![0.0; n])
    }

    /// Move the cache out as a call input handle.  Device-format state
    /// stages for free; host state converts at call time (counted there).
    /// `force_host` round-trips device state through a host vector first —
    /// the per-call baseline path (d2h counted here).
    ///
    /// The fallible materialization happens BEFORE the payload is moved
    /// out, so an error leaves the cache exactly as it was — this method
    /// never converts a conversion failure into a lost cache.
    fn take_handle(&mut self, shape: &[usize], force_host: bool,
                   d2h: &mut u64) -> Result<InputHandle> {
        if force_host {
            self.host_mut(d2h)?;
        }
        match std::mem::replace(self, KvBuf::Empty) {
            KvBuf::Host(v) => Ok(InputHandle::new(HostTensor::f32(shape, v))),
            KvBuf::Device(l) => Ok(InputHandle::from_literal(l)),
            // every error path restores the payload, so this arm is
            // believed dead — but a typed error aborts the worker cleanly
            // where a panic would poison the thread (see KvTakenError)
            KvBuf::Empty => Err(KvTakenError.into()),
        }
    }

    /// Reinstall the cache from a handle a failed call handed back
    /// (whichever representation survived).
    fn restore(&mut self, h: InputHandle) {
        let (host, lit) = h.into_parts();
        *self = match lit {
            Some(l) => KvBuf::Device(l),
            None => KvBuf::Host(
                // lint: allow(panic, InputHandle always carries host or literal — new()/from_literal() each set one and into_parts never drops both)
                host.expect("KV handle lost both representations").into_f32()),
        };
    }

    /// Host-mutable view, materializing from a literal when needed
    /// (mutations — prefill merge, fork — happen on the host copy; the
    /// next decode re-stages it).
    fn host_mut(&mut self, d2h: &mut u64) -> Result<&mut Vec<f32>> {
        if let KvBuf::Device(l) = self {
            let v = l.to_vec::<f32>()?;
            *d2h += (v.len() * 4) as u64;
            *self = KvBuf::Host(v);
        }
        match self {
            KvBuf::Host(v) => Ok(v),
            // Empty outside a call means a previous error path failed to
            // restore the payload; surface it as the same typed error the
            // take path uses instead of poisoning the worker thread
            _ => Err(KvTakenError.into()),
        }
    }
}

/// Pull one prefill call's outputs (full ck/cv caches + logits) to host
/// without touching engine state, so the caller can book transfer bytes
/// before acting on any extraction failure.
fn take_prefill_outputs(outs: &mut crate::runtime::CallOutputs<'_>)
                        -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    Ok((outs.take_host(0)?.into_f32(),
        outs.take_host(1)?.into_f32(),
        outs.take_host(2)?.into_f32()))
}

/// Pull one decode call's outputs (ck, cv, logits) in the representation
/// the residency mode asks for, without touching engine state — the caller
/// installs them only when all three extractions succeed.
fn take_decode_outputs(outs: &mut crate::runtime::CallOutputs<'_>,
                       resident: bool) -> Result<(KvBuf, KvBuf, Vec<f32>)> {
    let (k, v) = if resident {
        (KvBuf::Device(outs.take_literal(0)?),
         KvBuf::Device(outs.take_literal(1)?))
    } else {
        (KvBuf::Host(outs.take_host(0)?.into_f32()),
         KvBuf::Host(outs.take_host(1)?.into_f32()))
    };
    let logits = outs.take_host(2)?.into_f32();
    Ok((k, v, logits))
}

/// Merge the prefilled rows for `slots` from a prefill-output cache into
/// the persistent cache (both flat `[L,B,H,S,Dh]`, `row_sz = H*S*Dh`).
/// One definition for K and V, so their offset math can never diverge.
fn merge_rows(dst: &mut [f32], src: &[f32], slots: &[usize], l: usize,
              b: usize, row_sz: usize) {
    for &slot in slots {
        for layer in 0..l {
            let off = (layer * b + slot) * row_sz;
            dst[off..off + row_sz].copy_from_slice(&src[off..off + row_sz]);
        }
    }
}

/// Copy slot `src`'s cache rows into `dsts` within a flat `[L,B,H,S,Dh]`
/// buffer.  `prefix` limits the copy to positions `< prefix` per head
/// (`None` = full `max_seq` rows — the debug/parity path).
fn fork_rows(buf: &mut [f32], dims: (usize, usize, usize, usize, usize),
             src: usize, dsts: &[usize], prefix: Option<usize>) {
    let (l, b, h, s, dh) = dims;
    match prefix {
        None => {
            let row_sz = h * s * dh;
            for layer in 0..l {
                let src_off = (layer * b + src) * row_sz;
                for &dst in dsts {
                    let dst_off = (layer * b + dst) * row_sz;
                    buf.copy_within(src_off..src_off + row_sz, dst_off);
                }
            }
        }
        Some(plen) => {
            let seg = plen.min(s) * dh;
            for layer in 0..l {
                for head in 0..h {
                    let src_off = ((layer * b + src) * h + head) * s * dh;
                    for &dst in dsts {
                        let dst_off = ((layer * b + dst) * h + head) * s * dh;
                        buf.copy_within(src_off..src_off + seg, dst_off);
                    }
                }
            }
        }
    }
}

/// Persistent decode state across steps.
///
/// Owns its runtime handle (`Arc<Runtime>`) rather than borrowing it, so an
/// engine is `'static` and a worker thread can build one around a runtime it
/// opened itself — the PJRT client and artifact cache never cross a thread
/// boundary (they are not `Send`); only plain weight/request data does.
pub struct StepEngine {
    rt: Arc<Runtime>,
    pub weights: EngineWeights,
    /// resident weight inputs: the literal conversion is cached for each
    /// handle's lifetime, and `swap_weights` replaces every handle whose
    /// payload changed (keeping pointer-identical ones) — so decode ticks
    /// stage zero weight bytes and a stale conversion is unrepresentable
    /// (no handle outlives its content)
    weight_handles: Vec<InputHandle>,
    /// `[L, B, H, S, Dh]` caches, resident between artifact calls
    cache_k: KvBuf,
    cache_v: KvBuf,
    kv_shape: Vec<usize>,
    pub batch: usize,
    /// staged/fetched bytes since the last `take_transfer` drain
    acc_h2d: u64,
    acc_d2h: u64,
    /// weight bytes `swap_weights` scheduled for re-staging (payloads that
    /// were not pointer-identical) since the last `take_swap_h2d` drain
    acc_swap_h2d: u64,
    /// input residency on (the default).  Off = the per-call baseline:
    /// weights reconvert and KV round-trips through host vectors every
    /// call — kept for the bit-parity tests and the copy-tax bench column.
    resident: bool,
    /// debug: full-`max_seq`-row fork_kv (the pre-prefix-fork behavior)
    /// for the prefix-fork parity test
    pub full_row_fork: bool,
    /// logical page ledger over the dense `[L,B,H,S,Dh]` tensor (see
    /// `coordinator::kv` module docs): books every prefill/decode/fork
    /// this engine executes, gates admission, and measures sharing — the
    /// physical rows stay dense because the compiled artifacts pin the
    /// cache shape.
    pager: KvPager,
}

impl StepEngine {
    /// Worker factory for the threaded
    /// [`RolloutService`](super::service::RolloutService): runs *inside* the
    /// worker thread, opening a private `Runtime` from `dir` (PJRT clients
    /// and compiled executables are not `Send`, so every worker must own its
    /// whole artifact stack) and wrapping `weights` in a fresh engine.
    /// This is the single definition of that invariant — the trainer and
    /// `qurl serve` both build their worker fleets from it.
    pub fn factory(dir: std::path::PathBuf, weights: EngineWeights)
                   -> super::service::EngineFactory<StepEngine> {
        Box::new(move || -> Result<StepEngine> {
            let rt = Arc::new(Runtime::open(&dir)?);
            Ok(StepEngine::new(&rt, weights))
        })
    }

    pub fn new(rt: &Arc<Runtime>, weights: EngineWeights) -> StepEngine {
        let m = rt.manifest();
        let kv_shape = vec![m.n_layers, m.rollout_batch, m.n_heads, m.max_seq,
                            m.head_dim];
        let n: usize = kv_shape.iter().product();
        let handles = weight_handles(&weights);
        StepEngine {
            rt: rt.clone(),
            weights,
            weight_handles: handles,
            cache_k: KvBuf::zeros(n),
            cache_v: KvBuf::zeros(n),
            kv_shape,
            batch: m.rollout_batch,
            acc_h2d: 0,
            acc_d2h: 0,
            acc_swap_h2d: 0,
            resident: true,
            full_row_fork: false,
            pager: KvPager::new(m.rollout_batch, m.max_seq,
                                KvConfig::default()),
        }
    }

    /// Read-only view of the page ledger (tests, bench KV-memory columns).
    pub fn pager(&self) -> &KvPager {
        &self.pager
    }

    /// Toggle input residency (default on).  Off reproduces the per-call
    /// conversion path bit-for-bit — same artifact inputs, rebuilt from
    /// host vectors every call — for the parity tests and the
    /// fused-vs-resident copy-tax comparison.
    pub fn set_resident(&mut self, on: bool) {
        self.resident = on;
    }

    pub fn is_resident(&self) -> bool {
        self.resident
    }

    /// Bytes one full conversion of the installed weights costs (what
    /// every tick paid before residency; what only the first call after a
    /// swap pays now).
    pub fn weight_bytes(&self) -> u64 {
        self.weights.byte_len()
    }

    fn kv_dims(&self) -> (usize, usize, usize, usize, usize) {
        (self.kv_shape[0], self.kv_shape[1], self.kv_shape[2],
         self.kv_shape[3], self.kv_shape[4])
    }

    /// Record KV literal→host materialization bytes in BOTH ledgers — the
    /// engine's `take_transfer` counters (→ `sched_bytes_d2h`) and the
    /// store's per-artifact table under a pseudo-artifact entry, so
    /// `store.stats()` reconciles with the scheduler-level counters.
    fn note_kv_d2h(&mut self, bytes: u64) {
        if bytes > 0 {
            self.acc_d2h += bytes;
            self.rt.store.note_d2h(KV_MATERIALIZE, bytes);
        }
    }
}

/// Pseudo-artifact name under which engine-side KV cache materializations
/// (literal→host for prefill merges, forks, and the per-call baseline)
/// appear in [`ArtifactStore::stats`](crate::runtime::ArtifactStore::stats).
const KV_MATERIALIZE: &str = "kv_materialize(host)";

/// Resident weight handles for `w`, in artifact input order — the fresh
/// (unstaged) form `StepEngine::new` installs and `delta_weight_handles`
/// falls back to per changed payload.
fn weight_handles(w: &EngineWeights) -> Vec<InputHandle> {
    w.host_tensors().into_iter().map(InputHandle::new).collect()
}

/// Delta-aware handle refresh for a weight swap: keep the existing handle
/// — cached device conversion included — for every payload that is
/// pointer-identical between `old_w` and `new_w` ([`HostTensor::same_payload`];
/// same allocation ⇒ same bytes ⇒ the cached literal is still the truth),
/// and build a fresh unstaged handle for the rest.  Returns the handles in
/// artifact input order plus the byte total of replaced payloads — the h2d
/// the next call pays for this swap (drained as `swap_bytes_h2d`).
///
/// `Runtime::engine_weights_delta` produces exactly this pointer-reuse for
/// tensors whose quantized form came out bit-identical, so with small RL
/// steps most handles survive a requantization and a zero-change swap
/// re-stages nothing.  A mode switch (different payload layout) replaces
/// everything — the conservative direction: a false "changed" costs one
/// re-stage, a false "unchanged" would serve stale bytes.
fn delta_weight_handles(old_w: &EngineWeights, old: Vec<InputHandle>,
                        new_w: &EngineWeights) -> (Vec<InputHandle>, u64) {
    let new_ts = new_w.host_tensors();
    if old_w.mode() != new_w.mode() || old.len() != new_ts.len() {
        let bytes = new_ts.iter().map(HostTensor::byte_len).sum();
        return (new_ts.into_iter().map(InputHandle::new).collect(), bytes);
    }
    let old_ts = old_w.host_tensors();
    let mut bytes = 0u64;
    let handles = old
        .into_iter()
        .zip(old_ts.iter().zip(new_ts))
        .map(|(h, (ot, nt))| {
            if ot.same_payload(&nt) {
                h
            } else {
                bytes += nt.byte_len();
                InputHandle::new(nt)
            }
        })
        .collect();
    (handles, bytes)
}

impl DecodeEngine for StepEngine {
    type Weights = EngineWeights;

    fn slot_count(&self) -> usize {
        self.batch
    }

    /// Prefill prompts into the given slots, merging only those rows into
    /// the persistent cache.  `prompts[i]` goes to `slots[i]`.  The weight
    /// inputs ride their cached literals; the full-cache outputs must come
    /// back to the host for the row merge (admission-boundary cost, not
    /// per-tick).
    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]])
               -> Result<Vec<LogitsRow>> {
        assert_eq!(slots.len(), prompts.len());
        let m = self.rt.manifest();
        let (b, p, v) = (m.rollout_batch, m.max_prompt, m.vocab_size);
        let bos_id = m.bos_id;
        let mut tokens = vec![0i32; b * p];
        let mut lens = vec![1i32; b];
        // inert rows: lone BOS
        for r in 0..b {
            tokens[r * p] = bos_id;
        }
        for (i, &slot) in slots.iter().enumerate() {
            let ids = prompts[i];
            assert!(ids.len() <= p, "prompt longer than max_prompt");
            tokens[slot * p..slot * p + ids.len()].copy_from_slice(ids);
            lens[slot] = ids.len() as i32;
        }
        if !self.resident {
            for h in &mut self.weight_handles {
                h.invalidate();
            }
        }
        let fresh = [HostTensor::i32(&[b, p], tokens),
                     HostTensor::i32(&[b], lens)];
        let name = format!("prefill_{}", self.weights.mode().tag());
        let mut resident: Vec<&mut InputHandle> =
            self.weight_handles.iter_mut().collect();
        let mut outs =
            self.rt.store.call_with_resident(&name, &mut resident, &fresh)?;
        // accumulate the transfer ledger even if extraction fails midway,
        // so the engine counters always reconcile with the store's
        let taken = take_prefill_outputs(&mut outs);
        self.acc_h2d += outs.staged_h2d();
        self.acc_d2h += outs.fetched_d2h();
        drop(outs);
        let (ck, cv, logits) = taken?;
        // merge the new rows into the persistent cache (host side; the
        // next decode re-stages the merged cache once).  BOTH caches
        // materialize before either mutates — a conversion failure must
        // not leave K merged while V is stale — and the moved bytes go on
        // the books before any later fallible step can drop them.
        let mut d2h = 0u64;
        self.cache_k.host_mut(&mut d2h)?;
        self.cache_v.host_mut(&mut d2h)?;
        self.note_kv_d2h(d2h);
        let (l, _, h, s, dh) = self.kv_dims();
        let row_sz = h * s * dh;
        let mut none = 0u64;
        // already Host: these host_muts cannot fail or move bytes
        merge_rows(self.cache_k.host_mut(&mut none)?, &ck, slots, l,
                   self.batch, row_sz);
        merge_rows(self.cache_v.host_mut(&mut none)?, &cv, slots, l,
                   self.batch, row_sz);
        debug_assert_eq!(none, 0);
        // ledger after the last fallible step, so it books only work that
        // actually landed in the cache
        for (i, &slot) in slots.iter().enumerate() {
            self.pager.on_prefill(slot, prompts[i].len());
        }
        let block = LogitsBlock::from_vec(logits, v);
        Ok(slots
            .iter()
            .map(|&slot| LogitsRow::new(block.clone(), slot))
            .collect())
    }

    /// One decode step: for each (slot, pos, token), write KV at `pos` and
    /// return next-token logits per slot.  Inactive slots are fed an inert
    /// (pos=0, PAD) probe whose cache row is never merged back... but the
    /// artifact updates all rows, so inactive slots' caches are only safe
    /// because a future prefill overwrites them before reuse (tested).
    ///
    /// Steady-state cost: the KV literals recycle output→input and the
    /// weight literals ride their cache, so the only bytes staged are the
    /// `[B]` pos/token vectors and the only bytes fetched are the logits.
    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<LogitsRow>> {
        let m = self.rt.manifest();
        let (b, v, max_seq, pad_id) =
            (m.rollout_batch, m.vocab_size, m.max_seq, m.pad_id);
        let mut pos = vec![0i32; b];
        let mut tok = vec![pad_id; b];
        for &(slot, p, t) in rows {
            // KV capacity guard: the cache has exactly max_seq rows per
            // slot; a decode at p >= max_seq would write out of range in
            // the artifact's dynamic-update (silently clamped by XLA, which
            // would corrupt the last KV row instead of failing loudly).
            assert!((p as usize) < max_seq && slot < b,
                    "decode position {p} out of range (slot {slot}, \
                     max_seq {max_seq})");
            pos[slot] = p;
            tok[slot] = t;
        }
        if !self.resident {
            for h in &mut self.weight_handles {
                h.invalidate();
            }
        }
        let mut d2h = 0u64;
        let mut kh =
            self.cache_k.take_handle(&self.kv_shape, !self.resident, &mut d2h)?;
        let mut vh = match self.cache_v
            .take_handle(&self.kv_shape, !self.resident, &mut d2h)
        {
            Ok(h) => h,
            Err(e) => {
                // cache_k is already out in `kh`; put it back so a failed
                // take of the sibling cache cannot orphan it — and keep the
                // bytes cache_k's materialization already moved on the books
                self.note_kv_d2h(d2h);
                self.cache_k.restore(kh);
                return Err(e);
            }
        };
        self.note_kv_d2h(d2h);
        let fresh = [HostTensor::i32(&[b], pos), HostTensor::i32(&[b], tok)];
        let name = format!("decode_{}", self.weights.mode().tag());
        let call = {
            let mut resident: Vec<&mut InputHandle> =
                self.weight_handles.iter_mut().collect();
            resident.push(&mut kh);
            resident.push(&mut vh);
            self.rt.store.call_with_resident(&name, &mut resident, &fresh)
        };
        let mut outs = match call {
            Ok(o) => o,
            Err(e) => {
                // the KV contents still live in the handles (host payload
                // and/or staged literal — call_with_resident reinstalls
                // them on failure), so a failed artifact call cannot leave
                // this engine with empty caches poisoning later decodes
                self.cache_k.restore(kh);
                self.cache_v.restore(vh);
                return Err(e);
            }
        };
        // KV flows output→input: keep the fresh caches as device-format
        // literals (zero d2h) on the resident path; the baseline path
        // copies them out like the pre-residency engine did
        let taken = take_decode_outputs(&mut outs, self.resident);
        self.acc_h2d += outs.staged_h2d();
        self.acc_d2h += outs.fetched_d2h();
        drop(outs);
        match taken {
            Ok((k, v_new, logits)) => {
                self.cache_k = k;
                self.cache_v = v_new;
                for &(slot, p, _) in rows {
                    self.pager.on_decode(slot, p as usize);
                }
                let block = LogitsBlock::from_vec(logits, v);
                Ok(rows
                    .iter()
                    .map(|&(slot, _, _)| LogitsRow::new(block.clone(), slot))
                    .collect())
            }
            Err(e) => {
                // output extraction failed post-execution: fall back to the
                // pre-call caches still held by the input handles
                self.cache_k.restore(kh);
                self.cache_v.restore(vh);
                Err(e)
            }
        }
    }

    /// Host-side cache-row copy: duplicate `src_slot`'s K/V rows (every
    /// layer) into the destination slots.  Batched prefill writes identical
    /// KV for identical prompts regardless of slot index, so a fork is
    /// bit-for-bit equal to prefilling the prompt again (integration-tested
    /// against a fresh prefill).
    ///
    /// The copy spans only the `prompt_len` prefix per head: positions
    /// `>= prompt_len` of a fresh slot hold stale garbage either way
    /// (previous occupant vs prefill's masked tail), and the causal mask
    /// guarantees a position is never read before the sequence's own
    /// decode writes it — so the prefix copy is bit-identical to the full
    /// row at ~`max_seq/prompt_len`× less host traffic.  The full-row path
    /// survives behind [`StepEngine::full_row_fork`] for the parity test
    /// that establishes exactly that guarantee against the artifacts.
    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize],
               prompt_len: usize) -> Result<()> {
        let dims = self.kv_dims();
        let (_, b, _, s, _) = dims;
        assert!(src_slot < b, "fork from bad slot {src_slot}");
        for &dst_slot in dst_slots {
            assert!(dst_slot < b && dst_slot != src_slot,
                    "fork into bad slot {dst_slot}");
        }
        let prefix = if self.full_row_fork || prompt_len == 0
            || prompt_len >= s
        {
            None
        } else {
            Some(prompt_len)
        };
        // materialize both caches (booking the bytes) before forking either
        let mut d2h = 0u64;
        self.cache_k.host_mut(&mut d2h)?;
        self.cache_v.host_mut(&mut d2h)?;
        self.note_kv_d2h(d2h);
        let mut none = 0u64;
        fork_rows(self.cache_k.host_mut(&mut none)?, dims, src_slot,
                  dst_slots, prefix);
        fork_rows(self.cache_v.host_mut(&mut none)?, dims, src_slot,
                  dst_slots, prefix);
        debug_assert_eq!(none, 0);
        // logical ledger: paged destinations alias (the physical copy above
        // is what a later CoW would have produced — bit-identical bytes,
        // and the ledger is what admission and the bench read)
        self.pager.on_fork(src_slot, dst_slots, prompt_len);
        Ok(())
    }

    /// Hot weight swap: replace the resident weight tensors fed to the next
    /// prefill/decode artifact call.  KV caches and slot assignments are
    /// untouched, so a requantization no longer costs an engine rebuild (the
    /// pre-refactor `service = None` teardown re-allocated and re-zeroed
    /// every replica's caches).  The precision mode may change too — the
    /// artifact name is derived from the installed weights per call.
    ///
    /// The swap is delta-aware ([`delta_weight_handles`]): a handle whose
    /// payload is pointer-identical in the incoming weights keeps its
    /// cached conversion, everything else gets a fresh unstaged handle —
    /// so stale cached bytes stay unrepresentable no matter what `epoch`
    /// value the caller passes, while the next call stages only the
    /// payloads that actually changed (a full-refresh swap pays the old
    /// wholesale cost; a zero-change delta swap pays nothing).
    fn swap_weights(&mut self, w: EngineWeights, _epoch: u64) {
        let old = std::mem::take(&mut self.weight_handles);
        let (handles, staged) = delta_weight_handles(&self.weights, old, &w);
        self.weight_handles = handles;
        self.acc_swap_h2d += staged;
        self.weights = w;
    }

    fn take_transfer(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.acc_h2d), std::mem::take(&mut self.acc_d2h))
    }

    fn take_swap_h2d(&mut self) -> u64 {
        std::mem::take(&mut self.acc_swap_h2d)
    }

    fn configure_kv(&mut self, cfg: KvConfig) {
        self.pager = KvPager::new(self.batch, self.kv_shape[3], cfg);
    }

    fn release_kv(&mut self, slot: usize) {
        self.pager.on_release(slot);
    }

    fn kv_admit_cost(&self, prefill_len: usize, forked: bool) -> usize {
        self.pager.admit_cost(prefill_len, forked)
    }

    fn kv_free_pages(&self) -> Option<usize> {
        self.pager.free_pages_gated()
    }

    fn take_kv_stats(&mut self) -> KvPageStats {
        self.pager.take_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Delta swap contract: pointer-identical payloads keep their handle
    /// (zero re-stage), changed payloads get fresh handles and their bytes
    /// go on the swap ledger, and a mode switch restages everything.
    #[test]
    fn delta_swap_keeps_pointer_equal_handles_and_counts_the_rest() {
        let a = Arc::new(vec![0.5f32; 4]); // 16 B
        let qw = Arc::new(vec![1i8; 6]); // 6 B
        let qs = Arc::new(vec![0.25f32; 3]); // 12 B
        let old_w =
            EngineWeights::Int8 { a: a.clone(), qw, qs: qs.clone() };

        // zero-change swap: every payload Arc reused → zero scheduled h2d
        let (kept, bytes) =
            delta_weight_handles(&old_w, weight_handles(&old_w), &old_w);
        assert_eq!(bytes, 0, "zero-change swap must schedule zero h2d");

        // one changed payload: qw reallocated, a/qs Arcs reused
        let new_qw = Arc::new(vec![2i8; 6]);
        let new_w = EngineWeights::Int8 {
            a: a.clone(),
            qw: new_qw.clone(),
            qs: qs.clone(),
        };
        let (handles, bytes) = delta_weight_handles(&old_w, kept, &new_w);
        assert_eq!(bytes, 6, "only the 6-byte qw payload re-stages");
        // artifact input order is (a, qw, qs): unchanged handles still hold
        // the shared payloads, the changed one holds the new allocation
        let hosts: Vec<HostTensor> = handles
            .into_iter()
            .map(|h| h.into_parts().0.expect("unstaged handle keeps host"))
            .collect();
        assert!(std::ptr::eq(hosts[0].as_f32().as_ptr(), a.as_ptr()));
        assert!(std::ptr::eq(hosts[1].as_i8().as_ptr(), new_qw.as_ptr()));
        assert!(std::ptr::eq(hosts[2].as_f32().as_ptr(), qs.as_ptr()));

        // precision-mode switch: payload layout differs → full restage
        let bf16 = EngineWeights::Bf16 { flat: Arc::new(vec![0.0f32; 8]) };
        let (handles, bytes) =
            delta_weight_handles(&old_w, weight_handles(&old_w), &bf16);
        assert_eq!(handles.len(), 1);
        assert_eq!(bytes, bf16.byte_len());
    }

    /// Satellite: a double-take must surface as the typed [`KvTakenError`]
    /// (clean worker abort), not a panic (poisoned thread).
    #[test]
    fn kv_double_take_is_typed_error_not_panic() {
        let mut buf = KvBuf::Host(vec![0.0; 4]);
        let mut d2h = 0u64;
        let first = buf.take_handle(&[4], false, &mut d2h);
        assert!(first.is_ok());
        let second = buf.take_handle(&[4], false, &mut d2h);
        let err = second.expect_err("empty cache must error");
        assert!(err.downcast_ref::<KvTakenError>().is_some(),
                "expected KvTakenError, got: {err}");
    }

    #[test]
    fn logits_rows_share_one_block() {
        let block = LogitsBlock::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(block.rows(), 2);
        let a = LogitsRow::new(block.clone(), 0);
        let b = a.clone();
        let c = LogitsRow::new(block.clone(), 1);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        assert_eq!(b.as_slice(), a.as_slice());
        assert_eq!(c.as_slice(), &[3.0, 4.0]);
        // views are the same memory, not copies
        assert!(std::ptr::eq(a.as_slice().as_ptr(), b.as_slice().as_ptr()));
    }

    #[test]
    fn pooled_block_returns_storage_on_drop() {
        let pool = Rc::new(F32Pool::new());
        let block = LogitsBlock::pooled(vec![0.0; 8], 4, pool.clone());
        let row = LogitsRow::new(block.clone(), 1);
        drop(block);
        assert_eq!(pool.free_count(), 0, "live row must keep the block");
        drop(row);
        assert_eq!(pool.free_count(), 1, "last view returns the buffer");
    }

    #[test]
    fn fork_rows_prefix_copies_only_prompt_positions() {
        // tiny layout: L=2, B=3, H=2, S=4, Dh=1
        let dims = (2usize, 3usize, 2usize, 4usize, 1usize);
        let n = 2 * 3 * 2 * 4;
        let src_buf: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let run = |prefix: Option<usize>| {
            let mut buf = src_buf.clone();
            fork_rows(&mut buf, dims, 0, &[2], prefix);
            buf
        };
        let full = run(None);
        let pref = run(Some(2));
        let (l, b, h, s, dh) = dims;
        for layer in 0..l {
            for head in 0..h {
                for p in 0..s {
                    let src = (((layer * b) * h + head) * s + p) * dh;
                    let dst = (((layer * b + 2) * h + head) * s + p) * dh;
                    // full copy: whole row duplicated
                    assert_eq!(full[dst], full[src]);
                    if p < 2 {
                        // prefix copy matches the full copy on prompt rows
                        assert_eq!(pref[dst], full[dst], "prefix row differs");
                    } else {
                        // ...and leaves the tail untouched
                        assert_eq!(pref[dst], src_buf[dst], "tail clobbered");
                    }
                }
            }
        }
        // untouched slots identical in both
        for layer in 0..l {
            for head in 0..h {
                for p in 0..s {
                    let mid = (((layer * b + 1) * h + head) * s + p) * dh;
                    assert_eq!(full[mid], src_buf[mid]);
                    assert_eq!(pref[mid], src_buf[mid]);
                }
            }
        }
    }
}
