//! KV-cache slot allocator: maps active sequences to rows of the batched
//! cache tensors.  Invariants (property-tested): a slot is owned by at most
//! one request; free+active always partitions [0, B); slots are recycled
//! only after release.

#[derive(Clone, Debug)]
pub struct SlotMap {
    free: Vec<usize>,
    owner: Vec<Option<u64>>, // request id per slot
}

impl SlotMap {
    pub fn new(n: usize) -> SlotMap {
        SlotMap { free: (0..n).rev().collect(), owner: vec![None; n] }
    }

    pub fn capacity(&self) -> usize {
        self.owner.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn active_count(&self) -> usize {
        self.capacity() - self.free_count()
    }

    /// Claim a slot for a request; None when full.
    pub fn acquire(&mut self, request_id: u64) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(self.owner[slot].is_none());
        self.owner[slot] = Some(request_id);
        Some(slot)
    }

    /// Release the slot owned by `request_id`.  Panics on double-free or
    /// foreign ownership — those are scheduler bugs.
    pub fn release(&mut self, slot: usize, request_id: u64) {
        assert_eq!(self.owner[slot], Some(request_id),
                   "slot {slot} not owned by request {request_id}");
        self.owner[slot] = None;
        self.free.push(slot);
    }

    pub fn owner_of(&self, slot: usize) -> Option<u64> {
        self.owner[slot]
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.capacity()).filter(|&s| self.owner[s].is_some()).collect()
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> bool {
        let mut seen = vec![false; self.capacity()];
        for &f in &self.free {
            if f >= self.capacity() || seen[f] || self.owner[f].is_some() {
                return false;
            }
            seen[f] = true;
        }
        self.free.len() + self.owner.iter().filter(|o| o.is_some()).count()
            == self.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut sm = SlotMap::new(4);
        let s0 = sm.acquire(10).unwrap();
        let s1 = sm.acquire(11).unwrap();
        assert_ne!(s0, s1);
        assert_eq!(sm.active_count(), 2);
        sm.release(s0, 10);
        assert_eq!(sm.free_count(), 3);
        assert!(sm.check_invariants());
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut sm = SlotMap::new(2);
        assert!(sm.acquire(1).is_some());
        assert!(sm.acquire(2).is_some());
        assert!(sm.acquire(3).is_none());
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut sm = SlotMap::new(2);
        let s = sm.acquire(1).unwrap();
        sm.release(s, 1);
        sm.release(s, 1);
    }

    #[test]
    #[should_panic]
    fn foreign_release_panics() {
        let mut sm = SlotMap::new(2);
        let s = sm.acquire(1).unwrap();
        sm.release(s, 99);
    }
}
