//! KV-cache bookkeeping: the slot map (one sequence per batch row) and the
//! page layer (fixed-size position blocks with refcounted sharing) that
//! admission control and memory accounting run on.
//!
//! # Two allocators, two granularities
//!
//! * [`SlotMap`] — which batch row a sequence occupies.  Invariants
//!   (property-tested): a slot is owned by at most one request; free+active
//!   always partitions `[0, B)`; slots are recycled only after release.
//! * [`PageAllocator`]/[`PageTable`]/[`KvPager`] — which *pages* (runs of
//!   `page_size` cache positions) a sequence holds.  Under
//!   [`KvLayout::Dense`] every admitted sequence reserves one full
//!   `ceil(max_seq / page_size)` worth of pages up front (the flat layout's
//!   true memory cost, made explicit so dense and paged compete under one
//!   budget).  Under [`KvLayout::Paged`] a sequence holds only the pages
//!   its positions actually cover: prefill books the prompt-covering
//!   pages, each decode tick grows the table by at most one page, and
//!   `fork_kv` aliases the source's prompt pages by refcount instead of
//!   allocating — a page is copied ([`PageAllocator::cow`]) only on the
//!   first write into a shared page (copy-on-write).
//!
//! # Page-size / fragmentation trade-off
//!
//! The page is the unit of both waste and sharing.  A sequence's last page
//! is on average half empty, so internal fragmentation wastes
//! `~page_size/2` positions per sequence — small pages waste less and let
//! admission pack more sequences into a fixed budget.  But sharing and
//! CoW work at page granularity too: a forked group aliases
//! `floor(prompt_len / page_size)`-ish whole pages and must CoW the page
//! straddling the prompt boundary, so *smaller* pages also mean more
//! page-table entries, more refcount traffic, and (on a physical paged
//! backend) more gather indirection per attention read.  `page_size = 16`
//! is the conventional sweet spot (vLLM's default block size); the knob is
//! `--kv-page-size` end-to-end so the bench can sweep it.
//!
//! # Logical pages over a dense physical tensor
//!
//! The compiled artifacts pin the physical KV to one dense
//! `[L, B, H, S, Dh]` tensor, so on [`StepEngine`](super::StepEngine) the
//! page layer is the engine's *logical memory model*: it gates admission,
//! measures sharing/CoW, and gives preemption (ROADMAP item 2) a ledger to
//! act on, while the physical fork still copies prefix rows (bit-identical
//! either way — an alias later CoW'd carries exactly the bytes an eager
//! copy would).  [`MockEngine`](super::MockEngine) mirrors the same pager
//! so propcheck proves the allocator invariants artifact-free: no leaks
//! (freed == allocated at drain), no in-place writes to shared pages, and
//! alias/release balance under random cancel/prune interleavings.

/// How engines book KV memory (`--kv dense|paged`).
///
/// `Dense` is the seed layout and the bit-parity oracle: full-sequence
/// reservation per slot.  `Paged` books only covered positions and shares
/// prompt pages across forked siblings.  Token streams are bit-identical
/// across the two — the layout moves memory accounting and admission
/// order, never sampling (property- and integration-tested).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    Dense,
    Paged,
}

impl KvLayout {
    pub fn parse(s: &str) -> Option<KvLayout> {
        match s {
            "dense" => Some(KvLayout::Dense),
            "paged" => Some(KvLayout::Paged),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KvLayout::Dense => "dense",
            KvLayout::Paged => "paged",
        }
    }
}

/// KV layout configuration, threaded from `TrainerConfig` / CLI flags
/// through [`RolloutService`](super::RolloutService) into every engine
/// ([`DecodeEngine::configure_kv`](super::engine::DecodeEngine::configure_kv)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvConfig {
    pub layout: KvLayout,
    /// cache positions per page (the waste/sharing granularity above)
    pub page_size: usize,
    /// total page budget admission is gated on.  `None` (the default) sizes
    /// the budget to one full dense reservation per slot — exactly the
    /// memory the flat layout always held, so the gate can never bind
    /// tighter than the slot map and seed behavior is unchanged.  Tests and
    /// the bench set it lower to compare dense vs paged at equal memory.
    pub budget_pages: Option<usize>,
}

impl Default for KvConfig {
    fn default() -> KvConfig {
        KvConfig { layout: KvLayout::Dense, page_size: 16, budget_pages: None }
    }
}

/// Pages covering `len` positions.
pub fn pages_for(len: usize, page_size: usize) -> usize {
    len.div_ceil(page_size.max(1))
}

/// Drained page-ledger counters + current levels, per engine
/// ([`DecodeEngine::take_kv_stats`](super::engine::DecodeEngine::take_kv_stats)
/// → `SchedulerStats::kv_pages_*` → `sched_kv_pages_*` metric fields).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPageStats {
    /// pages newly acquired since the last drain (delta)
    pub allocated: u64,
    /// pages returned to the free list since the last drain (delta)
    pub freed: u64,
    /// alias grants since the last drain — each is one prompt page a forked
    /// sibling shares instead of allocating (delta)
    pub shared: u64,
    /// copy-on-write copies since the last drain — first writes into a
    /// shared page (delta)
    pub cow: u64,
    /// distinct live pages right now (level, not drained)
    pub active: usize,
    /// maximum of `active` over the engine's lifetime (level, not drained)
    pub high_water: usize,
}

/// Free-list page allocator with per-page refcounts.
///
/// A page is *live* while its refcount is nonzero; `active` counts distinct
/// live pages (aliases share one).  The budget caps *admission*
/// ([`PageAllocator::free_pages`]), not growth: an already-admitted
/// sequence's decode tick and CoW copies allocate unconditionally
/// ([`PageAllocator::acquire_grow`]) so in-flight work can never deadlock
/// on the gate — optimistic admission, with overdraw visible as
/// `high_water > budget`.  Leak accounting: on a drained system
/// `active == 0` and `allocated == freed` (property-tested).
#[derive(Clone, Debug, Default)]
pub struct PageAllocator {
    free: Vec<u32>,
    refs: Vec<u32>,
    budget: usize,
    active: usize,
    high_water: usize,
    allocated: u64,
    freed: u64,
    shared: u64,
    cow: u64,
}

impl PageAllocator {
    pub fn new(budget_pages: usize) -> PageAllocator {
        PageAllocator { budget: budget_pages, ..Default::default() }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Pages admission may still claim (0 once `active` reaches budget).
    pub fn free_pages(&self) -> usize {
        self.budget.saturating_sub(self.active)
    }

    pub fn active_pages(&self) -> usize {
        self.active
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn ref_count(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    pub fn is_shared(&self, page: u32) -> bool {
        self.refs[page as usize] > 1
    }

    /// Allocate one fresh page (refcount 1), growing past the budget if the
    /// free list is dry — see the struct docs for why growth never fails.
    pub fn acquire_grow(&mut self) -> u32 {
        let page = match self.free.pop() {
            Some(p) => p,
            None => {
                self.refs.push(0);
                (self.refs.len() - 1) as u32
            }
        };
        debug_assert_eq!(self.refs[page as usize], 0);
        self.refs[page as usize] = 1;
        self.active += 1;
        self.high_water = self.high_water.max(self.active);
        self.allocated += 1;
        page
    }

    /// Share an existing live page (fork aliasing): refcount bump, no
    /// allocation.
    pub fn alias(&mut self, page: u32) {
        assert!(self.refs[page as usize] > 0, "alias of dead page {page}");
        self.refs[page as usize] += 1;
        self.shared += 1;
    }

    /// Drop one reference; the page returns to the free list when the last
    /// holder releases it.  Panics on a dead page — that is a pager bug.
    pub fn release(&mut self, page: u32) {
        let r = &mut self.refs[page as usize];
        assert!(*r > 0, "release of dead page {page} (double free)");
        *r -= 1;
        if *r == 0 {
            self.free.push(page);
            self.freed += 1;
            self.active -= 1;
        }
    }

    /// Copy-on-write: called on the first write into a page held by more
    /// than one sequence.  The writer trades its alias for a fresh private
    /// page; the original keeps serving the other holders.  Shared pages
    /// are therefore never written in place (property-tested — this is the
    /// only path from a shared page to a writable one).
    pub fn cow(&mut self, page: u32) -> u32 {
        assert!(self.refs[page as usize] > 1,
                "cow of unshared page {page} (plain write suffices)");
        self.refs[page as usize] -= 1;
        self.cow += 1;
        self.acquire_grow()
    }

    /// Non-draining snapshot of counters and levels (tests, bench
    /// columns); [`PageAllocator::take_stats`] is the draining form.
    pub fn peek_stats(&self) -> KvPageStats {
        KvPageStats {
            allocated: self.allocated,
            freed: self.freed,
            shared: self.shared,
            cow: self.cow,
            active: self.active,
            high_water: self.high_water,
        }
    }

    /// Drain the delta counters (allocated/freed/shared/cow), keeping the
    /// levels (`active`, `high_water`) — mirrors how
    /// `SchedulerStats::weight_epoch` survives a stats drain.
    pub fn take_stats(&mut self) -> KvPageStats {
        KvPageStats {
            allocated: std::mem::take(&mut self.allocated),
            freed: std::mem::take(&mut self.freed),
            shared: std::mem::take(&mut self.shared),
            cow: std::mem::take(&mut self.cow),
            active: self.active,
            high_water: self.high_water,
        }
    }

    /// True once every page has been returned: no live refs, and the
    /// lifetime ledger balances (`allocated == freed` — counters drained
    /// mid-run still balance because both drain together).
    pub fn drained(&self) -> bool {
        self.active == 0
            && self.refs.iter().all(|&r| r == 0)
            && self.allocated == self.freed
    }

    /// Internal consistency (used by property tests): the free list holds
    /// exactly the zero-ref pages, without duplicates, and `active` counts
    /// the live ones.
    pub fn check_invariants(&self) -> bool {
        let mut on_free = vec![false; self.refs.len()];
        for &f in &self.free {
            let f = f as usize;
            if f >= self.refs.len() || on_free[f] || self.refs[f] != 0 {
                return false;
            }
            on_free[f] = true;
        }
        let live = self.refs.iter().filter(|&&r| r > 0).count();
        live == self.active
            && self.free.len() + live == self.refs.len()
            && self.allocated == self.freed + self.active as u64
    }
}

/// One sequence's ordered page list: entry `i` backs positions
/// `[i * page_size, (i + 1) * page_size)`.  Pure data — all allocation and
/// refcount traffic goes through the owning [`KvPager`].
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    pages: Vec<u32>,
}

impl PageTable {
    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    pub fn pages(&self) -> &[u32] {
        &self.pages
    }
}

/// Per-engine pager: a [`PageAllocator`] plus one [`PageTable`] per slot,
/// driven from the engine's own call stream (prefill books coverage,
/// decode grows/CoWs, fork aliases, release returns) so the ledger can
/// never drift from what the engine actually executed.  Both
/// [`StepEngine`](super::StepEngine) and
/// [`MockEngine`](super::MockEngine) embed one — the "MockEngine mirrors
/// the same allocator" guarantee is this type being the single
/// implementation.
#[derive(Clone, Debug)]
pub struct KvPager {
    cfg: KvConfig,
    alloc: PageAllocator,
    tables: Vec<Option<PageTable>>,
    max_seq: usize,
}

impl KvPager {
    pub fn new(slots: usize, max_seq: usize, cfg: KvConfig) -> KvPager {
        let full = pages_for(max_seq, cfg.page_size);
        let budget = cfg.budget_pages.unwrap_or(slots * full);
        KvPager {
            cfg,
            alloc: PageAllocator::new(budget),
            tables: vec![None; slots],
            max_seq,
        }
    }

    pub fn config(&self) -> KvConfig {
        self.cfg
    }

    pub fn allocator(&self) -> &PageAllocator {
        &self.alloc
    }

    pub fn table(&self, slot: usize) -> Option<&PageTable> {
        self.tables[slot].as_ref()
    }

    /// One full dense reservation, in pages.
    fn full_pages(&self) -> usize {
        pages_for(self.max_seq, self.cfg.page_size)
    }

    /// Pages admission must find free before starting a sequence whose
    /// first prefill covers `prefill_len` positions.  Dense always costs a
    /// full reservation (fork destinations included — the flat layout
    /// duplicates rows); paged fork destinations cost zero up front (they
    /// alias, then grow/CoW per tick).
    pub fn admit_cost(&self, prefill_len: usize, forked: bool) -> usize {
        match self.cfg.layout {
            KvLayout::Dense => self.full_pages(),
            KvLayout::Paged if forked => 0,
            KvLayout::Paged => pages_for(prefill_len, self.cfg.page_size),
        }
    }

    /// `Some(free pages)` when the admission gate is live — i.e. an
    /// explicit budget was set.  With the default budget the gate can
    /// never bind tighter than the slot map, so `None` lets the scheduler
    /// skip the bookkeeping on the seed-identical path.
    pub fn free_pages_gated(&self) -> Option<usize> {
        self.cfg.budget_pages.map(|_| self.alloc.free_pages())
    }

    /// Book a prefill covering positions `[0, len)` of `slot`.  Any stale
    /// table (a previous occupant that was never released) is returned
    /// first, so the pager self-heals instead of leaking when an engine is
    /// reused across scheduler lifetimes.
    pub fn on_prefill(&mut self, slot: usize, len: usize) {
        self.on_release(slot);
        let n = match self.cfg.layout {
            KvLayout::Dense => self.full_pages(),
            KvLayout::Paged => pages_for(len, self.cfg.page_size),
        };
        let pages = (0..n).map(|_| self.alloc.acquire_grow()).collect();
        self.tables[slot] = Some(PageTable { pages });
    }

    /// Book one decode write at `pos` in `slot`.  Paged: grow the table to
    /// cover `pos` and CoW the target page if it is shared — the returned
    /// page is always exclusively held (the CoW proof hook the property
    /// tests assert on).  Dense: positions were fully reserved at
    /// admission; returns `None`.
    pub fn on_decode(&mut self, slot: usize, pos: usize) -> Option<u32> {
        if self.cfg.layout == KvLayout::Dense {
            if self.tables[slot].is_none() {
                // self-heal: engines driven without a prefill (direct
                // harness use) still keep the ledger balanced
                self.on_prefill(slot, self.max_seq);
            }
            return None;
        }
        let idx = pos / self.cfg.page_size;
        let table = self.tables[slot].get_or_insert_with(PageTable::default);
        while table.pages.len() <= idx {
            table.pages.push(self.alloc.acquire_grow());
        }
        let page = table.pages[idx];
        let page = if self.alloc.is_shared(page) {
            let fresh = self.alloc.cow(page);
            table.pages[idx] = fresh;
            fresh
        } else {
            page
        };
        debug_assert!(!self.alloc.is_shared(page),
                      "shared page {page} about to be written in place");
        Some(page)
    }

    /// Book a KV fork: `dsts` start as copies of `src`'s first
    /// `prompt_len` positions.  Paged destinations alias the covering
    /// pages by refcount; dense destinations pay a full reservation, like
    /// any other dense admission.
    pub fn on_fork(&mut self, src: usize, dsts: &[usize], prompt_len: usize) {
        match self.cfg.layout {
            KvLayout::Dense => {
                for &dst in dsts {
                    self.on_prefill(dst, self.max_seq);
                }
            }
            KvLayout::Paged => {
                let n = pages_for(prompt_len, self.cfg.page_size);
                for &dst in dsts {
                    self.on_release(dst);
                    let shared: Vec<u32> = match &self.tables[src] {
                        Some(t) => {
                            t.pages[..n.min(t.pages.len())].to_vec()
                        }
                        None => Vec::new(),
                    };
                    for &p in &shared {
                        self.alloc.alias(p);
                    }
                    self.tables[dst] = Some(PageTable { pages: shared });
                }
            }
        }
    }

    /// Return every page `slot` holds (sequence finished, cancelled, or
    /// aborted).  Idempotent: releasing an empty slot is a no-op, so the
    /// cancel/prune paths can call it unconditionally.
    pub fn on_release(&mut self, slot: usize) {
        if let Some(t) = self.tables[slot].take() {
            for p in t.pages {
                self.alloc.release(p);
            }
        }
    }

    pub fn take_stats(&mut self) -> KvPageStats {
        self.alloc.take_stats()
    }

    /// Non-draining counter/level snapshot (see
    /// [`PageAllocator::peek_stats`]).
    pub fn peek_stats(&self) -> KvPageStats {
        self.alloc.peek_stats()
    }

    /// All slots empty and the allocator drained — the no-leak invariant.
    pub fn drained(&self) -> bool {
        self.tables.iter().all(|t| t.is_none()) && self.alloc.drained()
    }

    pub fn check_invariants(&self) -> bool {
        let held: u64 = self
            .tables
            .iter()
            .flatten()
            .map(|t| t.pages.len() as u64)
            .sum();
        // every table entry is a live ref; ref totals match table totals
        let refs: u64 =
            self.alloc.refs.iter().map(|&r| u64::from(r)).sum();
        held == refs && self.alloc.check_invariants()
    }
}

/// Maps active sequences to rows of the batched cache tensors.
#[derive(Clone, Debug)]
pub struct SlotMap {
    free: Vec<usize>,
    owner: Vec<Option<u64>>, // request id per slot
}

impl SlotMap {
    pub fn new(n: usize) -> SlotMap {
        SlotMap { free: (0..n).rev().collect(), owner: vec![None; n] }
    }

    pub fn capacity(&self) -> usize {
        self.owner.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn active_count(&self) -> usize {
        self.capacity() - self.free_count()
    }

    /// Claim a slot for a request; None when full.
    pub fn acquire(&mut self, request_id: u64) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(self.owner[slot].is_none());
        self.owner[slot] = Some(request_id);
        Some(slot)
    }

    /// Release the slot owned by `request_id`.  Panics on double-free or
    /// foreign ownership — those are scheduler bugs.
    pub fn release(&mut self, slot: usize, request_id: u64) {
        assert_eq!(self.owner[slot], Some(request_id),
                   "slot {slot} not owned by request {request_id}");
        self.owner[slot] = None;
        self.free.push(slot);
    }

    pub fn owner_of(&self, slot: usize) -> Option<u64> {
        self.owner[slot]
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.capacity()).filter(|&s| self.owner[s].is_some()).collect()
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> bool {
        let mut seen = vec![false; self.capacity()];
        for &f in &self.free {
            if f >= self.capacity() || seen[f] || self.owner[f].is_some() {
                return false;
            }
            seen[f] = true;
        }
        self.free.len() + self.owner.iter().filter(|o| o.is_some()).count()
            == self.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut sm = SlotMap::new(4);
        let s0 = sm.acquire(10).unwrap();
        let s1 = sm.acquire(11).unwrap();
        assert_ne!(s0, s1);
        assert_eq!(sm.active_count(), 2);
        sm.release(s0, 10);
        assert_eq!(sm.free_count(), 3);
        assert!(sm.check_invariants());
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut sm = SlotMap::new(2);
        assert!(sm.acquire(1).is_some());
        assert!(sm.acquire(2).is_some());
        assert!(sm.acquire(3).is_none());
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut sm = SlotMap::new(2);
        let s = sm.acquire(1).unwrap();
        sm.release(s, 1);
        sm.release(s, 1);
    }

    #[test]
    #[should_panic]
    fn foreign_release_panics() {
        let mut sm = SlotMap::new(2);
        let s = sm.acquire(1).unwrap();
        sm.release(s, 99);
    }

    #[test]
    fn layout_parse_roundtrip() {
        for l in [KvLayout::Dense, KvLayout::Paged] {
            assert_eq!(KvLayout::parse(l.name()), Some(l));
        }
        assert_eq!(KvLayout::parse("block"), None);
    }

    #[test]
    fn allocator_alias_cow_lifecycle() {
        let mut a = PageAllocator::new(8);
        let p = a.acquire_grow();
        a.alias(p);
        assert!(a.is_shared(p));
        assert_eq!(a.active_pages(), 1, "alias shares, never allocates");
        let q = a.cow(p);
        assert_ne!(p, q);
        assert!(!a.is_shared(p) && !a.is_shared(q));
        assert_eq!(a.active_pages(), 2);
        a.release(p);
        a.release(q);
        assert!(a.drained());
        let st = a.take_stats();
        assert_eq!((st.allocated, st.freed, st.shared, st.cow), (2, 2, 1, 1));
        assert_eq!(st.high_water, 2);
        assert!(a.check_invariants());
    }

    #[test]
    fn allocator_grows_past_budget_but_gates_admission() {
        let mut a = PageAllocator::new(1);
        let p = a.acquire_grow();
        assert_eq!(a.free_pages(), 0, "budget consumed");
        let q = a.acquire_grow(); // in-flight growth must not deadlock
        assert_eq!(a.active_pages(), 2);
        assert!(a.high_water() > a.budget(), "overdraw is visible");
        a.release(p);
        a.release(q);
        assert!(a.drained());
    }

    #[test]
    #[should_panic]
    fn page_double_free_panics() {
        let mut a = PageAllocator::new(4);
        let p = a.acquire_grow();
        a.release(p);
        a.release(p);
    }

    #[test]
    fn pager_dense_reserves_full_sequences() {
        // max_seq 32, page 8 -> 4 pages per dense sequence
        let mut pg = KvPager::new(2, 32, KvConfig {
            layout: KvLayout::Dense,
            page_size: 8,
            budget_pages: Some(8),
        });
        pg.on_prefill(0, 3); // prompt length is irrelevant under dense
        assert_eq!(pg.allocator().active_pages(), 4);
        assert_eq!(pg.on_decode(0, 3), None, "dense never CoWs");
        pg.on_fork(0, &[1], 3);
        assert_eq!(pg.allocator().active_pages(), 8, "fork dst pays in full");
        assert_eq!(pg.free_pages_gated(), Some(0));
        pg.on_release(0);
        pg.on_release(1);
        assert!(pg.drained());
        assert!(pg.check_invariants());
    }

    #[test]
    fn pager_paged_aliases_and_cows_on_first_write() {
        let mut pg = KvPager::new(2, 32, KvConfig {
            layout: KvLayout::Paged,
            page_size: 4,
            budget_pages: Some(8),
        });
        pg.on_prefill(0, 6); // covers pages 0..2
        assert_eq!(pg.allocator().active_pages(), 2);
        pg.on_fork(0, &[1], 6); // sibling aliases both pages
        assert_eq!(pg.allocator().active_pages(), 2, "alias allocates nothing");
        // first decode write past the prompt lands in shared page 1 -> CoW
        let w = pg.on_decode(1, 6).unwrap();
        assert_eq!(pg.allocator().ref_count(w), 1);
        assert_eq!(pg.allocator().active_pages(), 3);
        // source's own write is now unshared -> in place, no copy
        pg.on_decode(0, 6).unwrap();
        assert_eq!(pg.allocator().active_pages(), 3);
        // growth into a new page
        pg.on_decode(0, 8).unwrap();
        assert_eq!(pg.table(0).unwrap().len(), 3);
        let st_mid = pg.allocator().clone().take_stats();
        assert_eq!((st_mid.shared, st_mid.cow), (2, 1));
        pg.on_release(0);
        pg.on_release(1);
        assert!(pg.drained());
        assert!(pg.check_invariants());
    }

    #[test]
    fn pager_release_is_idempotent() {
        let mut pg = KvPager::new(1, 16, KvConfig {
            layout: KvLayout::Paged,
            page_size: 4,
            budget_pages: Some(4),
        });
        pg.on_prefill(0, 5);
        pg.on_release(0);
        pg.on_release(0); // cancel + abort may both hit the same slot
        assert!(pg.drained());
    }
}
