//! Artifact-free [`DecodeEngine`] for scheduler tests and propcheck runs.
//!
//! Logits are a pure function of the per-slot sequence state (a rolling
//! hash of the tokens fed so far), so a sequence's output is independent of
//! whatever else is co-scheduled — the same isolation contract the real
//! engine provides.  The mock also enforces the engine-side invariants the
//! artifacts would only fail on silently: slot indices in range, decode
//! positions strictly below `max_seq`, and prefill only into distinct slots.
//!
//! Like [`StepEngine`](super::StepEngine), it emits one flat
//! [`LogitsBlock`](super::engine::LogitsBlock) per call with
//! [`LogitsRow`] views into it, recycling block storage through a
//! [`F32Pool`] — so the propcheck suites exercise the same
//! row-view/pooling machinery the production path runs on.

use std::rc::Rc;

use anyhow::Result;

use crate::util::pool::F32Pool;

use super::engine::{DecodeEngine, LogitsBlock, LogitsRow};
use super::kv::{KvConfig, KvPageStats, KvPager};

/// Deterministic in-memory engine: B slots over a tiny vocabulary.
pub struct MockEngine {
    batch: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub eos_id: i32,
    /// rolling per-slot sequence hash (drives the logits)
    state: Vec<u64>,
    /// "weight" signature mixed into every logit; 0 (the default) is the
    /// identity, so unswapped behavior matches the pre-swap_weights engine
    /// bit-for-bit.  [`DecodeEngine::swap_weights`] replaces it — tests
    /// observe a hot requantization as a change in greedy outputs.
    weights: u64,
    /// swap-restage ledger: [`DecodeEngine::swap_weights`] books
    /// `size_of::<u64>()` when the pushed signature differs from the
    /// installed one and nothing when it matches — the mock analogue of
    /// `StepEngine` keeping pointer-equal handles, so the propcheck suites
    /// can assert "zero-change swap ⇒ zero swap h2d" through the full
    /// service/scheduler plumbing
    acc_swap_h2d: u64,
    /// logits-block storage recycler (one block per prefill/decode call)
    pool: Rc<F32Pool>,
    /// bookkeeping the tests assert on
    pub prefill_calls: usize,
    pub prefill_rows: usize,
    pub fork_calls: usize,
    pub forked_slots: usize,
    pub decode_calls: usize,
    pub max_pos_seen: i32,
    /// fail the next N decode calls with an error (worker/tick error-path
    /// tests); each failure consumes one count, so the engine recovers
    pub fail_decodes: usize,
    /// crash-injection for checkpoint-recovery tests: when nonzero, the
    /// decode call that would become call number `fail_at_tick` errors
    /// instead (once — the knob disarms after firing).  Unlike
    /// `fail_decodes` this counts *successful* calls, so a test can say
    /// "die mid-step at tick T" without knowing how many decodes already
    /// ran.  0 = off.
    pub fail_at_tick: usize,
    /// the same page ledger [`StepEngine`](super::StepEngine) embeds,
    /// driven from the same call stream — so propcheck proves the
    /// allocator invariants (no leaks, CoW before shared writes,
    /// alias/release balance) without artifacts
    pager: KvPager,
}

fn mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(27)
}

impl MockEngine {
    /// `eos_id` inside `[0, vocab)` surfaces with probability ~1/vocab per
    /// step; an id outside the vocabulary simply never fires (useful for
    /// forcing ContextLimit in tests).
    pub fn new(batch: usize, vocab: usize, max_seq: usize, eos_id: i32)
               -> MockEngine {
        MockEngine {
            batch,
            vocab,
            max_seq,
            eos_id,
            state: vec![0; batch],
            weights: 0,
            acc_swap_h2d: 0,
            pool: Rc::new(F32Pool::new()),
            prefill_calls: 0,
            prefill_rows: 0,
            fork_calls: 0,
            forked_slots: 0,
            decode_calls: 0,
            max_pos_seen: 0,
            fail_decodes: 0,
            fail_at_tick: 0,
            pager: KvPager::new(batch, max_seq, KvConfig::default()),
        }
    }

    /// Read-only view of the page ledger (propcheck drain/leak asserts).
    pub fn pager(&self) -> &KvPager {
        &self.pager
    }

    /// Append the logits row for a sequence whose rolling hash is `h`,
    /// under the currently installed weight signature.  Greedy-decoding
    /// this stream yields a pseudo-random but fully deterministic token
    /// sequence; EOS surfaces with probability ~1/vocab per step so request
    /// lifetimes vary.
    fn logits_into(&self, h: u64, out: &mut Vec<f32>) {
        out.extend((0..self.vocab).map(|v| {
            (mix(h ^ self.weights, v as u64 + 1) % 1024) as f32 / 1024.0
        }));
    }
}

impl DecodeEngine for MockEngine {
    type Weights = u64;

    fn slot_count(&self) -> usize {
        self.batch
    }

    fn prefill(&mut self, slots: &[usize], prompts: &[&[i32]])
               -> Result<Vec<LogitsRow>> {
        assert_eq!(slots.len(), prompts.len());
        self.prefill_calls += 1;
        self.prefill_rows += slots.len();
        let mut data = self.pool.take(slots.len() * self.vocab);
        for (i, &slot) in slots.iter().enumerate() {
            assert!(slot < self.batch, "prefill into bad slot {slot}");
            assert!(slots[..i].iter().all(|&s| s != slot),
                    "duplicate slot {slot} in one prefill");
            assert!(!prompts[i].is_empty() && prompts[i].len() < self.max_seq,
                    "prompt length {} out of range", prompts[i].len());
            let mut h = 0x51_6d0c;
            for &t in prompts[i] {
                h = mix(h, t as u64);
            }
            self.state[slot] = h;
            self.pager.on_prefill(slot, prompts[i].len());
            self.logits_into(h, &mut data);
        }
        let block = LogitsBlock::pooled(data, self.vocab, self.pool.clone());
        Ok((0..slots.len())
            .map(|i| LogitsRow::new(block.clone(), i))
            .collect())
    }

    fn decode(&mut self, rows: &[(usize, i32, i32)]) -> Result<Vec<LogitsRow>> {
        if self.fail_decodes > 0 {
            self.fail_decodes -= 1;
            anyhow::bail!("injected decode failure (fail_decodes)");
        }
        if self.fail_at_tick > 0 && self.decode_calls + 1 == self.fail_at_tick {
            self.fail_at_tick = 0; // fire once, then the engine recovers
            anyhow::bail!("injected crash at decode tick (fail_at_tick)");
        }
        self.decode_calls += 1;
        assert!(rows.len() <= self.batch, "decode wider than slot count");
        let mut data = self.pool.take(rows.len() * self.vocab);
        for &(slot, pos, tok) in rows {
            assert!(slot < self.batch, "decode into bad slot {slot}");
            assert!(pos >= 0 && (pos as usize) < self.max_seq,
                    "decode position {pos} out of KV range (max_seq {})",
                    self.max_seq);
            self.max_pos_seen = self.max_pos_seen.max(pos);
            self.state[slot] = mix(self.state[slot], tok as u64);
            self.pager.on_decode(slot, pos as usize);
            self.logits_into(self.state[slot], &mut data);
        }
        let block = LogitsBlock::pooled(data, self.vocab, self.pool.clone());
        Ok((0..rows.len())
            .map(|i| LogitsRow::new(block.clone(), i))
            .collect())
    }

    /// Forking the per-slot sequence hash reproduces exactly the state a
    /// fresh prefill of the same prompt would leave, mirroring the real
    /// engine's cache-row copy.  The prompt length is irrelevant here — the
    /// hash *is* the whole prompt state.
    fn fork_kv(&mut self, src_slot: usize, dst_slots: &[usize],
               prompt_len: usize) -> Result<()> {
        assert!(src_slot < self.batch, "fork from bad slot {src_slot}");
        self.fork_calls += 1;
        self.forked_slots += dst_slots.len();
        for &dst in dst_slots {
            assert!(dst < self.batch && dst != src_slot,
                    "fork into bad slot {dst}");
            self.state[dst] = self.state[src_slot];
        }
        self.pager.on_fork(src_slot, dst_slots, prompt_len);
        Ok(())
    }

    /// Swap the weight signature; per-slot sequence state survives, exactly
    /// like the real engine's KV caches survive a hot requantization.  A
    /// signature that differs from the installed one books its size on the
    /// swap-restage ledger; an identical one books nothing (the mock's
    /// zero-change delta swap).
    fn swap_weights(&mut self, w: u64, _epoch: u64) {
        if w != self.weights {
            self.acc_swap_h2d += std::mem::size_of::<u64>() as u64;
        }
        self.weights = w;
    }

    fn take_swap_h2d(&mut self) -> u64 {
        std::mem::take(&mut self.acc_swap_h2d)
    }

    fn configure_kv(&mut self, cfg: KvConfig) {
        self.pager = KvPager::new(self.batch, self.max_seq, cfg);
    }

    fn release_kv(&mut self, slot: usize) {
        self.pager.on_release(slot);
    }

    fn kv_admit_cost(&self, prefill_len: usize, forked: bool) -> usize {
        self.pager.admit_cost(prefill_len, forked)
    }

    fn kv_free_pages(&self) -> Option<usize> {
        self.pager.free_pages_gated()
    }

    fn take_kv_stats(&mut self) -> KvPageStats {
        self.pager.take_stats()
    }
}
