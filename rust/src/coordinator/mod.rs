//! L3 coordinator: the serving-style rollout path (vLLM-router-shaped).
//!
//! Two execution paths exist for rollouts:
//! * **bulk** — the fused `generate_*` artifacts (prefill + scan decode +
//!   sampling inside one HLO module); the training loop uses this, zero
//!   per-token host round-trips;
//! * **step-wise** — [`StepEngine`] + [`Scheduler`]: continuous batching
//!   over per-step prefill/decode artifacts with host-side sampling; this
//!   is the serving demo (latency/throughput/occupancy metrics) and the
//!   cross-validation target for the bulk path.

pub mod engine;
pub mod kv;
pub mod request;
pub mod sampler;
pub mod scheduler;

pub use engine::StepEngine;
pub use kv::SlotMap;
pub use request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
pub use scheduler::Scheduler;
