//! L3 coordinator: the serving-style rollout path (vLLM-router-shaped).
//!
//! Two execution paths exist for rollouts:
//! * **bulk** — the fused `generate_*` artifacts (prefill + scan decode +
//!   sampling inside one HLO module); every wave pays the fused scan's
//!   full trip count, so mixed-length batches wait for their longest
//!   member;
//! * **step-wise** — the layered serving stack the trainer's
//!   `--rollout-path scheduler` and `qurl serve` run on:
//!
//! ```text
//! rl::Trainer ── GroupSpec ──▶ RolloutService            (service.rs)
//!   │                            │ groups, rewards, in-flight pruning,
//!   │ requantize:                │ placement: --stripe
//!   │ push_weights(W)            │   rr|least-loaded|replay
//!   │ ──▶ WeightEpoch++          │ work stealing: --steal off|idle
//!   │                            │   (idle replica pulls whole queued
//!   │                            │   groups off the most-loaded one;
//!   │                            │   every move → PlacementLog, and
//!   │                            │   replay re-executes any log)
//!   │                            │ kv/chunk config fan-out: set_kv(),
//!   │                            │ set_prefill_chunk()
//!   │                            ├─ cmd chan ──▶ worker thread 0
//!   │   commands: Submit(group)  │               owns: Runtime (own PJRT
//!   │     Cancel(uid)            │               client), DecodeEngine,
//!   │     SwapWeights(W, epoch)  │               Scheduler  (scheduler.rs)
//!   │     Configure{min_prefill, │                 │ FIFO queue → KV slots,
//!   │       share_prefix, kv,    │                 │ page-gated admission,
//!   │       prefill_chunk}       │                 │ shared-prefix prefill
//!   │     TakeStats / AbortAll   │                 │ (fork_kv), chunked
//!   │     Steal{thief, groups}   │                 │ prefill, lockstep
//!   │                            │                 │ decode, cancel(),
//!   │   events: Finished(result) │                 │ swap_weights(),
//!   │     CancelOutcome, Stats,  │                 │ extract_queued()
//!   │     TickError, Aborted,    │                 │ (whole-group un-admit
//!   │     Idle, Stolen{reqs}     │                 │  for the steal path)
//!   │                            │                 ├──▶ DecodeEngine
//!   │                            │                 │     (engine.rs)
//!   │                            │                 │      │ books every
//!   │                            │                 │      │ prefill/decode/
//!   │                            │                 │      │ fork/release in
//!   │                            │                 │      ▼
//!   │                            │                 └──  KvPager   (kv.rs)
//!   │                            │                      PageAllocator:
//!   │                            │                      free list+refcounts,
//!   │                            │                      alias/CoW, budget
//!   │                            │                      gate, leak ledger
//!   │                            ├─ cmd chan ──▶ worker thread 1 ─▶ ...
//!   │                            │
//!   │                            └─ inline backend: same schedulers,
//!   │                               ticked round-robin on this thread
//!   ▼                              (reference semantics, parity-tested)
//! GroupResults (submission order, bit-identical across backends
//!               AND across --kv dense|paged — the dense oracle)
//! ```
//!
//! The [`Scheduler`] stays a request-level primitive: continuous batching
//! over per-step prefill/decode artifacts with host-side sampling, where
//! early-finished (or cancelled) sequences free their KV slot immediately
//! and queued requests backfill it.  [`RolloutService`] adds the RL-aware
//! layer on top — it understands *groups*, scores members as they finish,
//! prunes decided groups mid-flight (issuing cross-thread cancel
//! directives on the threaded backend), places groups across replicas per
//! [`StripePolicy`], and hot-swaps freshly requantized weights into live
//! engines ([`RolloutService::push_weights`] → [`WeightEpoch`]) instead of
//! tearing replicas down.
//!
//! Steal/replay flow ([`StealPolicy::Idle`]): a replica with free slots
//! and an empty queue announces itself (`Idle` event; the inline backend
//! checks the same predicate each round), the service picks the victim
//! with the most live outstanding tokens (shared atomics the schedulers
//! publish) and probes it (`Steal` command); the victim extracts the
//! first candidate group whose members are *all* still queued
//! ([`Scheduler::extract_queued`], all-or-nothing so `fork_kv` prefix
//! sharing stays intra-engine) and replies (`Stolen` event) with the
//! requests, which the service re-submits to the thief.  Every placement
//! and steal is appended to the [`PlacementLog`];
//! [`StripePolicy::Replay`] re-executes a recorded log, making a stolen
//! run reproducible bit-for-bit even though stealing itself reads live
//! timing.
//!
//! Threading model: PJRT clients, compiled executables and the artifact
//! cache are **not `Send`**, so the threaded backend never moves an engine
//! across threads — each worker runs an [`EngineFactory`] *inside* its
//! thread (for [`StepEngine`] that opens a private `Runtime`) and only
//! plain data (requests, weights, results, stats) crosses the channels.
//! [`MockEngine`] workers are plain values and exercise the same machinery
//! in the host-only test suites.
//!
//! # Residency boundary on the serving hot path
//!
//! What converts/copies when (measured end-to-end as the
//! `sched_bytes_h2d`/`sched_bytes_d2h` metrics; the tiers are defined in
//! [`runtime`](crate::runtime)):
//!
//! * **per weight epoch** — engine weights.  [`StepEngine`] holds them as
//!   resident input handles; [`DecodeEngine::swap_weights`] (driven by
//!   [`RolloutService::push_weights`] → `WeightEpoch`) installs new ones
//!   and the next call stages them exactly once.  Decode ticks between
//!   swaps stage **zero** weight bytes.  The change signal inside a swap
//!   is `Arc` pointer equality: `Runtime::engine_weights_delta` clones
//!   the previous epoch's payload `Arc` for every tensor that requantized
//!   bit-identically, `swap_weights` keeps the resident handle (cached
//!   conversion included) for every pointer-equal payload, and only the
//!   remainder re-stages (`sched_swap_bytes_h2d`).  Pointer-unequal but
//!   bytewise-equal payloads re-stage too — the conservative direction;
//!   stale bytes stay unrepresentable.
//! * **never (steady-state decode)** — the `[L,B,H,S,Dh]` KV caches flow
//!   decode-output → decode-input as raw device-format literals.
//! * **per admission boundary** — prefill/`fork_kv` mutate cache rows, so
//!   KV materializes to host vectors there and re-stages on the next
//!   decode; `fork_kv` copies only the `prompt_len` prefix per head
//!   (causal masking makes that bit-identical to a full-row copy —
//!   artifact-parity tested).
//! * **per tick** — only the `[B]` position/token control vectors (h2d)
//!   and one flat logits block (d2h).  Sequences hold [`LogitsRow`] views
//!   into the shared block instead of per-slot copies; prompts ride one
//!   `Arc` per group from `submit_group` into the engine.
//!
//! Greedy decode through the whole stack is bit-identical to the bulk path
//! (integration-tested, including fork_kv prefill), outputs are
//! bit-identical across inline/threaded execution and stripe policies
//! (property-tested), and bit-identical between the resident and per-call
//! input paths across a mid-run weight swap (integration-tested) —
//! residency, placement and thread interleaving change wall-clock and
//! copy-bytes, never learning.
//!
//! # Checkpoint/resume boundary
//!
//! The service participates in crash-safe checkpoints
//! ([`rl::checkpoint`](crate::rl::checkpoint)) through three calls, all
//! legal only between runs: [`RolloutService::snapshot`] captures the
//! cross-run state (uid allocators, placement cursor, load estimates,
//! [`WeightEpoch`], the full [`PlacementLog`]) as a [`ServiceSnapshot`];
//! [`RolloutService::restore`] installs one on a freshly built service;
//! and [`RolloutService::reissue_weights`] stamps the rebuilt engines
//! with the restored epoch (a swap at the *current* counter, where
//! [`RolloutService::push_weights`] would bump it).  Everything else the
//! service holds is either drained per step (`take_stats`), empty
//! between runs (group ledgers), or configuration re-derived from the
//! fingerprinted `TrainerConfig` — see [`ServiceSnapshot`] for the full
//! captured/not-captured inventory.

pub mod engine;
pub mod kv;
pub mod mock;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod service;

pub use engine::{DecodeEngine, KvTakenError, LogitsBlock, LogitsRow, StepEngine};
pub use kv::{pages_for, KvConfig, KvLayout, KvPageStats, KvPager,
             PageAllocator, PageTable, SlotMap};
pub use mock::MockEngine;
pub use request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
pub use scheduler::Scheduler;
pub use service::{EngineFactory, GroupMember, GroupResult, GroupSpec,
                  OutstandingGroupsError, PlacementLog, PlacementReason,
                  PlacementRecord, PrunePolicy, RolloutService,
                  ServiceSnapshot, StealPolicy, StripePolicy, WeightEpoch};
