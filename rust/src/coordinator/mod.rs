//! L3 coordinator: the serving-style rollout path (vLLM-router-shaped).
//!
//! Two execution paths exist for rollouts:
//! * **bulk** — the fused `generate_*` artifacts (prefill + scan decode +
//!   sampling inside one HLO module); every wave pays the fused scan's
//!   full trip count, so mixed-length batches wait for their longest
//!   member;
//! * **step-wise** — [`StepEngine`] + [`Scheduler`]: continuous batching
//!   over per-step prefill/decode artifacts with host-side sampling.
//!   Early-finished sequences free their KV slot immediately and queued
//!   requests backfill it, which is why the trainer can route its rollouts
//!   here (`TrainerConfig::rollout_path = Scheduler`); greedy decode is
//!   bit-identical to the bulk path (integration-tested), making the two
//!   paths interchangeable serving backends.

pub mod engine;
pub mod kv;
pub mod mock;
pub mod request;
pub mod sampler;
pub mod scheduler;

pub use engine::{DecodeEngine, StepEngine};
pub use kv::SlotMap;
pub use mock::MockEngine;
pub use request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
pub use scheduler::Scheduler;
