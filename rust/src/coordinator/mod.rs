//! L3 coordinator: the serving-style rollout path (vLLM-router-shaped).
//!
//! Two execution paths exist for rollouts:
//! * **bulk** — the fused `generate_*` artifacts (prefill + scan decode +
//!   sampling inside one HLO module); every wave pays the fused scan's
//!   full trip count, so mixed-length batches wait for their longest
//!   member;
//! * **step-wise** — the layered serving stack the trainer's
//!   `--rollout-path scheduler` and `qurl serve` run on:
//!
//! ```text
//! rl::Trainer ── GroupSpec ──▶ RolloutService      (service.rs)
//!                                │  groups, rewards, in-flight pruning,
//!                                │  round-robin striping over engines
//!                                ├──▶ Scheduler #0  (scheduler.rs)
//!                                │     │  FIFO queue → KV slots, batched
//!                                │     │  shared-prefix prefill (fork_kv),
//!                                │     │  lockstep decode, cancel()
//!                                │     └──▶ DecodeEngine (engine.rs)
//!                                │            StepEngine: PJRT artifacts
//!                                │            MockEngine: propcheck stand-in
//!                                └──▶ Scheduler #1 ──▶ DecodeEngine ...
//! ```
//!
//! The [`Scheduler`] stays a request-level primitive: continuous batching
//! over per-step prefill/decode artifacts with host-side sampling, where
//! early-finished (or cancelled) sequences free their KV slot immediately
//! and queued requests backfill it.  [`RolloutService`] adds the RL-aware
//! layer on top — it understands *groups*, scores members as they finish,
//! prunes decided groups mid-flight, and stripes groups across several
//! engines behind one submission interface.  Greedy decode through the
//! whole stack is bit-identical to the bulk path (integration-tested,
//! including fork_kv prefill), making the paths interchangeable serving
//! backends.

pub mod engine;
pub mod kv;
pub mod mock;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod service;

pub use engine::{DecodeEngine, StepEngine};
pub use kv::SlotMap;
pub use mock::MockEngine;
pub use request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
pub use scheduler::Scheduler;
pub use service::{GroupMember, GroupResult, GroupSpec, PrunePolicy,
                  RolloutService};
