//! Rollout request/response types for the serving-style scheduler.

use std::sync::Arc;

/// A generation request, vLLM-router style.
#[derive(Clone, Debug)]
pub struct RolloutRequest {
    pub id: u64,
    /// prompt token ids (BOS included), length <= max_prompt.  `Arc`'d so
    /// a group's members share one allocation all the way from
    /// `RolloutService::submit_group` into the scheduler — admission's
    /// shared-prefix clustering resolves siblings by pointer identity and
    /// the engine reads tokens in place, with no per-member prompt clones.
    pub prompt: Arc<Vec<i32>>,
    /// stop after this many generated tokens (EOS may stop earlier)
    pub max_new: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// per-request sampling seed (deterministic replay)
    pub seed: u64,
}

/// Why a sequence stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxNew,
    ContextLimit,
    /// Cancelled in flight via [`Scheduler::cancel`](super::Scheduler::cancel)
    /// (online rollout pruning).  Cancelled requests never surface in the
    /// scheduler's completion results; this reason only appears on the
    /// partial [`RolloutResult`] that `cancel` itself returns, which the
    /// [`RolloutService`](super::RolloutService) records as the member's
    /// outcome.
    Cancelled,
}

/// A completed rollout.
#[derive(Clone, Debug)]
pub struct RolloutResult {
    pub id: u64,
    /// generated token ids (EOS inclusive when present)
    pub generated: Vec<i32>,
    /// behavior logprob per generated token
    pub logprobs: Vec<f32>,
    pub finish: FinishReason,
    /// scheduler bookkeeping (seconds)
    pub queue_wait_s: f64,
    pub service_s: f64,
}

/// Scheduler-level counters for the throughput/latency report.  The trainer
/// merges these into its per-step `Recorder` rows (`sched_*` fields) when
/// rollouts run through the scheduler path.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub submitted: usize,
    pub completed: usize,
    /// requests removed in flight by [`Scheduler::cancel`]; on a drained
    /// scheduler `completed + cancelled == submitted` (property-tested)
    pub cancelled: usize,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    /// rows actually prefilled (post prefix-sharing); mean prefill batch
    /// size is `prefill_rows / prefill_calls`
    pub prefill_rows: usize,
    /// slots whose prompt KV was forked from a sibling instead of
    /// prefilled — each is one prefill row saved by prefix sharing
    pub forked: usize,
    pub decode_calls: usize,
    pub generated_tokens: usize,
    /// groups whose in-flight remainder was cancelled by the service's
    /// prune policy (bumped by [`RolloutService`], not the scheduler)
    pub pruned_groups: usize,
    /// bytes newly converted host→device-format across this scheduler's
    /// artifact calls (drained from
    /// [`DecodeEngine::take_transfer`](super::engine::DecodeEngine::take_transfer)
    /// on `Scheduler::take_stats`).  Resident inputs riding a cached
    /// conversion — weights between swaps, recycled KV literals — count
    /// zero, so on the resident path this collapses to per-tick control
    /// tensors plus admission-boundary KV staging; the per-call baseline
    /// pays weights + both KV caches every tick.  Mock engines report 0.
    pub bytes_h2d: u64,
    /// bytes copied device-format→host (logits each call; KV only when it
    /// must materialize for a row merge or fork)
    pub bytes_d2h: u64,
    /// weight bytes swaps scheduled for re-staging (drained from
    /// [`DecodeEngine::take_swap_h2d`](super::engine::DecodeEngine::take_swap_h2d)
    /// on `Scheduler::take_stats`): the payloads `swap_weights` replaced
    /// because they were not pointer-identical to the installed weights.
    /// Under delta requantization this is the change-proportional swap
    /// cost — a refresh whose tensors all requantized bit-identically
    /// drains 0 here even though a swap happened.
    pub swap_bytes_h2d: u64,
    /// manifest tensors whose requantized payload differed from the
    /// previous epoch's (bumped by the trainer's delta refresh, not the
    /// scheduler)
    pub requant_tensors_changed: usize,
    /// manifest tensors whose requantized payload came out bit-identical
    /// and was reused `Arc`-for-`Arc` — the paper's "quantization masks
    /// nearly all weight updates" effect, counted per refresh
    pub requant_tensors_skipped: usize,
    /// chunked-prefill work units: truncated prefill calls plus
    /// chunk-continuation decode rounds (0 when `prefill_chunk` is off)
    pub prefill_chunks: usize,
    /// KV pages newly acquired (drained from the engine's
    /// [`KvPager`](super::kv::KvPager) on `take_stats`)
    pub kv_pages_allocated: u64,
    /// KV pages returned to the free list — on a drained scheduler
    /// `kv_pages_freed == kv_pages_allocated` (no leaks; property-tested)
    pub kv_pages_freed: u64,
    /// prompt pages forked siblings alias instead of allocating
    pub kv_pages_shared: u64,
    /// copy-on-write page copies (first write into a shared page)
    pub kv_pages_cow: u64,
    /// distinct live KV pages at the last stats drain.  A *level* like
    /// `weight_epoch`: merging takes the max (per-replica truth lives in
    /// the `sched_e{i}_kv_pages_active` row fields) and `take_stats`
    /// preserves it across drains.
    pub kv_pages_active: usize,
    /// lifetime maximum of `kv_pages_active` (page-pressure high-water
    /// mark; same level semantics as above).  Above the configured budget
    /// = admission overdraw from in-flight growth.
    pub kv_pages_high_water: usize,
    /// whole queued groups this engine received through work stealing
    /// (bumped on the thief's side by
    /// [`RolloutService`](super::RolloutService), not the scheduler)
    pub steals: usize,
    /// decode ticks this replica sat out while the busiest replica of its
    /// drain still worked (`max_j decode_steps - decode_steps_i`, folded
    /// in by `RolloutService::take_stats`) — the starvation/straggler gap
    /// work stealing exists to close
    pub idle_ticks: usize,
    /// sum over decode calls of occupied-slot fraction
    pub occupancy_sum: f64,
    /// sum over completed requests of time spent queued before prefill
    pub queue_wait_sum_s: f64,
    pub wall_s: f64,
    /// weight generation the engine currently decodes with (the service's
    /// [`WeightEpoch`](super::service::WeightEpoch) counter at the last
    /// [`Scheduler::swap_weights`]); 0 = the weights the engine was built
    /// with.  A *level*, not a delta: merging takes the max, and
    /// [`Scheduler::take_stats`] preserves it across drains.
    pub weight_epoch: u64,
}

impl SchedulerStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_calls as f64
        }
    }

    /// Mean rows per prefill call (the dynamic-batching health metric the
    /// `--min-prefill-batch` knob steers).  0.0 on a step with no prefill
    /// calls — a pure-decode or fully-pruned wave must not divide by zero
    /// (pinned by `derived_stats_guard_zero_denominators`).
    pub fn mean_prefill_batch(&self) -> f64 {
        if self.prefill_calls == 0 {
            0.0
        } else {
            self.prefill_rows as f64 / self.prefill_calls as f64
        }
    }

    /// `bytes_h2d / decode_calls` — the per-tick staging tax the resident
    /// path collapses.  0.0 on a step with no decode calls (pure-prefill
    /// or fully-pruned wave), guarded like [`Self::mean_prefill_batch`]
    /// and pinned by the same unit test; the trainer's
    /// `sched_h2d_per_decode` row field reads this method so the guard
    /// has a single definition.
    pub fn h2d_per_decode(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.bytes_h2d as f64 / self.decode_calls as f64
        }
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_wait_sum_s / self.completed as f64
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    /// Max/min load-imbalance ratio across engine replicas, measured on
    /// decode ticks actually executed (the per-replica stats of one
    /// drain).  1.0 = perfectly balanced; the denominator floors at one
    /// tick so a fully idle replica yields a large finite ratio, never
    /// inf/NaN (these feed Recorder rows).
    pub fn load_imbalance(per: &[SchedulerStats]) -> f64 {
        let max = per.iter().map(|s| s.decode_steps).max().unwrap_or(0);
        if max == 0 {
            return 1.0;
        }
        let min = per.iter().map(|s| s.decode_steps).min().unwrap_or(0);
        max as f64 / min.max(1) as f64
    }

    /// Accumulate another scheduler run's counters (the trainer may drive
    /// several scheduler runs per RL step under DAPO resampling).
    pub fn merge(&mut self, other: &SchedulerStats) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.decode_steps += other.decode_steps;
        self.prefill_calls += other.prefill_calls;
        self.prefill_rows += other.prefill_rows;
        self.forked += other.forked;
        self.decode_calls += other.decode_calls;
        self.generated_tokens += other.generated_tokens;
        self.pruned_groups += other.pruned_groups;
        self.bytes_h2d += other.bytes_h2d;
        self.bytes_d2h += other.bytes_d2h;
        self.swap_bytes_h2d += other.swap_bytes_h2d;
        self.requant_tensors_changed += other.requant_tensors_changed;
        self.requant_tensors_skipped += other.requant_tensors_skipped;
        self.prefill_chunks += other.prefill_chunks;
        self.kv_pages_allocated += other.kv_pages_allocated;
        self.kv_pages_freed += other.kv_pages_freed;
        self.kv_pages_shared += other.kv_pages_shared;
        self.kv_pages_cow += other.kv_pages_cow;
        self.steals += other.steals;
        self.idle_ticks += other.idle_ticks;
        // levels, not deltas — see the field docs
        self.kv_pages_active = self.kv_pages_active.max(other.kv_pages_active);
        self.kv_pages_high_water =
            self.kv_pages_high_water.max(other.kv_pages_high_water);
        self.occupancy_sum += other.occupancy_sum;
        self.queue_wait_sum_s += other.queue_wait_sum_s;
        self.wall_s += other.wall_s;
        self.weight_epoch = self.weight_epoch.max(other.weight_epoch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_bytes_and_maxes_epoch() {
        let mut a = SchedulerStats {
            bytes_h2d: 100,
            bytes_d2h: 10,
            swap_bytes_h2d: 64,
            requant_tensors_changed: 2,
            requant_tensors_skipped: 20,
            weight_epoch: 3,
            ..Default::default()
        };
        let b = SchedulerStats {
            bytes_h2d: 7,
            bytes_d2h: 2,
            swap_bytes_h2d: 8,
            requant_tensors_changed: 1,
            requant_tensors_skipped: 21,
            weight_epoch: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!((a.bytes_h2d, a.bytes_d2h), (107, 12));
        assert_eq!(a.swap_bytes_h2d, 72,
                   "swap restage bytes are a counter, merge sums them");
        assert_eq!((a.requant_tensors_changed, a.requant_tensors_skipped),
                   (3, 41));
        assert_eq!(a.weight_epoch, 3, "epoch is a level, merge takes max");
    }

    #[test]
    fn merge_sums_page_deltas_and_maxes_levels() {
        let mut a = SchedulerStats {
            kv_pages_allocated: 10,
            kv_pages_freed: 8,
            kv_pages_shared: 3,
            kv_pages_cow: 1,
            kv_pages_active: 2,
            kv_pages_high_water: 9,
            prefill_chunks: 2,
            ..Default::default()
        };
        let b = SchedulerStats {
            kv_pages_allocated: 5,
            kv_pages_freed: 5,
            kv_pages_shared: 1,
            kv_pages_cow: 2,
            kv_pages_active: 4,
            kv_pages_high_water: 6,
            prefill_chunks: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!((a.kv_pages_allocated, a.kv_pages_freed), (15, 13));
        assert_eq!((a.kv_pages_shared, a.kv_pages_cow), (4, 3));
        assert_eq!(a.prefill_chunks, 3);
        assert_eq!((a.kv_pages_active, a.kv_pages_high_water), (4, 9),
                   "page levels merge by max, like weight_epoch");
    }

    #[test]
    fn merge_sums_steals_and_idle_ticks() {
        let mut a = SchedulerStats {
            steals: 2,
            idle_ticks: 5,
            ..Default::default()
        };
        let b = SchedulerStats {
            steals: 1,
            idle_ticks: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!((a.steals, a.idle_ticks), (3, 12),
                   "steals/idle_ticks are counters, merge sums them");
    }

    /// Imbalance ratio: balanced replicas score 1.0, a starved replica
    /// inflates the ratio, and the degenerate cases (no replicas, no
    /// decode work, a fully idle replica) stay finite.
    #[test]
    fn load_imbalance_ratio_guards_degenerate_cases() {
        let ticks = |n: usize| SchedulerStats {
            decode_steps: n,
            ..Default::default()
        };
        assert_eq!(SchedulerStats::load_imbalance(&[]), 1.0);
        assert_eq!(SchedulerStats::load_imbalance(&[ticks(0), ticks(0)]),
                   1.0);
        assert_eq!(SchedulerStats::load_imbalance(&[ticks(6), ticks(6)]),
                   1.0);
        assert_eq!(SchedulerStats::load_imbalance(&[ticks(9), ticks(3)]),
                   3.0);
        let starved =
            SchedulerStats::load_imbalance(&[ticks(40), ticks(0)]);
        assert!(starved.is_finite() && starved >= 40.0,
                "idle replica must inflate, not poison, the ratio");
    }

    /// Satellite: zero-denominator steps (pure-decode waves have no
    /// prefill calls; pure-prefill or fully-pruned waves have no decode
    /// calls) must yield 0.0, not NaN/inf — these feed Recorder rows and
    /// a NaN would poison every downstream tail_mean.
    #[test]
    fn derived_stats_guard_zero_denominators() {
        let empty = SchedulerStats::default();
        assert_eq!(empty.mean_prefill_batch(), 0.0);
        assert_eq!(empty.h2d_per_decode(), 0.0);
        assert_eq!(empty.mean_occupancy(), 0.0);
        assert_eq!(empty.mean_queue_wait_s(), 0.0);
        assert_eq!(empty.tokens_per_s(), 0.0);
        let pure_decode = SchedulerStats {
            decode_calls: 4,
            bytes_h2d: 64,
            ..Default::default()
        };
        assert_eq!(pure_decode.mean_prefill_batch(), 0.0);
        assert_eq!(pure_decode.h2d_per_decode(), 16.0);
        let pure_prefill = SchedulerStats {
            prefill_calls: 2,
            prefill_rows: 6,
            bytes_h2d: 64,
            ..Default::default()
        };
        assert_eq!(pure_prefill.h2d_per_decode(), 0.0);
        assert_eq!(pure_prefill.mean_prefill_batch(), 3.0);
        assert!(pure_decode.h2d_per_decode().is_finite()
                && pure_prefill.mean_prefill_batch().is_finite());
    }
}
