//! Rollout request/response types for the serving-style scheduler.

use std::sync::Arc;

/// A generation request, vLLM-router style.
#[derive(Clone, Debug)]
pub struct RolloutRequest {
    pub id: u64,
    /// prompt token ids (BOS included), length <= max_prompt.  `Arc`'d so
    /// a group's members share one allocation all the way from
    /// `RolloutService::submit_group` into the scheduler — admission's
    /// shared-prefix clustering resolves siblings by pointer identity and
    /// the engine reads tokens in place, with no per-member prompt clones.
    pub prompt: Arc<Vec<i32>>,
    /// stop after this many generated tokens (EOS may stop earlier)
    pub max_new: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// per-request sampling seed (deterministic replay)
    pub seed: u64,
}

/// Why a sequence stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxNew,
    ContextLimit,
    /// Cancelled in flight via [`Scheduler::cancel`](super::Scheduler::cancel)
    /// (online rollout pruning).  Cancelled requests never surface in the
    /// scheduler's completion results; this reason only appears on the
    /// partial [`RolloutResult`] that `cancel` itself returns, which the
    /// [`RolloutService`](super::RolloutService) records as the member's
    /// outcome.
    Cancelled,
}

/// A completed rollout.
#[derive(Clone, Debug)]
pub struct RolloutResult {
    pub id: u64,
    /// generated token ids (EOS inclusive when present)
    pub generated: Vec<i32>,
    /// behavior logprob per generated token
    pub logprobs: Vec<f32>,
    pub finish: FinishReason,
    /// scheduler bookkeeping (seconds)
    pub queue_wait_s: f64,
    pub service_s: f64,
}

/// Scheduler-level counters for the throughput/latency report.  The trainer
/// merges these into its per-step `Recorder` rows (`sched_*` fields) when
/// rollouts run through the scheduler path.
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    pub submitted: usize,
    pub completed: usize,
    /// requests removed in flight by [`Scheduler::cancel`]; on a drained
    /// scheduler `completed + cancelled == submitted` (property-tested)
    pub cancelled: usize,
    pub decode_steps: usize,
    pub prefill_calls: usize,
    /// rows actually prefilled (post prefix-sharing); mean prefill batch
    /// size is `prefill_rows / prefill_calls`
    pub prefill_rows: usize,
    /// slots whose prompt KV was forked from a sibling instead of
    /// prefilled — each is one prefill row saved by prefix sharing
    pub forked: usize,
    pub decode_calls: usize,
    pub generated_tokens: usize,
    /// groups whose in-flight remainder was cancelled by the service's
    /// prune policy (bumped by [`RolloutService`], not the scheduler)
    pub pruned_groups: usize,
    /// bytes newly converted host→device-format across this scheduler's
    /// artifact calls (drained from
    /// [`DecodeEngine::take_transfer`](super::engine::DecodeEngine::take_transfer)
    /// on `Scheduler::take_stats`).  Resident inputs riding a cached
    /// conversion — weights between swaps, recycled KV literals — count
    /// zero, so on the resident path this collapses to per-tick control
    /// tensors plus admission-boundary KV staging; the per-call baseline
    /// pays weights + both KV caches every tick.  Mock engines report 0.
    pub bytes_h2d: u64,
    /// bytes copied device-format→host (logits each call; KV only when it
    /// must materialize for a row merge or fork)
    pub bytes_d2h: u64,
    /// sum over decode calls of occupied-slot fraction
    pub occupancy_sum: f64,
    /// sum over completed requests of time spent queued before prefill
    pub queue_wait_sum_s: f64,
    pub wall_s: f64,
    /// weight generation the engine currently decodes with (the service's
    /// [`WeightEpoch`](super::service::WeightEpoch) counter at the last
    /// [`Scheduler::swap_weights`]); 0 = the weights the engine was built
    /// with.  A *level*, not a delta: merging takes the max, and
    /// [`Scheduler::take_stats`] preserves it across drains.
    pub weight_epoch: u64,
}

impl SchedulerStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_calls == 0 {
            0.0
        } else {
            self.occupancy_sum / self.decode_calls as f64
        }
    }

    /// Mean rows per prefill call (the dynamic-batching health metric the
    /// `--min-prefill-batch` knob steers).
    pub fn mean_prefill_batch(&self) -> f64 {
        if self.prefill_calls == 0 {
            0.0
        } else {
            self.prefill_rows as f64 / self.prefill_calls as f64
        }
    }

    pub fn mean_queue_wait_s(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_wait_sum_s / self.completed as f64
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    /// Accumulate another scheduler run's counters (the trainer may drive
    /// several scheduler runs per RL step under DAPO resampling).
    pub fn merge(&mut self, other: &SchedulerStats) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.decode_steps += other.decode_steps;
        self.prefill_calls += other.prefill_calls;
        self.prefill_rows += other.prefill_rows;
        self.forked += other.forked;
        self.decode_calls += other.decode_calls;
        self.generated_tokens += other.generated_tokens;
        self.pruned_groups += other.pruned_groups;
        self.bytes_h2d += other.bytes_h2d;
        self.bytes_d2h += other.bytes_d2h;
        self.occupancy_sum += other.occupancy_sum;
        self.queue_wait_sum_s += other.queue_wait_sum_s;
        self.wall_s += other.wall_s;
        self.weight_epoch = self.weight_epoch.max(other.weight_epoch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_bytes_and_maxes_epoch() {
        let mut a = SchedulerStats {
            bytes_h2d: 100,
            bytes_d2h: 10,
            weight_epoch: 3,
            ..Default::default()
        };
        let b = SchedulerStats {
            bytes_h2d: 7,
            bytes_d2h: 2,
            weight_epoch: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!((a.bytes_h2d, a.bytes_d2h), (107, 12));
        assert_eq!(a.weight_epoch, 3, "epoch is a level, merge takes max");
    }
}
