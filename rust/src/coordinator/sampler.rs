//! Host-side token sampler — mirrors the in-artifact sampler semantics
//! (model.sample_token): temperature scaling, nucleus filtering, exact
//! behavior logprobs, greedy at temp < 1e-7.
//!
//! Used by the per-step scheduler path; the bulk `generate_*` artifacts
//! sample on-device.  Greedy decoding is bit-identical between the two
//! paths (integration-tested); stochastic sampling matches in distribution
//! (different RNG streams).

use crate::util::rng::Pcg64;

/// Sample one token from a logits row.  Returns (token, logprob under the
/// actual sampling distribution).
pub fn sample(logits: &[f32], temp: f32, top_p: f32, rng: &mut Pcg64)
              -> (i32, f32) {
    if temp < 1e-7 {
        return greedy(logits);
    }
    let t = temp.max(1e-6);
    // log-softmax of logits/t
    let scaled: Vec<f64> = logits.iter().map(|&x| (x / t) as f64).collect();
    let mx = scaled.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let lse = scaled.iter().map(|&x| (x - mx).exp()).sum::<f64>().ln() + mx;
    let logp: Vec<f64> = scaled.iter().map(|&x| x - lse).collect();
    let p: Vec<f64> = logp.iter().map(|&x| x.exp()).collect();

    // nucleus: smallest prefix of the sorted distribution with mass >= top_p.
    // Ties at the boundary are broken by sorted order (the stable sort keeps
    // ascending token-id order among equal probabilities), never by
    // threshold comparison — a `p >= thresh` filter would keep EVERY token
    // tied with the boundary probability, inflating the kept set past the
    // minimal nucleus and diverging from the artifact sampler on tied
    // logits.
    let mut order: Vec<usize> = (0..p.len()).collect();
    order.sort_by(|&a, &b| p[b].partial_cmp(&p[a]).unwrap());
    let mut keep: Vec<usize> = Vec::new();
    let mut cum = 0.0;
    for &i in &order {
        keep.push(i);
        cum += p[i];
        if cum >= top_p as f64 {
            break;
        }
    }
    let mass: f64 = keep.iter().map(|&i| p[i]).sum();
    // categorical over the renormalized nucleus
    let mut x = rng.f64() * mass;
    let mut chosen = *keep.last().unwrap();
    for &i in &keep {
        x -= p[i];
        if x <= 0.0 {
            chosen = i;
            break;
        }
    }
    (chosen as i32, (p[chosen] / mass).ln() as f32)
}

/// Greedy pick with the logprob under the untempered distribution.
pub fn greedy(logits: &[f32]) -> (i32, f32) {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln()
        + mx as f64;
    (best as i32, (logits[best] as f64 - lse) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let logits = [0.1f32, 2.5, -1.0, 2.4];
        let (t, lp) = greedy(&logits);
        assert_eq!(t, 1);
        assert!(lp < 0.0 && lp > -1.0);
    }

    #[test]
    fn temp_zero_is_greedy() {
        let logits = [0.0f32, 3.0, 1.0];
        let mut rng = Pcg64::new(1);
        let (t, _) = sample(&logits, 0.0, 1.0, &mut rng);
        assert_eq!(t, 1);
    }

    #[test]
    fn full_top_p_matches_softmax_frequencies() {
        let logits = [0.0f32, 1.0, 2.0];
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            let (t, lp) = sample(&logits, 1.0, 1.0, &mut rng);
            counts[t as usize] += 1;
            assert!(lp <= 0.0);
        }
        let z: f64 = (0..3).map(|i| (logits[i] as f64).exp()).sum();
        for i in 0..3 {
            let expect = (logits[i] as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!((got - expect).abs() < 0.01, "{i}: {got} vs {expect}");
        }
    }

    #[test]
    fn top_p_filters_tail() {
        // p = softmax([5, 0, 0, 0]) -> head has ~0.97 mass; top_p=0.5 keeps
        // only the head
        let logits = [5.0f32, 0.0, 0.0, 0.0];
        let mut rng = Pcg64::new(3);
        for _ in 0..2000 {
            let (t, lp) = sample(&logits, 1.0, 0.5, &mut rng);
            assert_eq!(t, 0);
            assert!(lp.abs() < 1e-6); // renormalized singleton
        }
    }

    #[test]
    fn tied_logits_keep_minimal_nucleus() {
        // three-way tie at the top: p ~ [1/3, 1/3, 1/3, ~0].  top_p = 0.4
        // needs two tokens (mass 2/3 >= 0.4); the old `p >= thresh` filter
        // kept all three tied tokens.  Ties break by sorted order, which is
        // stable: ascending token id among equals -> tokens {0, 1} only.
        let logits = [2.0f32, 2.0, 2.0, -30.0];
        let mut rng = Pcg64::new(11);
        let mut seen = [false; 4];
        for _ in 0..4000 {
            let (t, lp) = sample(&logits, 1.0, 0.4, &mut rng);
            seen[t as usize] = true;
            // renormalized two-token nucleus: logprob == ln(1/2)
            assert!((lp - 0.5f32.ln()).abs() < 1e-5, "lp {lp}");
        }
        assert!(seen[0] && seen[1], "both nucleus members sampled");
        assert!(!seen[2] && !seen[3], "tie leaked past the nucleus");
    }

    #[test]
    fn top_p_zero_keeps_top_token() {
        // degenerate top_p: the minimal prefix is never empty
        let logits = [0.0f32, 1.0, 0.5];
        let mut rng = Pcg64::new(12);
        for _ in 0..200 {
            let (t, lp) = sample(&logits, 1.0, 0.0, &mut rng);
            assert_eq!(t, 1);
            assert!(lp.abs() < 1e-6);
        }
    }

    #[test]
    fn logprob_is_consistent_with_frequency() {
        let logits = [1.0f32, 0.5, 0.0, -0.5];
        let mut rng = Pcg64::new(4);
        let mut lp_by_tok = std::collections::HashMap::new();
        let mut counts = std::collections::HashMap::new();
        let n = 80_000;
        for _ in 0..n {
            let (t, lp) = sample(&logits, 1.0, 0.8, &mut rng);
            lp_by_tok.insert(t, lp);
            *counts.entry(t).or_insert(0usize) += 1;
        }
        for (t, c) in counts {
            let freq = (c as f64 / n as f64).ln();
            let lp = lp_by_tok[&t] as f64;
            assert!((freq - lp).abs() < 0.06, "tok {t}: {freq} vs {lp}");
        }
    }
}
