//! Continuous-batching rollout scheduler (the vLLM-router-shaped piece of
//! L3): a FIFO request queue feeding KV slots, prefill admission batching,
//! lockstep decode over all active slots, per-request sampling state, and
//! service metrics.
//!
//! Invariants (tested in rust/tests + propcheck):
//! * every submitted request completes exactly once;
//! * a request's output is independent of co-scheduled requests (greedy
//!   decode matches the fused generate artifact bit-for-bit);
//! * slots recycle only after completion; occupancy never exceeds B.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::util::rng::Pcg64;

use super::engine::StepEngine;
use super::kv::SlotMap;
use super::request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
use super::sampler;

struct ActiveSeq {
    req: RolloutRequest,
    slot: usize,
    /// index of the last accepted token (prompt or generated)
    pos: usize,
    /// distribution for the NEXT token (logits row)
    pending_logits: Vec<f32>,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    rng: Pcg64,
    enqueued_at: Instant,
    started_at: Instant,
}

pub struct Scheduler<'rt, 'eng> {
    engine: &'eng mut StepEngine<'rt>,
    slots: SlotMap,
    queue: VecDeque<(RolloutRequest, Instant)>,
    active: Vec<ActiveSeq>,
    pub stats: SchedulerStats,
    max_seq: usize,
    eos_id: i32,
    /// admit new requests only when at least this many can prefill together
    /// (dynamic batching knob; 1 = admit eagerly)
    pub min_prefill_batch: usize,
}

impl<'rt, 'eng> Scheduler<'rt, 'eng> {
    pub fn new(engine: &'eng mut StepEngine<'rt>, max_seq: usize,
               eos_id: i32) -> Self {
        let b = engine.batch;
        Scheduler {
            engine,
            slots: SlotMap::new(b),
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: SchedulerStats::default(),
            max_seq,
            eos_id,
            min_prefill_batch: 1,
        }
    }

    pub fn submit(&mut self, req: RolloutRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Admit queued requests into free slots (batched prefill).
    fn admit(&mut self) -> Result<()> {
        let admissible = self.queue.len().min(self.slots.free_count());
        if admissible == 0
            || (admissible < self.min_prefill_batch
                && !self.active.is_empty())
        {
            return Ok(());
        }
        let mut slots = Vec::new();
        let mut prompts = Vec::new();
        let mut newly = Vec::new();
        for _ in 0..admissible {
            let (req, t_enq) = self.queue.pop_front().unwrap();
            let slot = self.slots.acquire(req.id).expect("free slot");
            slots.push(slot);
            prompts.push(req.prompt.clone());
            newly.push((req, t_enq, slot));
        }
        self.stats.prefill_calls += 1;
        let logits = self.engine.prefill(&slots, &prompts)?;
        for ((req, t_enq, slot), lg) in newly.into_iter().zip(logits) {
            let rng = Pcg64::new(req.seed);
            self.active.push(ActiveSeq {
                pos: req.prompt.len() - 1,
                pending_logits: lg,
                generated: Vec::new(),
                logprobs: Vec::new(),
                rng,
                enqueued_at: t_enq,
                started_at: Instant::now(),
                req,
                slot,
            });
        }
        Ok(())
    }

    /// One scheduler tick: admit, sample pending distributions, decode.
    /// Returns rollouts that completed this tick.
    pub fn tick(&mut self) -> Result<Vec<RolloutResult>> {
        self.admit()?;
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        // sample next token for every active sequence
        let mut finished: Vec<RolloutResult> = Vec::new();
        let mut decode_rows: Vec<(usize, i32, i32)> = Vec::new();
        let mut decode_idx: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let (tok, lp) = sampler::sample(&a.pending_logits,
                                            a.req.temperature, a.req.top_p,
                                            &mut a.rng);
            a.generated.push(tok);
            a.logprobs.push(lp);
            a.pos += 1; // the new token's index
            self.stats.generated_tokens += 1;
            let finish = if tok == self.eos_id {
                Some(FinishReason::Eos)
            } else if a.generated.len() >= a.req.max_new {
                Some(FinishReason::MaxNew)
            } else if a.pos + 1 >= self.max_seq {
                Some(FinishReason::ContextLimit)
            } else {
                None
            };
            if let Some(reason) = finish {
                let a = self.active.swap_remove(i);
                self.slots.release(a.slot, a.req.id);
                self.stats.completed += 1;
                finished.push(RolloutResult {
                    id: a.req.id,
                    generated: a.generated,
                    logprobs: a.logprobs,
                    finish: reason,
                    queue_wait_s: (a.started_at - a.enqueued_at).as_secs_f64(),
                    service_s: a.started_at.elapsed().as_secs_f64(),
                });
            } else {
                decode_rows.push((a.slot, a.pos as i32, tok));
                decode_idx.push(i);
                i += 1;
            }
        }
        // lockstep decode for survivors
        if !decode_rows.is_empty() {
            self.stats.decode_calls += 1;
            self.stats.occupancy_sum +=
                decode_rows.len() as f64 / self.engine.batch as f64;
            let logits = self.engine.decode(&decode_rows)?;
            for (k, &idx) in decode_idx.iter().enumerate() {
                self.active[idx].pending_logits = logits[k].clone();
            }
        }
        self.stats.decode_steps += 1;
        Ok(finished)
    }

    /// Drive to completion; returns all results (submission order not
    /// guaranteed — callers match by id).
    pub fn run_to_completion(&mut self) -> Result<Vec<RolloutResult>> {
        let t0 = Instant::now();
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick()?);
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}
