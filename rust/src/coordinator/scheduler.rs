//! Continuous-batching rollout scheduler (the vLLM-router-shaped piece of
//! L3): a FIFO request queue feeding KV slots, prefill admission batching,
//! lockstep decode over all active slots, per-request sampling state, and
//! service metrics.
//!
//! Generic over [`DecodeEngine`], so the same scheduling logic serves the
//! PJRT [`StepEngine`](super::StepEngine) in production (the trainer's
//! `--rollout-path scheduler` and `qurl serve`) and the artifact-free
//! [`MockEngine`](super::mock::MockEngine) in property tests.
//!
//! Invariants (tested in rust/tests + propcheck):
//! * every submitted request resolves exactly once — completed in tick
//!   results or cancelled via [`Scheduler::cancel`], never both
//!   (`completed + cancelled == submitted` once drained);
//! * a request's output is independent of co-scheduled requests (greedy
//!   decode matches the fused generate artifact bit-for-bit), including
//!   requests admitted through shared-prefix fork_kv prefill;
//! * slots recycle only after completion or cancellation; occupancy never
//!   exceeds B;
//! * decode positions stay strictly below `max_seq` (KV capacity).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::util::rng::Pcg64;

use super::engine::{DecodeEngine, LogitsRow};
use super::kv::{KvConfig, SlotMap};
use super::request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
use super::sampler;

struct ActiveSeq {
    req: RolloutRequest,
    slot: usize,
    /// index of the last accepted token (prompt or generated)
    pos: usize,
    /// prompt tokens in the KV cache so far.  `== prompt.len()` once the
    /// sequence generates; less only mid chunked prefill, where the tail
    /// rides decode ticks one chunk per tick instead of stalling admission
    /// behind one monolithic prefill.
    prompt_fed: usize,
    /// distribution for the NEXT token — a shared view into the engine's
    /// per-call logits block, not a per-sequence copy.  Only meaningful
    /// once `prompt_fed == prompt.len()`; chunk-feed decodes overwrite it
    /// until then.
    pending_logits: LogitsRow,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    rng: Pcg64,
    enqueued_at: Instant,
    started_at: Instant,
}

/// Why (if at all) a sequence must stop after accepting the token at `pos`
/// (`n_generated` tokens emitted so far).  Priority: EOS > MaxNew >
/// ContextLimit.
///
/// KV-capacity audit: continuing from `pos` makes the engine decode with a
/// KV write at `pos` and logits for the token at `pos + 1`, so both indices
/// must stay below `max_seq`.  Stopping when `pos + 1 >= max_seq` admits
/// `pos <= max_seq - 2` into decode — the write lands in range and the
/// final context position `max_seq - 1` is still reachable by sampling.
/// The naive `pos >= max_seq` guard would instead decode at
/// `pos = max_seq - 1` and sample a token at index `max_seq`, one past the
/// cache (covered by tests below and the assert in `StepEngine::decode`).
fn finish_reason(tok: i32, eos_id: i32, n_generated: usize, max_new: usize,
                 pos: usize, max_seq: usize) -> Option<FinishReason> {
    if tok == eos_id {
        Some(FinishReason::Eos)
    } else if n_generated >= max_new {
        Some(FinishReason::MaxNew)
    } else if pos + 1 >= max_seq {
        Some(FinishReason::ContextLimit)
    } else {
        None
    }
}

pub struct Scheduler<E: DecodeEngine> {
    engine: E,
    slots: SlotMap,
    queue: VecDeque<(RolloutRequest, Instant)>,
    active: Vec<ActiveSeq>,
    pub stats: SchedulerStats,
    max_seq: usize,
    eos_id: i32,
    /// admit new requests only when at least this many can prefill together
    /// (dynamic batching knob; 1 = admit eagerly)
    pub min_prefill_batch: usize,
    /// group-shared prefix prefill: within one admission batch, requests
    /// with identical prompts prefill once and fork their KV rows into the
    /// sibling slots ([`DecodeEngine::fork_kv`]).  Exact for greedy AND
    /// sampled decode (prefill logits/KV depend only on the prompt; sampling
    /// state stays per-request).  Off reproduces the PR-1 per-request
    /// prefill for baseline comparisons.
    pub share_prefix: bool,
    /// chunked prefill: prompts longer than this prefill only their first
    /// `prefill_chunk` tokens at admission; the tail rides the regular
    /// decode ticks, up to one chunk per tick, interleaved with the
    /// co-scheduled sequences' generation instead of stalling the batch
    /// behind one monolithic prefill.  0 (the default) disables chunking.
    /// Bit-parity: a token's distribution depends only on its own
    /// sequence's prior tokens, so chunk-fed and whole-prompt prefill
    /// yield identical streams (property-tested on the mock,
    /// integration-tested against the artifacts).
    pub prefill_chunk: usize,
}

impl<E: DecodeEngine> Scheduler<E> {
    /// Takes the engine by value; pass `&mut engine` to lend a caller-owned
    /// engine (the blanket `DecodeEngine for &mut E` impl forwards).
    pub fn new(engine: E, max_seq: usize, eos_id: i32) -> Self {
        let b = engine.slot_count();
        Scheduler {
            engine,
            slots: SlotMap::new(b),
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: SchedulerStats::default(),
            max_seq,
            eos_id,
            min_prefill_batch: 1,
            share_prefix: true,
            prefill_chunk: 0,
        }
    }

    /// Install a KV layout on the engine ([`DecodeEngine::configure_kv`]).
    /// Call before serving begins — rebuilding the page ledger mid-flight
    /// does not crash (the pager self-heals slot by slot) but resets the
    /// page counters.
    pub fn set_kv(&mut self, cfg: KvConfig) {
        self.engine.configure_kv(cfg);
    }

    /// Prompt positions the first prefill call covers for a prompt of
    /// `prompt_len` tokens (the whole prompt unless chunking truncates it).
    fn effective_prefill_len(&self, prompt_len: usize) -> usize {
        if self.prefill_chunk > 0 {
            prompt_len.min(self.prefill_chunk)
        } else {
            prompt_len
        }
    }

    pub fn submit(&mut self, req: RolloutRequest) {
        self.stats.submitted += 1;
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Sequences currently decoding (occupied KV slots) — the concurrency
    /// the admission gate actually achieved.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Requests still queued (submitted but not yet admitted to a slot).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// KV slots currently unoccupied — spare decode capacity a
    /// work-stealing placement layer can fill.
    pub fn free_slots(&self) -> usize {
        self.slots.free_count()
    }

    /// Live outstanding-token estimate: queued requests at their full
    /// budget `min(prompt + max_new, max_seq)`, active sequences at what
    /// remains of it.  This is the scheduler-side load signal the
    /// service's work-stealing layer reads through shared per-engine
    /// atomics — unlike the submission-time `est_load` ledger it shrinks
    /// as sequences finish, so an early-EOS or pruned-out replica shows
    /// up under-loaded while a straggler still queues.
    pub fn outstanding_tokens(&self) -> u64 {
        let budget = |req: &RolloutRequest| {
            req.prompt.len().saturating_add(req.max_new).min(self.max_seq)
        };
        let queued: u64 =
            self.queue.iter().map(|(r, _)| budget(r) as u64).sum();
        let active: u64 = self
            .active
            .iter()
            .map(|a| budget(&a.req).saturating_sub(a.pos) as u64)
            .sum();
        queued.saturating_add(active)
    }

    /// Extract a set of *queued* requests — the work-stealing handoff.
    /// All-or-nothing: succeeds only when every id is still queued (none
    /// admitted, active, completed or cancelled), so whole groups move
    /// between engines and `fork_kv` prefix sharing stays intra-engine.
    /// Extracted requests leave this scheduler's ledger entirely
    /// (`submitted` is debited; the thief's `submit` re-counts them, so
    /// the merged `completed + cancelled == submitted` invariant holds
    /// across a steal) and return in the order given.
    pub fn extract_queued(&mut self, ids: &[u64])
                          -> Option<Vec<RolloutRequest>> {
        if ids.is_empty() {
            return None;
        }
        // resolve every id to a queue index before touching the queue, so
        // a missing or duplicated id (two entries resolving to one index)
        // rejects the whole steal with the ledger untouched
        let mut idx: Vec<usize> = Vec::with_capacity(ids.len());
        for id in ids {
            match self.queue.iter().position(|(r, _)| r.id == *id) {
                Some(qi) if !idx.contains(&qi) => idx.push(qi),
                _ => return None,
            }
        }
        // remove highest index first so the remaining ones stay valid;
        // `picked` keeps the caller's order
        let mut order: Vec<usize> = (0..idx.len()).collect();
        order.sort_by_key(|&k| std::cmp::Reverse(idx[k]));
        let mut picked: Vec<Option<RolloutRequest>> =
            ids.iter().map(|_| None).collect();
        for k in order {
            if let Some((req, _)) = self.queue.remove(idx[k]) {
                picked[k] = Some(req);
            }
        }
        let out: Vec<RolloutRequest> =
            picked.into_iter().flatten().collect();
        if out.len() != ids.len() {
            return None;
        }
        self.stats.submitted -= out.len();
        Some(out)
    }

    /// Install freshly quantized engine weights between ticks (hot
    /// requantization).  `epoch` is the service's
    /// [`WeightEpoch`](super::service::WeightEpoch) counter, surfaced in
    /// [`SchedulerStats::weight_epoch`] so metric rows show which weight
    /// generation served each step, and passed down to the engine (which
    /// replaces its resident weight handles, so the new weights convert to
    /// device format once, on their first call).
    /// Queued and active requests are untouched; their next decode simply
    /// runs under the new weights.
    pub fn swap_weights(&mut self, w: E::Weights, epoch: u64) {
        self.engine.swap_weights(w, epoch);
        self.stats.weight_epoch = epoch;
    }

    /// Cancel every queued and active request at once (error recovery /
    /// shutdown): all KV slots recycle, every removed request counts as
    /// cancelled, so the `completed + cancelled == submitted` ledger stays
    /// balanced even after an aborted run.  Returns how many requests were
    /// aborted.  Unlike [`Scheduler::cancel`] the partials are dropped —
    /// callers abort precisely when the outputs are no longer trustworthy.
    pub fn abort_all(&mut self) -> usize {
        let mut n = 0;
        while self.queue.pop_front().is_some() {
            self.stats.cancelled += 1;
            n += 1;
        }
        for a in self.active.drain(..) {
            self.slots.release(a.slot, a.req.id);
            self.engine.release_kv(a.slot);
            self.stats.cancelled += 1;
            n += 1;
        }
        n
    }

    /// Drain the counters for this scheduler, preserving the weight-epoch
    /// *level* (it is a generation marker, not a per-run delta — resetting
    /// it to 0 would make a later stats row claim the engine regressed to
    /// its initial weights).  The engine's staged-byte counters drain here
    /// too, so `bytes_h2d`/`bytes_d2h` land in the same stats row as the
    /// decode/prefill call counts they pair with.
    pub fn take_stats(&mut self) -> SchedulerStats {
        let (h2d, d2h) = self.engine.take_transfer();
        self.stats.bytes_h2d += h2d;
        self.stats.bytes_d2h += d2h;
        self.stats.swap_bytes_h2d += self.engine.take_swap_h2d();
        let kv = self.engine.take_kv_stats();
        self.stats.kv_pages_allocated += kv.allocated;
        self.stats.kv_pages_freed += kv.freed;
        self.stats.kv_pages_shared += kv.shared;
        self.stats.kv_pages_cow += kv.cow;
        self.stats.kv_pages_active = kv.active;
        self.stats.kv_pages_high_water = kv.high_water;
        let st = std::mem::take(&mut self.stats);
        self.stats.weight_epoch = st.weight_epoch;
        // page levels survive the drain like the epoch does
        self.stats.kv_pages_active = st.kv_pages_active;
        self.stats.kv_pages_high_water = st.kv_pages_high_water;
        st
    }

    /// Remove a request wherever it currently lives — still queued (its
    /// prefill never happens) or actively decoding (its KV slot frees
    /// immediately).  Returns the partial output with
    /// [`FinishReason::Cancelled`], or `None` when the id is unknown or
    /// already completed.  Cancelled requests never appear in
    /// [`Scheduler::tick`] results; on a drained scheduler
    /// `completed + cancelled == submitted`.
    pub fn cancel(&mut self, id: u64) -> Option<RolloutResult> {
        let qi = self.queue.iter().position(|(r, _)| r.id == id);
        if let Some((req, t_enq)) = qi.and_then(|qi| self.queue.remove(qi))
        {
            self.stats.cancelled += 1;
            return Some(RolloutResult {
                id: req.id,
                generated: Vec::new(),
                logprobs: Vec::new(),
                finish: FinishReason::Cancelled,
                queue_wait_s: t_enq.elapsed().as_secs_f64(),
                service_s: 0.0,
            });
        }
        if let Some(ai) = self.active.iter().position(|a| a.req.id == id) {
            let a = self.active.swap_remove(ai);
            self.slots.release(a.slot, a.req.id);
            // online pruning reclaims KV memory, not just compute: the
            // cancelled sequence's non-shared pages return to the free
            // list immediately
            self.engine.release_kv(a.slot);
            self.stats.cancelled += 1;
            return Some(RolloutResult {
                id: a.req.id,
                generated: a.generated,
                logprobs: a.logprobs,
                finish: FinishReason::Cancelled,
                queue_wait_s: (a.started_at - a.enqueued_at).as_secs_f64(),
                service_s: a.started_at.elapsed().as_secs_f64(),
            });
        }
        None
    }

    /// Admit queued requests into free slots (batched prefill).  With
    /// `share_prefix`, duplicate prompts within the batch prefill once and
    /// fork KV into the sibling slots — `prefill_rows` counts only the
    /// representative rows, `forked` the rows saved.
    fn admit(&mut self) -> Result<()> {
        let mut admissible = self.queue.len().min(self.slots.free_count());
        if admissible == 0
            || (admissible < self.min_prefill_batch
                && !self.active.is_empty())
        {
            return Ok(());
        }
        // page-budget gate (live only under an explicit budget —
        // `kv_free_pages` is None otherwise and the wave is slot-bound as
        // before): walk the FIFO head charging each candidate its
        // admission cost — cluster leaders pay their first-chunk coverage
        // (dense: one full reservation), prefix-shared siblings pay their
        // fork cost — and stop at the first that does not fit, preserving
        // arrival order.  This is where paged beats dense at equal
        // memory: a long-prompt dense wave reserves max_seq positions per
        // request while paged reserves only the prompt-covering pages.
        if let Some(mut free) = self.engine.kv_free_pages() {
            let mut take = 0usize;
            while take < admissible {
                let prompt = &self.queue[take].0.prompt;
                let plen = self.effective_prefill_len(prompt.len());
                let forked = self.share_prefix
                    && self.queue.iter().take(take).any(|(r, _)| {
                        Arc::ptr_eq(&r.prompt, prompt) || r.prompt == *prompt
                    });
                let cost = self.engine.kv_admit_cost(plen, forked);
                if cost > free {
                    break;
                }
                free -= cost;
                take += 1;
            }
            if take == 0 {
                if !self.active.is_empty() {
                    // pages free as in-flight sequences finish; wait
                    return Ok(());
                }
                // an idle scheduler must never deadlock on a request
                // larger than the whole budget: force-admit the head and
                // let the pager overdraw (visible as high_water > budget)
                take = 1;
            }
            admissible = take;
        }
        let mut newly = Vec::new();
        while newly.len() < admissible {
            // `admissible` was clamped to queue length and free slots
            // above; running out early just admits fewer this round
            let Some((req, t_enq)) = self.queue.pop_front() else {
                break;
            };
            let Some(slot) = self.slots.acquire(req.id) else {
                self.queue.push_front((req, t_enq));
                break;
            };
            newly.push((req, t_enq, slot));
        }
        // cluster identical prompts: reps[k] is the newly-index of cluster
        // k's representative; rep_for[i] is request i's cluster.  Prompts
        // are Arc-shared end-to-end (one group's members hold the same
        // allocation), so the common case resolves by pointer identity
        // before falling back to a content compare.
        let mut reps: Vec<usize> = Vec::new();
        let mut rep_for: Vec<usize> = Vec::with_capacity(newly.len());
        for i in 0..newly.len() {
            let found = if self.share_prefix {
                reps.iter().position(|&r| {
                    let (a, b) = (&newly[r].0.prompt, &newly[i].0.prompt);
                    Arc::ptr_eq(a, b) || a == b
                })
            } else {
                None
            };
            match found {
                Some(k) => rep_for.push(k),
                None => {
                    rep_for.push(reps.len());
                    reps.push(i);
                }
            }
        }
        let slots: Vec<usize> = reps.iter().map(|&i| newly[i].2).collect();
        // borrowed, not cloned: the engine reads prompt tokens in place —
        // chunked prefill covers only the first `prefill_chunk` positions;
        // the tail rides later decode ticks
        let prompts: Vec<&[i32]> = reps
            .iter()
            .map(|&i| {
                let p = newly[i].0.prompt.as_slice();
                &p[..self.effective_prefill_len(p.len())]
            })
            .collect();
        self.stats.prefill_calls += 1;
        self.stats.prefill_rows += reps.len();
        self.stats.prefill_chunks += prompts
            .iter()
            .zip(reps.iter())
            .filter(|&(p, &i)| p.len() < newly[i].0.prompt.len())
            .count();
        let logits = self.engine.prefill(&slots, &prompts)?;
        drop(prompts);
        for (k, &ri) in reps.iter().enumerate() {
            let dsts: Vec<usize> = (0..newly.len())
                .filter(|&i| i != ri && rep_for[i] == k)
                .map(|i| newly[i].2)
                .collect();
            if !dsts.is_empty() {
                // prefix-limited fork: only the rows prefilled so far
                // carry state (the whole prompt unless chunking truncated
                // it — siblings chunk-feed the rest independently)
                let fed =
                    self.effective_prefill_len(newly[ri].0.prompt.len());
                self.engine.fork_kv(newly[ri].2, &dsts, fed)?;
                self.stats.forked += dsts.len();
            }
        }
        for (i, (req, t_enq, slot)) in newly.into_iter().enumerate() {
            let rng = Pcg64::new(req.seed);
            let fed = self.effective_prefill_len(req.prompt.len());
            self.active.push(ActiveSeq {
                pos: fed - 1,
                prompt_fed: fed,
                // Rc bump into the shared block — forked siblings reference
                // the representative's prefill row, no vocab-sized copy
                pending_logits: logits[rep_for[i]].clone(),
                generated: Vec::new(),
                logprobs: Vec::new(),
                rng,
                enqueued_at: t_enq,
                started_at: Instant::now(),
                req,
                slot,
            });
        }
        Ok(())
    }

    /// One scheduler tick: admit, sample pending distributions, decode.
    /// Returns rollouts that completed this tick.
    pub fn tick(&mut self) -> Result<Vec<RolloutResult>> {
        self.admit()?;
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        // sample next token for every active sequence; sequences still
        // chunk-feeding their prompt skip sampling and ride the same
        // lockstep decode with their next prompt token instead
        let mut finished: Vec<RolloutResult> = Vec::new();
        let mut decode_rows: Vec<(usize, i32, i32)> = Vec::new();
        let mut decode_idx: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            if a.prompt_fed < a.req.prompt.len() {
                a.pos += 1;
                debug_assert_eq!(a.pos, a.prompt_fed);
                decode_rows.push((a.slot, a.pos as i32,
                                  a.req.prompt[a.prompt_fed]));
                a.prompt_fed += 1;
                decode_idx.push(i);
                i += 1;
                continue;
            }
            let (tok, lp) = sampler::sample(a.pending_logits.as_slice(),
                                            a.req.temperature, a.req.top_p,
                                            &mut a.rng);
            a.generated.push(tok);
            a.logprobs.push(lp);
            a.pos += 1; // the new token's index
            self.stats.generated_tokens += 1;
            let finish = finish_reason(tok, self.eos_id, a.generated.len(),
                                       a.req.max_new, a.pos, self.max_seq);
            if let Some(reason) = finish {
                let a = self.active.swap_remove(i);
                self.slots.release(a.slot, a.req.id);
                self.engine.release_kv(a.slot);
                self.stats.completed += 1;
                let queue_wait_s = (a.started_at - a.enqueued_at).as_secs_f64();
                self.stats.queue_wait_sum_s += queue_wait_s;
                finished.push(RolloutResult {
                    id: a.req.id,
                    generated: a.generated,
                    logprobs: a.logprobs,
                    finish: reason,
                    queue_wait_s,
                    service_s: a.started_at.elapsed().as_secs_f64(),
                });
            } else {
                decode_rows.push((a.slot, a.pos as i32, tok));
                decode_idx.push(i);
                i += 1;
            }
        }
        // lockstep decode for survivors
        if !decode_rows.is_empty() {
            self.stats.decode_calls += 1;
            self.stats.occupancy_sum +=
                decode_rows.len() as f64 / self.engine.slot_count() as f64;
            let logits = self.engine.decode(&decode_rows)?;
            for (&idx, lg) in decode_idx.iter().zip(logits) {
                self.active[idx].pending_logits = lg;
            }
        }
        // chunk continuation: sequences still feeding their prompt advance
        // up to `prefill_chunk - 1` more tokens through decode rounds over
        // just those slots (the main decode above fed the first), so a
        // long prompt costs ~ceil(tail / chunk) ticks while co-scheduled
        // generation keeps its one-token-per-tick cadence.
        for _ in 1..self.prefill_chunk.max(1) {
            let mut rows: Vec<(usize, i32, i32)> = Vec::new();
            let mut idxs: Vec<usize> = Vec::new();
            for (i, a) in self.active.iter_mut().enumerate() {
                if a.prompt_fed < a.req.prompt.len() {
                    a.pos += 1;
                    rows.push((a.slot, a.pos as i32,
                               a.req.prompt[a.prompt_fed]));
                    a.prompt_fed += 1;
                    idxs.push(i);
                }
            }
            if rows.is_empty() {
                break;
            }
            self.stats.decode_calls += 1;
            self.stats.prefill_chunks += 1;
            self.stats.occupancy_sum +=
                rows.len() as f64 / self.engine.slot_count() as f64;
            let logits = self.engine.decode(&rows)?;
            for (&idx, lg) in idxs.iter().zip(logits) {
                self.active[idx].pending_logits = lg;
            }
        }
        self.stats.decode_steps += 1;
        Ok(finished)
    }

    /// Drive to completion; returns all results (submission order not
    /// guaranteed — callers match by id).
    pub fn run_to_completion(&mut self) -> Result<Vec<RolloutResult>> {
        let t0 = Instant::now();
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick()?);
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::kv::{KvConfig, KvLayout};
    use super::super::mock::MockEngine;
    use super::*;

    const MAX_SEQ: usize = 16;
    const EOS: i32 = 2;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> RolloutRequest {
        RolloutRequest {
            id,
            prompt: Arc::new((0..prompt_len).map(|i| 3 + (i as i32 % 5))
                .collect()),
            max_new,
            // greedy: the mock's argmax stream is deterministic and can hit
            // EOS, exercising all three finish reasons
            temperature: 0.0,
            top_p: 1.0,
            seed: id ^ 0x5eed,
        }
    }

    /// Boundary case from the KV-capacity audit: prompt_len + max_new ==
    /// max_seq must complete without any decode position reaching max_seq,
    /// and generation may legitimately fill the very last context slot.
    #[test]
    fn context_boundary_no_out_of_range_decode() {
        for prompt_len in [1usize, 4, MAX_SEQ - 1] {
            let mut eng = MockEngine::new(2, 8, MAX_SEQ, EOS);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            sched.submit(req(0, prompt_len, MAX_SEQ - prompt_len));
            let results = sched.run_to_completion().unwrap();
            assert_eq!(results.len(), 1);
            let r = &results[0];
            assert!(r.generated.len() <= MAX_SEQ - prompt_len);
            // last accepted token index stays in context
            assert!(prompt_len - 1 + r.generated.len() <= MAX_SEQ - 1);
            // MockEngine::decode asserts pos < max_seq; double-check here
            assert!((eng.max_pos_seen as usize) < MAX_SEQ);
        }
    }

    /// An unbounded request must stop via ContextLimit exactly when the
    /// last context index is consumed — never one token later.
    #[test]
    fn context_limit_fires_at_last_index() {
        let prompt_len = 5;
        let mut eng = MockEngine::new(1, 8, MAX_SEQ, 127 /* unreachable eos */);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
        sched.submit(req(0, prompt_len, usize::MAX));
        let results = sched.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish, FinishReason::ContextLimit);
        assert_eq!(results[0].generated.len(), MAX_SEQ - prompt_len);
        assert!((eng.max_pos_seen as usize) < MAX_SEQ);
    }

    /// finish_reason truth table around the boundary.
    #[test]
    fn finish_reason_priorities() {
        // EOS wins over everything
        assert_eq!(finish_reason(EOS, EOS, 1, 1, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::Eos));
        // MaxNew before ContextLimit when both bind
        assert_eq!(finish_reason(5, EOS, 4, 4, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::MaxNew));
        // last usable index triggers ContextLimit...
        assert_eq!(finish_reason(5, EOS, 1, 8, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::ContextLimit));
        // ...one before it does not (decode at max_seq-2 is in range)
        assert_eq!(finish_reason(5, EOS, 1, 8, MAX_SEQ - 2, MAX_SEQ), None);
    }

    /// Identical prompts admitted together prefill once and fork KV into
    /// the sibling slots; greedy outputs match per-request prefill exactly
    /// (the fork_kv ≡ fresh-prefill contract, mock side).
    #[test]
    fn shared_prefix_fork_matches_fresh_prefill() {
        let run = |share: bool| {
            let mut eng = MockEngine::new(4, 8, MAX_SEQ, EOS);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            sched.share_prefix = share;
            for id in 0..4u64 {
                let mut r = req(0, 5, 8);
                r.id = id; // same prompt in every request
                sched.submit(r);
            }
            let mut results = sched.run_to_completion().unwrap();
            results.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> =
                results.iter().map(|r| r.generated.clone()).collect();
            (toks, eng.prefill_rows, eng.forked_slots)
        };
        let (shared, rows_shared, forked) = run(true);
        let (plain, rows_plain, forked_off) = run(false);
        assert_eq!(shared, plain, "fork_kv diverged from fresh prefill");
        assert_eq!((rows_shared, forked), (1, 3));
        assert_eq!((rows_plain, forked_off), (4, 0));
        // greedy group members are identical sequences
        assert!(shared.windows(2).all(|w| w[0] == w[1]));
    }

    /// cancel() removes queued requests before prefill and active requests
    /// mid-decode; cancelled ids never surface in tick results and the
    /// drained ledger balances (completed + cancelled == submitted).
    #[test]
    fn cancel_queued_and_active() {
        let mut eng = MockEngine::new(2, 8, MAX_SEQ, 127 /* no eos */);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
        for id in 0..4u64 {
            sched.submit(req(id, 3, 6));
        }
        // first tick admits 2 of 4 (B = 2); the rest stay queued
        let t = sched.tick().unwrap();
        assert!(t.is_empty());
        let c_active = sched.cancel(0).unwrap();
        assert_eq!(c_active.finish, FinishReason::Cancelled);
        assert!(!c_active.generated.is_empty(), "active had begun decoding");
        let c_queued = sched.cancel(3).unwrap();
        assert!(c_queued.generated.is_empty(), "queued never decoded");
        assert!(sched.cancel(3).is_none(), "double cancel must be a no-op");
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2], "cancelled ids leaked into results");
        assert!(results.iter().all(|r| r.finish != FinishReason::Cancelled));
        assert_eq!(sched.stats.cancelled, 2);
        assert_eq!(sched.stats.completed + sched.stats.cancelled,
                   sched.stats.submitted);
    }

    /// extract_queued is all-or-nothing on the queue: a set containing an
    /// admitted (active) request is refused outright, a fully queued set
    /// moves out with its `submitted` count, and re-submitting the
    /// extracted requests elsewhere keeps the global ledger balanced —
    /// the work-stealing handoff contract.
    #[test]
    fn extract_queued_is_all_or_nothing() {
        let mut eng = MockEngine::new(2, 8, MAX_SEQ, 127 /* no eos */);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
        for id in 0..6u64 {
            sched.submit(req(id, 3, 6));
        }
        // first tick admits ids 0 and 1 (B = 2); 2..6 stay queued
        let t = sched.tick().unwrap();
        assert!(t.is_empty());
        assert_eq!(sched.queue_len(), 4);
        assert!(sched.extract_queued(&[1, 2]).is_none(),
                "a partially admitted set must be refused");
        assert!(sched.extract_queued(&[]).is_none());
        assert!(sched.extract_queued(&[99]).is_none(), "unknown id");
        let stolen = sched.extract_queued(&[4, 5]).unwrap();
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![4, 5], "extraction preserves the given order");
        assert_eq!(sched.queue_len(), 2);
        assert_eq!(sched.stats.submitted, 4, "extraction debits submitted");
        assert!(sched.extract_queued(&[4]).is_none(),
                "double extraction must be refused");
        // thief side: a second scheduler serves the stolen requests and
        // the summed ledger balances
        let mut thief_eng = MockEngine::new(2, 8, MAX_SEQ, 127);
        let mut thief = Scheduler::new(&mut thief_eng, MAX_SEQ, 127);
        for r in stolen {
            thief.submit(r);
        }
        let a = sched.run_to_completion().unwrap();
        let b = thief.run_to_completion().unwrap();
        assert_eq!(a.len() + b.len(), 6);
        assert_eq!(sched.stats.completed + thief.stats.completed,
                   sched.stats.submitted + thief.stats.submitted);
        // outstanding load drains to zero on both sides
        assert_eq!(sched.outstanding_tokens() + thief.outstanding_tokens(),
                   0);
    }

    /// Chunked prefill is invisible in the outputs: every chunk setting
    /// (off, tiny, one-token) yields bit-identical token streams and
    /// logprobs, greedy and sampled, with and without prefix sharing —
    /// only the call pattern (prefill coverage + chunk-feed decodes)
    /// changes.
    #[test]
    fn chunked_prefill_matches_whole_prompt() {
        let run = |chunk: usize, share: bool, temp: f32| {
            let mut eng = MockEngine::new(3, 8, MAX_SEQ, EOS);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            sched.share_prefix = share;
            sched.prefill_chunk = chunk;
            for id in 0..5u64 {
                let mut r = req(id, 4 + (id as usize % 7), 6);
                r.temperature = temp;
                if id >= 3 {
                    r.prompt = Arc::new(vec![9, 8, 7, 6, 5, 4, 3, 2, 1]);
                }
                sched.submit(r);
            }
            let mut results = sched.run_to_completion().unwrap();
            let chunks = sched.stats.prefill_chunks;
            results.sort_by_key(|r| r.id);
            let key: Vec<(u64, Vec<i32>, Vec<u32>)> = results
                .iter()
                .map(|r| (r.id, r.generated.clone(),
                          r.logprobs.iter().map(|l| l.to_bits()).collect()))
                .collect();
            (key, chunks)
        };
        for share in [false, true] {
            for temp in [0.0f32, 0.9] {
                let (whole, chunks0) = run(0, share, temp);
                assert_eq!(chunks0, 0, "chunk counter must stay 0 when off");
                for chunk in [1usize, 3, 64] {
                    let (chunked, chunks) = run(chunk, share, temp);
                    assert_eq!(chunked, whole,
                               "chunk={chunk} share={share} temp={temp} \
                                diverged from whole-prompt prefill");
                    if chunk < 9 {
                        assert!(chunks > 0,
                                "chunking engaged but counted no chunks");
                    }
                }
            }
        }
    }

    /// Acceptance: at equal page budget, an admission-blocked long-prompt
    /// workload runs strictly more concurrent requests under paged KV
    /// than under dense — dense reserves `max_seq` positions per
    /// sequence, paged only the covered pages.
    #[test]
    fn paged_admits_more_than_dense_at_equal_memory() {
        // max_seq 16, page 4 -> dense reservation = 4 pages/seq;
        // budget 8 pages -> dense caps at 2 concurrent.  Prompts cover 1
        // page and generate few tokens, so paged packs ~8.
        let run = |layout: KvLayout| {
            let mut eng = MockEngine::new(8, 8, MAX_SEQ, 127 /* no eos */);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
            sched.set_kv(KvConfig {
                layout,
                page_size: 4,
                budget_pages: Some(8),
            });
            for id in 0..8u64 {
                sched.submit(req(id, 4, 2));
            }
            let mut peak = 0usize;
            let mut results = Vec::new();
            while sched.pending() > 0 {
                results.extend(sched.tick().unwrap());
                peak = peak.max(sched.active_count());
            }
            assert_eq!(results.len(), 8, "every request still completes");
            (peak, sched.take_stats())
        };
        let (dense_peak, dense_st) = run(KvLayout::Dense);
        let (paged_peak, paged_st) = run(KvLayout::Paged);
        assert_eq!(dense_peak, 2, "dense: 8-page budget / 4-page seqs");
        assert!(paged_peak > dense_peak,
                "paged ({paged_peak}) must beat dense ({dense_peak}) at \
                 equal memory");
        // both drain leak-free
        for st in [&dense_st, &paged_st] {
            assert_eq!(st.kv_pages_freed, st.kv_pages_allocated,
                       "pages leaked at drain");
            assert_eq!(st.kv_pages_active, 0);
        }
        // the memory-per-concurrency claim: dense would need
        // peak * full-reservation pages to run what paged ran
        let dense_equiv = paged_peak * (MAX_SEQ / 4);
        assert!(paged_st.kv_pages_high_water < dense_equiv,
                "paged peak footprint {} not below the {} pages dense \
                 needs for the same concurrency",
                paged_st.kv_pages_high_water, dense_equiv);
    }

    /// Acceptance: cancelling part of a prefix-shared group mid-flight
    /// (online pruning) returns every non-shared page to the free list
    /// immediately, and the whole ledger drains leak-free.
    #[test]
    fn pruned_group_returns_pages_to_free_list() {
        let mut eng = MockEngine::new(4, 8, MAX_SEQ, 127 /* no eos */);
        {
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
            sched.set_kv(KvConfig {
                layout: KvLayout::Paged,
                page_size: 4,
                budget_pages: Some(16),
            });
            for id in 0..4u64 {
                let mut r = req(0, 6, 8);
                r.id = id; // one group: identical prompts fork-share
                sched.submit(r);
            }
            // a few ticks so every member CoWs private pages
            for _ in 0..3 {
                sched.tick().unwrap();
            }
            let before = sched.engine.pager().peek_stats();
            sched.cancel(1).unwrap();
            sched.cancel(2).unwrap();
            let after = sched.engine.pager().peek_stats();
            assert!(after.freed > before.freed,
                    "pruning must reclaim pages, not just compute");
            assert!(after.active < before.active);
            let _ = sched.run_to_completion().unwrap();
            let st = sched.take_stats();
            assert!(st.kv_pages_shared > 0, "group never shared pages");
            assert!(st.kv_pages_cow > 0, "members never CoW'd");
        }
        assert!(eng.pager().drained(),
                "pages leaked after prune + drain");
        assert!(eng.pager().check_invariants());
    }

    /// With the default (dense, unbudgeted) config the page ledger still
    /// books and drains — the seed-identical path keeps leak accounting.
    #[test]
    fn default_layout_ledger_balances() {
        let mut eng = MockEngine::new(3, 8, MAX_SEQ, EOS);
        {
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            for id in 0..6u64 {
                sched.submit(req(id, 3, 5));
            }
            let _ = sched.run_to_completion().unwrap();
            let st = sched.take_stats();
            assert!(st.kv_pages_allocated > 0);
            assert_eq!(st.kv_pages_freed, st.kv_pages_allocated);
        }
        assert!(eng.pager().drained());
    }

    /// More requests than slots: all complete exactly once, slots recycle.
    #[test]
    fn oversubscribed_queue_drains() {
        let mut eng = MockEngine::new(3, 8, MAX_SEQ, EOS);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
        for id in 0..10u64 {
            sched.submit(req(id, 1 + (id as usize % 4), 6));
        }
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(sched.stats.completed, sched.stats.submitted);
        assert!(sched.stats.mean_occupancy() <= 1.0 + 1e-9);
        assert!(sched.stats.mean_queue_wait_s() >= 0.0);
    }
}
