//! Continuous-batching rollout scheduler (the vLLM-router-shaped piece of
//! L3): a FIFO request queue feeding KV slots, prefill admission batching,
//! lockstep decode over all active slots, per-request sampling state, and
//! service metrics.
//!
//! Generic over [`DecodeEngine`], so the same scheduling logic serves the
//! PJRT [`StepEngine`](super::StepEngine) in production (the trainer's
//! `--rollout-path scheduler` and `qurl serve`) and the artifact-free
//! [`MockEngine`](super::mock::MockEngine) in property tests.
//!
//! Invariants (tested in rust/tests + propcheck):
//! * every submitted request resolves exactly once — completed in tick
//!   results or cancelled via [`Scheduler::cancel`], never both
//!   (`completed + cancelled == submitted` once drained);
//! * a request's output is independent of co-scheduled requests (greedy
//!   decode matches the fused generate artifact bit-for-bit), including
//!   requests admitted through shared-prefix fork_kv prefill;
//! * slots recycle only after completion or cancellation; occupancy never
//!   exceeds B;
//! * decode positions stay strictly below `max_seq` (KV capacity).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::util::rng::Pcg64;

use super::engine::{DecodeEngine, LogitsRow};
use super::kv::SlotMap;
use super::request::{FinishReason, RolloutRequest, RolloutResult, SchedulerStats};
use super::sampler;

struct ActiveSeq {
    req: RolloutRequest,
    slot: usize,
    /// index of the last accepted token (prompt or generated)
    pos: usize,
    /// distribution for the NEXT token — a shared view into the engine's
    /// per-call logits block, not a per-sequence copy
    pending_logits: LogitsRow,
    generated: Vec<i32>,
    logprobs: Vec<f32>,
    rng: Pcg64,
    enqueued_at: Instant,
    started_at: Instant,
}

/// Why (if at all) a sequence must stop after accepting the token at `pos`
/// (`n_generated` tokens emitted so far).  Priority: EOS > MaxNew >
/// ContextLimit.
///
/// KV-capacity audit: continuing from `pos` makes the engine decode with a
/// KV write at `pos` and logits for the token at `pos + 1`, so both indices
/// must stay below `max_seq`.  Stopping when `pos + 1 >= max_seq` admits
/// `pos <= max_seq - 2` into decode — the write lands in range and the
/// final context position `max_seq - 1` is still reachable by sampling.
/// The naive `pos >= max_seq` guard would instead decode at
/// `pos = max_seq - 1` and sample a token at index `max_seq`, one past the
/// cache (covered by tests below and the assert in `StepEngine::decode`).
fn finish_reason(tok: i32, eos_id: i32, n_generated: usize, max_new: usize,
                 pos: usize, max_seq: usize) -> Option<FinishReason> {
    if tok == eos_id {
        Some(FinishReason::Eos)
    } else if n_generated >= max_new {
        Some(FinishReason::MaxNew)
    } else if pos + 1 >= max_seq {
        Some(FinishReason::ContextLimit)
    } else {
        None
    }
}

pub struct Scheduler<E: DecodeEngine> {
    engine: E,
    slots: SlotMap,
    queue: VecDeque<(RolloutRequest, Instant)>,
    active: Vec<ActiveSeq>,
    pub stats: SchedulerStats,
    max_seq: usize,
    eos_id: i32,
    /// admit new requests only when at least this many can prefill together
    /// (dynamic batching knob; 1 = admit eagerly)
    pub min_prefill_batch: usize,
    /// group-shared prefix prefill: within one admission batch, requests
    /// with identical prompts prefill once and fork their KV rows into the
    /// sibling slots ([`DecodeEngine::fork_kv`]).  Exact for greedy AND
    /// sampled decode (prefill logits/KV depend only on the prompt; sampling
    /// state stays per-request).  Off reproduces the PR-1 per-request
    /// prefill for baseline comparisons.
    pub share_prefix: bool,
}

impl<E: DecodeEngine> Scheduler<E> {
    /// Takes the engine by value; pass `&mut engine` to lend a caller-owned
    /// engine (the blanket `DecodeEngine for &mut E` impl forwards).
    pub fn new(engine: E, max_seq: usize, eos_id: i32) -> Self {
        let b = engine.slot_count();
        Scheduler {
            engine,
            slots: SlotMap::new(b),
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: SchedulerStats::default(),
            max_seq,
            eos_id,
            min_prefill_batch: 1,
            share_prefix: true,
        }
    }

    pub fn submit(&mut self, req: RolloutRequest) {
        self.stats.submitted += 1;
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Install freshly quantized engine weights between ticks (hot
    /// requantization).  `epoch` is the service's
    /// [`WeightEpoch`](super::service::WeightEpoch) counter, surfaced in
    /// [`SchedulerStats::weight_epoch`] so metric rows show which weight
    /// generation served each step, and passed down to the engine (which
    /// replaces its resident weight handles, so the new weights convert to
    /// device format once, on their first call).
    /// Queued and active requests are untouched; their next decode simply
    /// runs under the new weights.
    pub fn swap_weights(&mut self, w: E::Weights, epoch: u64) {
        self.engine.swap_weights(w, epoch);
        self.stats.weight_epoch = epoch;
    }

    /// Cancel every queued and active request at once (error recovery /
    /// shutdown): all KV slots recycle, every removed request counts as
    /// cancelled, so the `completed + cancelled == submitted` ledger stays
    /// balanced even after an aborted run.  Returns how many requests were
    /// aborted.  Unlike [`Scheduler::cancel`] the partials are dropped —
    /// callers abort precisely when the outputs are no longer trustworthy.
    pub fn abort_all(&mut self) -> usize {
        let mut n = 0;
        while self.queue.pop_front().is_some() {
            self.stats.cancelled += 1;
            n += 1;
        }
        for a in self.active.drain(..) {
            self.slots.release(a.slot, a.req.id);
            self.stats.cancelled += 1;
            n += 1;
        }
        n
    }

    /// Drain the counters for this scheduler, preserving the weight-epoch
    /// *level* (it is a generation marker, not a per-run delta — resetting
    /// it to 0 would make a later stats row claim the engine regressed to
    /// its initial weights).  The engine's staged-byte counters drain here
    /// too, so `bytes_h2d`/`bytes_d2h` land in the same stats row as the
    /// decode/prefill call counts they pair with.
    pub fn take_stats(&mut self) -> SchedulerStats {
        let (h2d, d2h) = self.engine.take_transfer();
        self.stats.bytes_h2d += h2d;
        self.stats.bytes_d2h += d2h;
        let st = std::mem::take(&mut self.stats);
        self.stats.weight_epoch = st.weight_epoch;
        st
    }

    /// Remove a request wherever it currently lives — still queued (its
    /// prefill never happens) or actively decoding (its KV slot frees
    /// immediately).  Returns the partial output with
    /// [`FinishReason::Cancelled`], or `None` when the id is unknown or
    /// already completed.  Cancelled requests never appear in
    /// [`Scheduler::tick`] results; on a drained scheduler
    /// `completed + cancelled == submitted`.
    pub fn cancel(&mut self, id: u64) -> Option<RolloutResult> {
        if let Some(qi) = self.queue.iter().position(|(r, _)| r.id == id) {
            let (req, t_enq) = self.queue.remove(qi).unwrap();
            self.stats.cancelled += 1;
            return Some(RolloutResult {
                id: req.id,
                generated: Vec::new(),
                logprobs: Vec::new(),
                finish: FinishReason::Cancelled,
                queue_wait_s: t_enq.elapsed().as_secs_f64(),
                service_s: 0.0,
            });
        }
        if let Some(ai) = self.active.iter().position(|a| a.req.id == id) {
            let a = self.active.swap_remove(ai);
            self.slots.release(a.slot, a.req.id);
            self.stats.cancelled += 1;
            return Some(RolloutResult {
                id: a.req.id,
                generated: a.generated,
                logprobs: a.logprobs,
                finish: FinishReason::Cancelled,
                queue_wait_s: (a.started_at - a.enqueued_at).as_secs_f64(),
                service_s: a.started_at.elapsed().as_secs_f64(),
            });
        }
        None
    }

    /// Admit queued requests into free slots (batched prefill).  With
    /// `share_prefix`, duplicate prompts within the batch prefill once and
    /// fork KV into the sibling slots — `prefill_rows` counts only the
    /// representative rows, `forked` the rows saved.
    fn admit(&mut self) -> Result<()> {
        let admissible = self.queue.len().min(self.slots.free_count());
        if admissible == 0
            || (admissible < self.min_prefill_batch
                && !self.active.is_empty())
        {
            return Ok(());
        }
        let mut newly = Vec::new();
        for _ in 0..admissible {
            let (req, t_enq) = self.queue.pop_front().unwrap();
            let slot = self.slots.acquire(req.id).expect("free slot");
            newly.push((req, t_enq, slot));
        }
        // cluster identical prompts: reps[k] is the newly-index of cluster
        // k's representative; rep_for[i] is request i's cluster.  Prompts
        // are Arc-shared end-to-end (one group's members hold the same
        // allocation), so the common case resolves by pointer identity
        // before falling back to a content compare.
        let mut reps: Vec<usize> = Vec::new();
        let mut rep_for: Vec<usize> = Vec::with_capacity(newly.len());
        for i in 0..newly.len() {
            let found = if self.share_prefix {
                reps.iter().position(|&r| {
                    let (a, b) = (&newly[r].0.prompt, &newly[i].0.prompt);
                    Arc::ptr_eq(a, b) || a == b
                })
            } else {
                None
            };
            match found {
                Some(k) => rep_for.push(k),
                None => {
                    rep_for.push(reps.len());
                    reps.push(i);
                }
            }
        }
        let slots: Vec<usize> = reps.iter().map(|&i| newly[i].2).collect();
        // borrowed, not cloned: the engine reads prompt tokens in place
        let prompts: Vec<&[i32]> =
            reps.iter().map(|&i| newly[i].0.prompt.as_slice()).collect();
        self.stats.prefill_calls += 1;
        self.stats.prefill_rows += reps.len();
        let logits = self.engine.prefill(&slots, &prompts)?;
        drop(prompts);
        for (k, &ri) in reps.iter().enumerate() {
            let dsts: Vec<usize> = (0..newly.len())
                .filter(|&i| i != ri && rep_for[i] == k)
                .map(|i| newly[i].2)
                .collect();
            if !dsts.is_empty() {
                // prefix-limited fork: only the prompt_len rows carry state
                self.engine.fork_kv(newly[ri].2, &dsts,
                                    newly[ri].0.prompt.len())?;
                self.stats.forked += dsts.len();
            }
        }
        for (i, (req, t_enq, slot)) in newly.into_iter().enumerate() {
            let rng = Pcg64::new(req.seed);
            self.active.push(ActiveSeq {
                pos: req.prompt.len() - 1,
                // Rc bump into the shared block — forked siblings reference
                // the representative's prefill row, no vocab-sized copy
                pending_logits: logits[rep_for[i]].clone(),
                generated: Vec::new(),
                logprobs: Vec::new(),
                rng,
                enqueued_at: t_enq,
                started_at: Instant::now(),
                req,
                slot,
            });
        }
        Ok(())
    }

    /// One scheduler tick: admit, sample pending distributions, decode.
    /// Returns rollouts that completed this tick.
    pub fn tick(&mut self) -> Result<Vec<RolloutResult>> {
        self.admit()?;
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        // sample next token for every active sequence
        let mut finished: Vec<RolloutResult> = Vec::new();
        let mut decode_rows: Vec<(usize, i32, i32)> = Vec::new();
        let mut decode_idx: Vec<usize> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            let (tok, lp) = sampler::sample(a.pending_logits.as_slice(),
                                            a.req.temperature, a.req.top_p,
                                            &mut a.rng);
            a.generated.push(tok);
            a.logprobs.push(lp);
            a.pos += 1; // the new token's index
            self.stats.generated_tokens += 1;
            let finish = finish_reason(tok, self.eos_id, a.generated.len(),
                                       a.req.max_new, a.pos, self.max_seq);
            if let Some(reason) = finish {
                let a = self.active.swap_remove(i);
                self.slots.release(a.slot, a.req.id);
                self.stats.completed += 1;
                let queue_wait_s = (a.started_at - a.enqueued_at).as_secs_f64();
                self.stats.queue_wait_sum_s += queue_wait_s;
                finished.push(RolloutResult {
                    id: a.req.id,
                    generated: a.generated,
                    logprobs: a.logprobs,
                    finish: reason,
                    queue_wait_s,
                    service_s: a.started_at.elapsed().as_secs_f64(),
                });
            } else {
                decode_rows.push((a.slot, a.pos as i32, tok));
                decode_idx.push(i);
                i += 1;
            }
        }
        // lockstep decode for survivors
        if !decode_rows.is_empty() {
            self.stats.decode_calls += 1;
            self.stats.occupancy_sum +=
                decode_rows.len() as f64 / self.engine.slot_count() as f64;
            let logits = self.engine.decode(&decode_rows)?;
            for (&idx, lg) in decode_idx.iter().zip(logits) {
                self.active[idx].pending_logits = lg;
            }
        }
        self.stats.decode_steps += 1;
        Ok(finished)
    }

    /// Drive to completion; returns all results (submission order not
    /// guaranteed — callers match by id).
    pub fn run_to_completion(&mut self) -> Result<Vec<RolloutResult>> {
        let t0 = Instant::now();
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.tick()?);
        }
        self.stats.wall_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::mock::MockEngine;
    use super::*;

    const MAX_SEQ: usize = 16;
    const EOS: i32 = 2;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> RolloutRequest {
        RolloutRequest {
            id,
            prompt: Arc::new((0..prompt_len).map(|i| 3 + (i as i32 % 5))
                .collect()),
            max_new,
            // greedy: the mock's argmax stream is deterministic and can hit
            // EOS, exercising all three finish reasons
            temperature: 0.0,
            top_p: 1.0,
            seed: id ^ 0x5eed,
        }
    }

    /// Boundary case from the KV-capacity audit: prompt_len + max_new ==
    /// max_seq must complete without any decode position reaching max_seq,
    /// and generation may legitimately fill the very last context slot.
    #[test]
    fn context_boundary_no_out_of_range_decode() {
        for prompt_len in [1usize, 4, MAX_SEQ - 1] {
            let mut eng = MockEngine::new(2, 8, MAX_SEQ, EOS);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            sched.submit(req(0, prompt_len, MAX_SEQ - prompt_len));
            let results = sched.run_to_completion().unwrap();
            assert_eq!(results.len(), 1);
            let r = &results[0];
            assert!(r.generated.len() <= MAX_SEQ - prompt_len);
            // last accepted token index stays in context
            assert!(prompt_len - 1 + r.generated.len() <= MAX_SEQ - 1);
            // MockEngine::decode asserts pos < max_seq; double-check here
            assert!((eng.max_pos_seen as usize) < MAX_SEQ);
        }
    }

    /// An unbounded request must stop via ContextLimit exactly when the
    /// last context index is consumed — never one token later.
    #[test]
    fn context_limit_fires_at_last_index() {
        let prompt_len = 5;
        let mut eng = MockEngine::new(1, 8, MAX_SEQ, 127 /* unreachable eos */);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
        sched.submit(req(0, prompt_len, usize::MAX));
        let results = sched.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish, FinishReason::ContextLimit);
        assert_eq!(results[0].generated.len(), MAX_SEQ - prompt_len);
        assert!((eng.max_pos_seen as usize) < MAX_SEQ);
    }

    /// finish_reason truth table around the boundary.
    #[test]
    fn finish_reason_priorities() {
        // EOS wins over everything
        assert_eq!(finish_reason(EOS, EOS, 1, 1, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::Eos));
        // MaxNew before ContextLimit when both bind
        assert_eq!(finish_reason(5, EOS, 4, 4, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::MaxNew));
        // last usable index triggers ContextLimit...
        assert_eq!(finish_reason(5, EOS, 1, 8, MAX_SEQ - 1, MAX_SEQ),
                   Some(FinishReason::ContextLimit));
        // ...one before it does not (decode at max_seq-2 is in range)
        assert_eq!(finish_reason(5, EOS, 1, 8, MAX_SEQ - 2, MAX_SEQ), None);
    }

    /// Identical prompts admitted together prefill once and fork KV into
    /// the sibling slots; greedy outputs match per-request prefill exactly
    /// (the fork_kv ≡ fresh-prefill contract, mock side).
    #[test]
    fn shared_prefix_fork_matches_fresh_prefill() {
        let run = |share: bool| {
            let mut eng = MockEngine::new(4, 8, MAX_SEQ, EOS);
            let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
            sched.share_prefix = share;
            for id in 0..4u64 {
                let mut r = req(0, 5, 8);
                r.id = id; // same prompt in every request
                sched.submit(r);
            }
            let mut results = sched.run_to_completion().unwrap();
            results.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> =
                results.iter().map(|r| r.generated.clone()).collect();
            (toks, eng.prefill_rows, eng.forked_slots)
        };
        let (shared, rows_shared, forked) = run(true);
        let (plain, rows_plain, forked_off) = run(false);
        assert_eq!(shared, plain, "fork_kv diverged from fresh prefill");
        assert_eq!((rows_shared, forked), (1, 3));
        assert_eq!((rows_plain, forked_off), (4, 0));
        // greedy group members are identical sequences
        assert!(shared.windows(2).all(|w| w[0] == w[1]));
    }

    /// cancel() removes queued requests before prefill and active requests
    /// mid-decode; cancelled ids never surface in tick results and the
    /// drained ledger balances (completed + cancelled == submitted).
    #[test]
    fn cancel_queued_and_active() {
        let mut eng = MockEngine::new(2, 8, MAX_SEQ, 127 /* no eos */);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, 127);
        for id in 0..4u64 {
            sched.submit(req(id, 3, 6));
        }
        // first tick admits 2 of 4 (B = 2); the rest stay queued
        let t = sched.tick().unwrap();
        assert!(t.is_empty());
        let c_active = sched.cancel(0).unwrap();
        assert_eq!(c_active.finish, FinishReason::Cancelled);
        assert!(!c_active.generated.is_empty(), "active had begun decoding");
        let c_queued = sched.cancel(3).unwrap();
        assert!(c_queued.generated.is_empty(), "queued never decoded");
        assert!(sched.cancel(3).is_none(), "double cancel must be a no-op");
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2], "cancelled ids leaked into results");
        assert!(results.iter().all(|r| r.finish != FinishReason::Cancelled));
        assert_eq!(sched.stats.cancelled, 2);
        assert_eq!(sched.stats.completed + sched.stats.cancelled,
                   sched.stats.submitted);
    }

    /// More requests than slots: all complete exactly once, slots recycle.
    #[test]
    fn oversubscribed_queue_drains() {
        let mut eng = MockEngine::new(3, 8, MAX_SEQ, EOS);
        let mut sched = Scheduler::new(&mut eng, MAX_SEQ, EOS);
        for id in 0..10u64 {
            sched.submit(req(id, 1 + (id as usize % 4), 6));
        }
        let mut results = sched.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(sched.stats.completed, sched.stats.submitted);
        assert!(sched.stats.mean_occupancy() <= 1.0 + 1e-9);
        assert!(sched.stats.mean_queue_wait_s() >= 0.0);
    }
}
