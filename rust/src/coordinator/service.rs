//! Reward-aware rollout service: the layer between the RL trainer and the
//! continuous-batching [`Scheduler`]s.
//!
//! The scheduler is a request-level primitive — it knows nothing about RL.
//! QuRL's rollouts, however, come in *groups* (`group_size` samples of one
//! prompt for GRPO/DAPO advantages), and that structure is worth money at
//! serving time:
//!
//! * **group-shared prefix prefill** — all members of a group share the
//!   full prompt, so the service submits them together and the scheduler
//!   prefills the prompt once, forking its KV rows into the sibling slots
//!   ([`DecodeEngine::fork_kv`]); prefill work drops ~`group_size`×;
//! * **in-flight pruning ("Prune as You Generate")** — DAPO discards
//!   groups whose rewards are all identical (they carry zero advantage).
//!   Instead of filtering *after* every member has burned its full decode
//!   budget, the service scores each member the moment it finishes (the
//!   caller's reward closure) and, once [`PrunePolicy::min_finished`]
//!   members agree, cancels the group's queued/active remainder via
//!   [`Scheduler::cancel`] — freeing slots for groups that still matter;
//! * **multi-engine striping** — the service fronts several engines (one
//!   scheduler each, e.g. one per precision or replica) behind a single
//!   submission interface, striping whole groups round-robin (fork_kv is
//!   intra-engine) and merging the per-engine [`SchedulerStats`].
//!
//! The trainer's rollout path reduces to "submit [`GroupSpec`]s, collect
//! [`GroupResult`]s"; group expansion, per-member seeds and reward-driven
//! cancellation all live here.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use super::engine::DecodeEngine;
use super::request::{FinishReason, RolloutRequest, RolloutResult,
                     SchedulerStats};
use super::scheduler::Scheduler;

/// One prompt to roll out `group_size` times (a GRPO/DAPO group).
#[derive(Clone, Debug)]
pub struct GroupSpec {
    /// caller-chosen id echoed back on the [`GroupResult`]
    pub group_id: usize,
    /// prompt token ids (BOS included), shared by every member
    pub prompt: Vec<i32>,
    pub group_size: usize,
    pub max_new: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// base sampling seed; member `i` decodes with a stream derived from
    /// `seed + i` so siblings diverge under temperature sampling
    pub seed: u64,
}

/// Outcome of one group member.
#[derive(Clone, Debug)]
pub struct GroupMember {
    /// completed rollout, or the partial output at cancellation time
    /// (`finish == Cancelled`)
    pub result: RolloutResult,
    /// reward reported by the caller's reward closure; `None` for
    /// cancelled members (they were never scored)
    pub reward: Option<f32>,
}

/// A resolved group: every member either completed or was cancelled.
#[derive(Clone, Debug)]
pub struct GroupResult {
    pub group_id: usize,
    /// engine index the group was striped onto
    pub engine: usize,
    /// member order matches submission order within the group
    pub members: Vec<GroupMember>,
    /// true when the prune policy cancelled part of the group in flight
    pub pruned: bool,
}

impl GroupResult {
    /// Every member ran to completion (nothing was pruned away).
    pub fn complete(&self) -> bool {
        self.members
            .iter()
            .all(|m| m.result.finish != FinishReason::Cancelled)
    }

    /// DAPO signal: at least two scored members disagree on reward.
    pub fn informative(&self) -> bool {
        let mut first: Option<f32> = None;
        for m in self.members.iter().filter_map(|m| m.reward) {
            match first {
                None => first = Some(m),
                Some(f) if (m - f).abs() > 1e-6 => return true,
                Some(_) => {}
            }
        }
        false
    }

    /// Decode tokens this group consumed (completed + cancelled partials).
    pub fn generated_tokens(&self) -> usize {
        self.members.iter().map(|m| m.result.generated.len()).sum()
    }
}

/// When the service may cancel the in-flight remainder of a group.
#[derive(Clone, Copy, Debug)]
pub struct PrunePolicy {
    pub enabled: bool,
    /// minimum finished members, all with identical reward, before the
    /// group is predicted uninformative and its siblings cancelled.
    /// Higher = fewer mispredictions (a late member could still have
    /// differed), lower = more decode budget recovered — the PAYG
    /// trade-off.
    pub min_finished: usize,
}

impl PrunePolicy {
    pub fn off() -> PrunePolicy {
        PrunePolicy { enabled: false, min_finished: usize::MAX }
    }

    pub fn online(min_finished: usize) -> PrunePolicy {
        PrunePolicy { enabled: true, min_finished: min_finished.max(2) }
    }
}

struct GroupState {
    group_id: usize,
    engine: usize,
    size: usize,
    /// scheduler request id per member
    uids: Vec<u64>,
    outcomes: Vec<Option<GroupMember>>,
    finished: usize,
    cancelled: usize,
    pruned: bool,
}

pub struct RolloutService<E: DecodeEngine> {
    scheds: Vec<Scheduler<E>>,
    groups: Vec<GroupState>,
    /// request id -> (group index, member index)
    by_uid: HashMap<u64, (usize, usize)>,
    next_uid: u64,
    /// round-robin striping cursor
    next_engine: usize,
    pub prune: PrunePolicy,
    /// service-loop wall time, merged into the drained stats
    wall_s: f64,
}

impl<E: DecodeEngine> RolloutService<E> {
    pub fn new(engines: Vec<E>, max_seq: usize, eos_id: i32) -> Self {
        assert!(!engines.is_empty(), "service needs at least one engine");
        let scheds = engines
            .into_iter()
            .map(|e| Scheduler::new(e, max_seq, eos_id))
            .collect();
        RolloutService {
            scheds,
            groups: Vec::new(),
            by_uid: HashMap::new(),
            next_uid: 0,
            next_engine: 0,
            prune: PrunePolicy::off(),
            wall_s: 0.0,
        }
    }

    pub fn engines(&self) -> usize {
        self.scheds.len()
    }

    /// Apply the dynamic-batching admission floor to every engine queue.
    pub fn set_min_prefill_batch(&mut self, n: usize) {
        for s in &mut self.scheds {
            s.min_prefill_batch = n.max(1);
        }
    }

    /// Toggle group-shared prefix prefill (on by default; off reproduces
    /// the per-request PR-1 prefill for baselines).
    pub fn set_share_prefix(&mut self, on: bool) {
        for s in &mut self.scheds {
            s.share_prefix = on;
        }
    }

    /// Submit a group.  All members land on one engine (fork_kv is an
    /// intra-engine cache copy) contiguously, so they admit together and
    /// share one prefill whenever slots allow; groups stripe round-robin
    /// across engines.
    pub fn submit_group(&mut self, spec: GroupSpec) {
        assert!(spec.group_size > 0, "empty group");
        let engine = self.next_engine;
        self.next_engine = (self.next_engine + 1) % self.scheds.len();
        let gi = self.groups.len();
        let mut uids = Vec::with_capacity(spec.group_size);
        for member in 0..spec.group_size {
            let uid = self.next_uid;
            self.next_uid += 1;
            self.by_uid.insert(uid, (gi, member));
            self.scheds[engine].submit(RolloutRequest {
                id: uid,
                prompt: spec.prompt.clone(),
                max_new: spec.max_new,
                temperature: spec.temperature,
                top_p: spec.top_p,
                seed: spec
                    .seed
                    .wrapping_add(member as u64)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15),
            });
            uids.push(uid);
        }
        self.groups.push(GroupState {
            group_id: spec.group_id,
            engine,
            size: spec.group_size,
            uids,
            outcomes: vec![None; spec.group_size],
            finished: 0,
            cancelled: 0,
            pruned: false,
        });
    }

    /// Drive every engine to completion, scoring members with `reward_fn`
    /// (called once per completed member, with the caller's `group_id`) and
    /// pruning decided groups in flight per [`Self::prune`].  Returns the
    /// resolved groups in submission order.
    pub fn run<F>(&mut self, mut reward_fn: F) -> Result<Vec<GroupResult>>
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        let t0 = Instant::now();
        loop {
            let mut progressed = false;
            for e in 0..self.scheds.len() {
                if self.scheds[e].pending() == 0 {
                    continue;
                }
                progressed = true;
                let finished = self.scheds[e].tick()?;
                for res in finished {
                    self.absorb(res, &mut reward_fn);
                }
            }
            if !progressed {
                break;
            }
        }
        self.wall_s += t0.elapsed().as_secs_f64();
        self.by_uid.clear();
        let mut out = Vec::with_capacity(self.groups.len());
        for g in self.groups.drain(..) {
            assert_eq!(g.finished + g.cancelled, g.size,
                       "group {} resolved {}/{} members",
                       g.group_id, g.finished + g.cancelled, g.size);
            out.push(GroupResult {
                group_id: g.group_id,
                engine: g.engine,
                members: g
                    .outcomes
                    .into_iter()
                    .map(|o| o.expect("member unresolved"))
                    .collect(),
                pruned: g.pruned,
            });
        }
        Ok(out)
    }

    /// Record one completed member; if its group is now decided-uniform,
    /// cancel the group's queued/active remainder.
    fn absorb<F>(&mut self, res: RolloutResult, reward_fn: &mut F)
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        let (gi, mi) = self.by_uid[&res.id];
        let reward = reward_fn(self.groups[gi].group_id, &res);
        {
            let g = &mut self.groups[gi];
            g.finished += 1;
            g.outcomes[mi] =
                Some(GroupMember { result: res, reward: Some(reward) });
        }
        if !self.prune.enabled {
            return;
        }
        let (engine, to_cancel) = {
            let g = &self.groups[gi];
            if g.pruned
                || g.finished < self.prune.min_finished
                || g.finished + g.cancelled >= g.size
            {
                return;
            }
            let rewards: Vec<f32> = g
                .outcomes
                .iter()
                .flatten()
                .filter_map(|m| m.reward)
                .collect();
            let uniform =
                rewards.iter().all(|&r| (r - rewards[0]).abs() <= 1e-6);
            if !uniform {
                return;
            }
            let to_cancel: Vec<(usize, u64)> = g
                .uids
                .iter()
                .enumerate()
                .filter(|&(m, _)| g.outcomes[m].is_none())
                .map(|(m, &u)| (m, u))
                .collect();
            (g.engine, to_cancel)
        };
        // Cancel first, flag after: siblings may have completed in the same
        // tick batch (cancel returns None for them), and a group where no
        // cancel landed saved nothing — it must not count as pruned in the
        // stats or carry `GroupResult::pruned`.
        let mut any_cancelled = false;
        for (m, uid) in to_cancel {
            if let Some(partial) = self.scheds[engine].cancel(uid) {
                any_cancelled = true;
                let g = &mut self.groups[gi];
                g.cancelled += 1;
                g.outcomes[m] =
                    Some(GroupMember { result: partial, reward: None });
            }
        }
        if any_cancelled {
            self.groups[gi].pruned = true;
            self.scheds[engine].stats.pruned_groups += 1;
        }
    }

    /// Drain the merged per-engine counters (plus the service-loop wall
    /// time), resetting them for the next run — the trainer logs one
    /// `sched_*` Recorder row per RL step from this.
    pub fn take_stats(&mut self) -> SchedulerStats {
        let mut out = SchedulerStats::default();
        for s in &mut self.scheds {
            let st = std::mem::take(&mut s.stats);
            out.merge(&st);
        }
        out.wall_s += self.wall_s;
        self.wall_s = 0.0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::mock::MockEngine;
    use super::*;

    const MAX_SEQ: usize = 24;
    const VOCAB: usize = 8;
    const EOS: i32 = 2;

    fn spec(group_id: usize, prompt_sig: i32, g: usize, temp: f32)
            -> GroupSpec {
        GroupSpec {
            group_id,
            prompt: vec![1, 3 + (prompt_sig % 5), 4, 5],
            group_size: g,
            max_new: 12,
            temperature: temp,
            top_p: 1.0,
            seed: 0x5eed ^ ((group_id as u64) << 8),
        }
    }

    fn service(n_engines: usize, slots: usize)
               -> RolloutService<MockEngine> {
        let engines: Vec<MockEngine> = (0..n_engines)
            .map(|_| MockEngine::new(slots, VOCAB, MAX_SEQ, EOS))
            .collect();
        RolloutService::new(engines, MAX_SEQ, EOS)
    }

    /// Striping over several engines: every group resolves completely, on
    /// its round-robin engine, and the merged ledger balances.
    #[test]
    fn striped_groups_all_complete() {
        let mut svc = service(3, 4);
        let (n_groups, g) = (7, 4);
        for gid in 0..n_groups {
            svc.submit_group(spec(gid, gid as i32, g, 1.0));
        }
        let results = svc.run(|_, res| res.generated.len() as f32).unwrap();
        assert_eq!(results.len(), n_groups);
        for (i, gr) in results.iter().enumerate() {
            assert_eq!(gr.group_id, i, "submission order preserved");
            assert_eq!(gr.engine, i % 3, "round-robin striping");
            assert_eq!(gr.members.len(), g);
            assert!(gr.complete());
            assert!(!gr.pruned);
            assert!(gr.members.iter().all(|m| m.reward.is_some()));
        }
        let st = svc.take_stats();
        assert_eq!(st.submitted, n_groups * g);
        assert_eq!(st.completed, st.submitted);
        assert_eq!(st.cancelled, 0);
        // shared prefill: members share prompts, so rows < submissions
        assert!(st.prefill_rows < st.submitted);
        assert_eq!(st.prefill_rows + st.forked, st.submitted);
        // second take_stats is empty (drained)
        assert_eq!(svc.take_stats().submitted, 0);
    }

    /// A reward that is constant for some groups and member-dependent for
    /// others: pruning must cancel only the uniform groups' remainders,
    /// keep the ledger balanced, and strictly reduce decoded tokens vs the
    /// same workload without pruning.
    #[test]
    fn pruning_cancels_uniform_groups_and_saves_tokens() {
        let run = |prune: bool| {
            let mut svc = service(1, 3); // B=3 < g: siblings queue
            svc.prune = if prune { PrunePolicy::online(2) } else {
                PrunePolicy::off()
            };
            let (n_groups, g) = (6, 6);
            for gid in 0..n_groups {
                svc.submit_group(spec(gid, gid as i32, g, 1.0));
            }
            // groups 0, 2, 4 uniform (uninformative); 1, 3, 5 vary by member
            let results = svc
                .run(|gid, res| {
                    if gid % 2 == 0 {
                        1.0
                    } else {
                        (res.generated.len() % 3) as f32
                    }
                })
                .unwrap();
            let tokens: usize =
                results.iter().map(|r| r.generated_tokens()).sum();
            (results, svc.take_stats(), tokens)
        };
        let (pruned_res, pruned_st, pruned_tokens) = run(true);
        let (plain_res, plain_st, plain_tokens) = run(false);
        assert_eq!(plain_st.cancelled, 0);
        assert_eq!(pruned_st.completed + pruned_st.cancelled,
                   pruned_st.submitted);
        assert!(pruned_st.cancelled > 0, "nothing was pruned");
        assert!(pruned_st.pruned_groups >= 3,
                "uniform groups not pruned: {}", pruned_st.pruned_groups);
        assert!(pruned_tokens < plain_tokens,
                "pruning saved no decode tokens: {pruned_tokens} vs \
                 {plain_tokens}");
        for gr in &pruned_res {
            if gr.pruned {
                assert!(!gr.complete());
                assert!(gr.members.iter().any(
                    |m| m.result.finish == FinishReason::Cancelled));
                // cancelled members are unscored
                assert!(gr
                    .members
                    .iter()
                    .filter(|m| m.result.finish == FinishReason::Cancelled)
                    .all(|m| m.reward.is_none()));
            }
        }
        // un-pruned run: informativeness matches the reward construction
        for gr in &plain_res {
            assert!(gr.complete());
        }
        assert!(plain_res.iter().filter(|r| !r.informative()).count() >= 3);
    }

    /// With pruning off and greedy decode, all members of a group are
    /// identical (fork ≡ fresh prefill at the service level too).
    #[test]
    fn greedy_group_members_identical() {
        let mut svc = service(2, 4);
        for gid in 0..4 {
            svc.submit_group(spec(gid, gid as i32, 4, 0.0));
        }
        let results = svc.run(|_, _| 0.0).unwrap();
        for gr in &results {
            let first = &gr.members[0].result.generated;
            for m in &gr.members {
                assert_eq!(&m.result.generated, first,
                           "greedy siblings diverged in group {}",
                           gr.group_id);
            }
        }
    }
}
