//! Reward-aware rollout service: the layer between the RL trainer and the
//! continuous-batching [`Scheduler`]s.
//!
//! The scheduler is a request-level primitive — it knows nothing about RL.
//! QuRL's rollouts, however, come in *groups* (`group_size` samples of one
//! prompt for GRPO/DAPO advantages), and that structure is worth money at
//! serving time:
//!
//! * **group-shared prefix prefill** — all members of a group share the
//!   full prompt, so the service submits them together and the scheduler
//!   prefills the prompt once, forking its KV rows into the sibling slots
//!   ([`DecodeEngine::fork_kv`]); prefill work drops ~`group_size`×;
//! * **in-flight pruning ("Prune as You Generate")** — DAPO discards
//!   groups whose rewards are all identical (they carry zero advantage).
//!   Instead of filtering *after* every member has burned its full decode
//!   budget, the service scores each member the moment it finishes (the
//!   caller's reward closure) and, once [`PrunePolicy::min_finished`]
//!   members agree, cancels the group's queued/active remainder via
//!   [`Scheduler::cancel`] — freeing slots for groups that still matter;
//! * **multi-engine execution** — the service fronts several engines (one
//!   scheduler each) behind a single submission interface, placing whole
//!   groups per [`StripePolicy`] (fork_kv is intra-engine) and merging the
//!   per-engine [`SchedulerStats`].
//!
//! # Execution backends
//!
//! The service runs its engines through one of two backends:
//!
//! * **inline** ([`RolloutService::new`]) — one thread round-robins the
//!   schedulers; zero threading overhead, works for borrowed engines, and
//!   is the reference semantics every other mode is parity-tested against;
//! * **threaded** ([`RolloutService::threaded`]) — one worker thread per
//!   engine replica.  Each worker *constructs its own engine* from a
//!   `Send` factory (for [`StepEngine`](super::StepEngine) that means
//!   opening its own `Runtime`: PJRT clients are not `Send`, so no XLA
//!   state ever crosses a thread), owns a [`Scheduler`], and ticks it
//!   whenever work is pending.  The control thread feeds it over an mpsc
//!   command channel (submissions, cancels, weight swaps, stats drains)
//!   and collects [`RolloutResult`]s from a shared completion channel, so
//!   reward scoring and cross-thread pruning stay online while all
//!   replicas decode in parallel.
//!
//! **Determinism:** a request's output depends only on its prompt, seed
//! and the engine weights (the scheduler isolation contract), and group
//! placement is computed from submission-time load estimates — never from
//! live queue depths.  Completed outputs are therefore bit-for-bit
//! identical across inline/threaded and across stripe policies
//! (property-tested); threading changes wall-clock and the *lengths of
//! cancelled partials* (a cancel directive lands asynchronously), never a
//! completed member.
//!
//! # Work stealing and the placement log
//!
//! Submission-time placement leaves the run's wall-clock set by its
//! slowest replica: early-EOS finishers, skewed lengths and online
//! pruning drain one engine while stragglers still queue on another.
//! [`StealPolicy::Idle`] closes that gap — an idle replica (free slots,
//! empty queue) pulls whole *queued, never-admitted* groups from the
//! most-loaded replica, read off live outstanding-token counters the
//! schedulers publish through shared atomics.  Whole groups only, so
//! `fork_kv` prefix sharing stays intra-engine
//! ([`Scheduler::extract_queued`] is all-or-nothing).
//!
//! Stealing reads live state, so *placement* becomes timing-dependent —
//! but outputs are engine-independent, so only attribution and
//! wall-clock can vary.  Reproducibility is restored by turning
//! placement into data: every placement and steal is appended to an
//! ordered [`PlacementLog`] (`seq, group_uid, from, to, reason`),
//! dumpable to JSON, and [`StripePolicy::Replay`] re-executes a recorded
//! log — each group goes straight to its recorded final engine, so a
//! stolen run's completed members reproduce bit-for-bit with no live
//! timing in the loop.  (Cancelled *partials* remain timing artifacts
//! under pruning, exactly as for inline vs threaded above.)
//!
//! # In-flight requantization
//!
//! [`RolloutService::push_weights`] ships freshly quantized weights to
//! every engine and bumps the monotone [`WeightEpoch`]; workers install
//! them between ticks ([`DecodeEngine::swap_weights`]) without touching KV
//! state, so `requantize_every` works at sub-step granularity and the old
//! "tear the service down and rebuild every replica" path is gone.  The
//! epoch lands in [`SchedulerStats::weight_epoch`] for observability.
//!
//! The trainer's rollout path reduces to "submit [`GroupSpec`]s, collect
//! [`GroupResult`]s"; group expansion, per-member seeds
//! ([`member_seed`]), reward-driven cancellation and placement all live
//! here.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::rng::member_seed;

use super::engine::DecodeEngine;
use super::kv::KvConfig;
use super::request::{FinishReason, RolloutRequest, RolloutResult,
                     SchedulerStats};
use super::scheduler::Scheduler;

/// One prompt to roll out `group_size` times (a GRPO/DAPO group).
#[derive(Clone, Debug)]
pub struct GroupSpec {
    /// caller-chosen id echoed back on the [`GroupResult`]
    pub group_id: usize,
    /// prompt token ids (BOS included), shared by every member
    pub prompt: Vec<i32>,
    pub group_size: usize,
    pub max_new: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// base sampling seed; member `i` decodes with the stream
    /// [`member_seed`]`(seed, i)` so siblings diverge under temperature
    /// sampling
    pub seed: u64,
}

/// Outcome of one group member.
#[derive(Clone, Debug)]
pub struct GroupMember {
    /// completed rollout, or the partial output at cancellation time
    /// (`finish == Cancelled`)
    pub result: RolloutResult,
    /// reward reported by the caller's reward closure; `None` for
    /// cancelled members (they were never scored)
    pub reward: Option<f32>,
}

/// A resolved group: every member either completed or was cancelled.
#[derive(Clone, Debug)]
pub struct GroupResult {
    pub group_id: usize,
    /// engine index the group was placed on
    pub engine: usize,
    /// member order matches submission order within the group
    pub members: Vec<GroupMember>,
    /// true when the prune policy cancelled part of the group in flight
    pub pruned: bool,
}

impl GroupResult {
    /// Every member ran to completion (nothing was pruned away).
    pub fn complete(&self) -> bool {
        self.members
            .iter()
            .all(|m| m.result.finish != FinishReason::Cancelled)
    }

    /// DAPO signal: at least two scored members disagree on reward.
    pub fn informative(&self) -> bool {
        let mut first: Option<f32> = None;
        for m in self.members.iter().filter_map(|m| m.reward) {
            match first {
                None => first = Some(m),
                Some(f) if (m - f).abs() > 1e-6 => return true,
                Some(_) => {}
            }
        }
        false
    }

    /// Decode tokens this group consumed (completed + cancelled partials).
    pub fn generated_tokens(&self) -> usize {
        self.members.iter().map(|m| m.result.generated.len()).sum()
    }
}

/// When the service may cancel the in-flight remainder of a group.
#[derive(Clone, Copy, Debug)]
pub struct PrunePolicy {
    pub enabled: bool,
    /// minimum finished members, all with identical reward, before the
    /// group is predicted uninformative and its siblings cancelled.
    /// Higher = fewer mispredictions (a late member could still have
    /// differed), lower = more decode budget recovered — the PAYG
    /// trade-off.
    pub min_finished: usize,
}

impl PrunePolicy {
    pub fn off() -> PrunePolicy {
        PrunePolicy { enabled: false, min_finished: usize::MAX }
    }

    pub fn online(min_finished: usize) -> PrunePolicy {
        PrunePolicy { enabled: true, min_finished: min_finished.max(2) }
    }
}

/// How `submit_group` places groups onto engine replicas.
///
/// `RoundRobin` and `LeastLoaded` are *deterministic in the submission
/// sequence*: placement never reads live queue depth or completion
/// timing, so a workload's placement (and therefore its outputs) is
/// identical across inline and threaded execution and across repeated
/// runs.  `Replay` is deterministic in a recorded [`PlacementLog`]
/// instead — it reproduces any run, including one whose placement was
/// perturbed by live work stealing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StripePolicy {
    /// Blind rotation: group `k` lands on engine `k % n`.
    RoundRobin,
    /// Place each group on the engine with the fewest *estimated*
    /// outstanding decode tokens, `min(prompt_len + max_new, max_seq) ×
    /// group_size` summed over the groups already placed this run.  A
    /// heavy group (long prompt, large budget, big group) stops attracting
    /// neighbors until the other replicas catch up — round-robin instead
    /// piles every `n`-th heavy group onto the same engine.
    LeastLoaded,
    /// Place each group on the final engine a recorded [`PlacementLog`]
    /// put it on (install the log with [`RolloutService::set_replay`]).
    /// Groups the log has never seen fall back to round-robin.  Stealing
    /// is a no-op under replay: the log already bakes in every steal.
    Replay,
}

impl StripePolicy {
    pub fn parse(s: &str) -> Option<StripePolicy> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Some(StripePolicy::RoundRobin),
            "least-loaded" | "ll" | "leastloaded" => Some(StripePolicy::LeastLoaded),
            "replay" => Some(StripePolicy::Replay),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StripePolicy::RoundRobin => "rr",
            StripePolicy::LeastLoaded => "least-loaded",
            StripePolicy::Replay => "replay",
        }
    }
}

/// Whether idle replicas may pull queued groups from loaded ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealPolicy {
    /// Never steal: placement is final at submission time (the legacy
    /// semantics every parity test pins down).
    Off,
    /// An idle replica — free slots and an empty local queue — steals
    /// whole queued groups from the most-loaded replica (by live
    /// outstanding tokens).  Every steal is recorded in the
    /// [`PlacementLog`] so the run stays reproducible via
    /// [`StripePolicy::Replay`].
    Idle,
}

impl StealPolicy {
    pub fn parse(s: &str) -> Option<StealPolicy> {
        match s {
            "off" | "none" => Some(StealPolicy::Off),
            "idle" | "on" => Some(StealPolicy::Idle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StealPolicy::Off => "off",
            StealPolicy::Idle => "idle",
        }
    }
}

/// Why a [`PlacementRecord`] exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementReason {
    /// initial placement at `submit_group` (`from == to`)
    Place,
    /// a live steal moved the still-queued group `from` → `to`
    Steal,
}

impl PlacementReason {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementReason::Place => "place",
            PlacementReason::Steal => "steal",
        }
    }

    pub fn parse(s: &str) -> Option<PlacementReason> {
        match s {
            "place" => Some(PlacementReason::Place),
            "steal" => Some(PlacementReason::Steal),
            _ => None,
        }
    }
}

/// One placement decision.  `group_uid` is the service-lifetime group
/// counter (never reset across runs), so a log taken after several runs
/// still lines up with the same submission sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementRecord {
    /// position in the log (0-based, dense)
    pub seq: u64,
    pub group_uid: u64,
    pub from_engine: usize,
    pub to_engine: usize,
    pub reason: PlacementReason,
}

/// Ordered record of every placement and steal a service made — the
/// determinism artifact for work stealing.  Placement under stealing
/// depends on thread timing; the log captures what actually happened as
/// data, and [`StripePolicy::Replay`] re-executes it so the run
/// reproduces bit-for-bit (completed members; cancelled-partial lengths
/// under pruning remain timing artifacts, as everywhere else).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PlacementLog {
    pub records: Vec<PlacementRecord>,
}

impl PlacementLog {
    fn push(&mut self, group_uid: u64, from: usize, to: usize,
            reason: PlacementReason) {
        let seq = self.records.len() as u64;
        self.records.push(PlacementRecord {
            seq,
            group_uid,
            from_engine: from,
            to_engine: to,
            reason,
        });
    }

    /// Engine the group ended up on: its last record wins (a stolen
    /// group has a `Place` followed by one or more `Steal`s).
    pub fn final_engine(&self, group_uid: u64) -> Option<usize> {
        self.records
            .iter()
            .rev()
            .find(|r| r.group_uid == group_uid)
            .map(|r| r.to_engine)
    }

    pub fn steals(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.reason == PlacementReason::Steal)
            .count()
    }

    pub fn to_json(&self) -> Json {
        let recs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("seq", Json::num(r.seq as f64)),
                    ("group_uid", Json::num(r.group_uid as f64)),
                    ("from_engine", Json::num(r.from_engine as f64)),
                    ("to_engine", Json::num(r.to_engine as f64)),
                    ("reason", Json::str(r.reason.name())),
                ])
            })
            .collect();
        Json::obj(vec![("placement_log", Json::Arr(recs))])
    }

    pub fn from_json(j: &Json) -> Result<PlacementLog> {
        let recs = j
            .get("placement_log")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing \"placement_log\" array"))?;
        let mut log = PlacementLog::default();
        for (i, r) in recs.iter().enumerate() {
            let field = |k: &str| {
                r.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| {
                        anyhow!("placement record {i}: bad field {k:?}")
                    })
            };
            let reason = r
                .get("reason")
                .and_then(|v| v.as_str())
                .and_then(PlacementReason::parse)
                .ok_or_else(|| {
                    anyhow!("placement record {i}: bad field \"reason\"")
                })?;
            log.records.push(PlacementRecord {
                seq: field("seq")? as u64,
                group_uid: field("group_uid")? as u64,
                from_engine: field("from_engine")?,
                to_engine: field("to_engine")?,
                reason,
            });
        }
        Ok(log)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing placement log {path:?}"))
    }

    pub fn load(path: &std::path::Path) -> Result<PlacementLog> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading placement log {path:?}"))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing placement log {path:?}"))?;
        PlacementLog::from_json(&j)
    }
}

/// Typed error for a stats drain attempted mid-run: with groups
/// outstanding, threaded workers may be emitting `Finished` events the
/// drain would swallow, so [`RolloutService::take_stats`] is only legal
/// between runs.  Mirrors [`KvTakenError`](super::engine::KvTakenError):
/// callers can `downcast_ref` it from the `anyhow` chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutstandingGroupsError {
    /// groups still unresolved at the time of the call
    pub outstanding: usize,
}

impl std::fmt::Display for OutstandingGroupsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "take_stats with {} groups outstanding — drain the run first",
               self.outstanding)
    }
}

impl std::error::Error for OutstandingGroupsError {}

/// Monotone counter identifying the weight generation engines decode with.
/// Bumped by [`RolloutService::push_weights`]; observable per engine in
/// [`SchedulerStats::weight_epoch`].  Epoch 0 is the weights the engines
/// were built with.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct WeightEpoch(pub u64);

/// The cross-run state a [`RolloutService`] carries between steps — the
/// exact set a checkpoint must capture for a rebuilt service to place,
/// seed, and log identically to one that never went away
/// ([`RolloutService::snapshot`] / [`RolloutService::restore`]).
///
/// What is *not* here, and why: per-engine [`SchedulerStats`] and the
/// service wall clock are drained by `take_stats` at every step boundary
/// (checkpoints happen right after a drain, so they are zero by
/// construction); `by_uid`/`groups` are empty between runs; `live_load`,
/// `idle_workers` and `steal_inflight` are intra-run scratch; `replay`,
/// the stripe/steal/prune policies and the scheduler knobs are
/// configuration, re-derived from the (fingerprinted) `TrainerConfig` on
/// resume rather than serialized twice.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceSnapshot {
    /// next scheduler request id ([`RolloutRequest::id`] allocator)
    pub next_uid: u64,
    /// round-robin placement cursor
    pub next_engine: usize,
    /// per-engine outstanding-cost estimate (monotone under plain
    /// least-loaded — restoring it verbatim is what keeps post-resume
    /// least-loaded placement identical to the uninterrupted run)
    pub est_load: Vec<u64>,
    /// service-lifetime group counter backing
    /// [`PlacementRecord::group_uid`]
    pub next_group_uid: u64,
    /// current [`WeightEpoch`] value
    pub epoch: u64,
    /// full placement/steal history (replay fodder and parity artifact)
    pub log: PlacementLog,
}

impl ServiceSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("next_uid", Json::num(self.next_uid as f64)),
            ("next_engine", Json::num(self.next_engine as f64)),
            ("est_load",
             Json::Arr(self.est_load.iter()
                 .map(|&x| Json::num(x as f64)).collect())),
            ("next_group_uid", Json::num(self.next_group_uid as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            ("log", self.log.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServiceSnapshot> {
        let field = |k: &str| {
            j.get(k).and_then(|v| v.as_usize()).ok_or_else(|| {
                anyhow!("service snapshot: bad field {k:?}")
            })
        };
        let est_load = j
            .get("est_load")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("service snapshot: bad field \"est_load\""))?
            .iter()
            .map(|v| {
                v.as_usize().map(|x| x as u64).ok_or_else(|| {
                    anyhow!("service snapshot: non-numeric est_load entry")
                })
            })
            .collect::<Result<Vec<u64>>>()?;
        let log = PlacementLog::from_json(
            j.get("log")
                .ok_or_else(|| anyhow!("service snapshot: bad field \"log\""))?,
        )?;
        Ok(ServiceSnapshot {
            next_uid: field("next_uid")? as u64,
            next_engine: field("next_engine")?,
            est_load,
            next_group_uid: field("next_group_uid")? as u64,
            epoch: field("epoch")? as u64,
            log,
        })
    }
}

/// Factory an engine worker thread runs to build its own engine.  `Send`
/// so it can move into the thread; the engine it returns never leaves that
/// thread, which is what lets non-`Send` engines (PJRT-backed
/// [`StepEngine`](super::StepEngine)) run threaded.
pub type EngineFactory<E> = Box<dyn FnOnce() -> Result<E> + Send>;

struct GroupState {
    group_id: usize,
    /// service-lifetime placement-log identity ([`PlacementRecord`])
    uid: u64,
    /// engine currently holding the group (updated when it is stolen)
    engine: usize,
    size: usize,
    /// estimated decode-token cost charged to `est_load` at placement;
    /// moved on steal, debited on resolution in the recorded-log world
    cost: u64,
    /// scheduler request id per member
    uids: Vec<u64>,
    outcomes: Vec<Option<GroupMember>>,
    finished: usize,
    cancelled: usize,
    pruned: bool,
    /// cancel directives were already issued for this group (at most once)
    cancel_requested: bool,
}

/// Control-thread → worker commands (threaded backend).
enum Command<W> {
    /// submit a whole group's requests (contiguous, so they co-admit and
    /// share one prefix prefill whenever slots allow)
    Submit(Vec<RolloutRequest>),
    Cancel(u64),
    SwapWeights(W, WeightEpoch),
    Configure {
        min_prefill_batch: usize,
        share_prefix: bool,
        prefill_chunk: usize,
    },
    /// Separate from `Configure` on purpose: applying a [`KvConfig`]
    /// rebuilds the engine's page ledger (tables dropped, counters reset),
    /// so it must fire only when the caller actually changes KV settings —
    /// never as a side effect of resending the other knobs.
    ConfigureKv(KvConfig),
    TakeStats,
    /// work-stealing probe on behalf of idle engine `thief`: the victim
    /// extracts the first candidate group whose members are *all* still
    /// queued (all-or-nothing, so prefix sharing stays intra-engine) and
    /// replies `Event::Stolen` — empty when nothing was stealable
    Steal {
        thief: usize,
        /// candidate groups, each a whole group's request ids
        candidates: Vec<Vec<u64>>,
    },
    AbortAll,
    Shutdown,
}

/// Worker → control-thread events.  Not generic: only plain result data
/// crosses back.
enum Event {
    /// startup handshake: the factory ran (engine built or failed)
    Ready(usize, Result<()>),
    Finished(usize, RolloutResult),
    /// reply to `Cancel`: `None` means the request had already completed
    /// (its `Finished` event is in flight or was already delivered)
    CancelOutcome(u64, Option<RolloutResult>),
    /// a tick failed; the worker aborted its scheduler (slots recycled,
    /// ledger balanced) before reporting, and stays servable
    TickError(usize, anyhow::Error),
    Stats(usize, SchedulerStats),
    /// the worker has free slots and an empty queue — a steal
    /// opportunity; re-armed by its next `Submit`
    Idle(usize),
    /// reply to `Steal`: the extracted whole-group requests (empty =
    /// nothing on the victim was still fully queued)
    Stolen {
        victim: usize,
        thief: usize,
        reqs: Vec<RolloutRequest>,
    },
    Aborted(usize),
}

struct WorkerHandle<W> {
    cmd: Sender<Command<W>>,
    join: Option<JoinHandle<()>>,
}

enum Backend<E: DecodeEngine> {
    Inline(Vec<Scheduler<E>>),
    Threaded {
        workers: Vec<WorkerHandle<E::Weights>>,
        events: Receiver<Event>,
    },
}

/// Engine-worker main loop: build the engine, own a scheduler, drain
/// commands (they outrank decode work — a cancel or weight swap must land
/// before the next tick), tick when requests are pending, block when idle.
///
/// Steal participation: the worker publishes its live outstanding-token
/// count into `live[idx]` every iteration, and announces [`Event::Idle`]
/// once per `Submit` generation when its queue is empty with slots free
/// (never before its first `Submit`, so the startup handshake sees only
/// `Ready`).  Whether anything is done with that is the control thread's
/// policy call — the worker is steal-policy-oblivious.
fn worker_loop<E: DecodeEngine>(idx: usize, factory: EngineFactory<E>,
                                cmds: Receiver<Command<E::Weights>>,
                                events: Sender<Event>, max_seq: usize,
                                eos_id: i32, live: Arc<Vec<AtomicU64>>) {
    let engine = match factory() {
        Ok(e) => {
            let _ = events.send(Event::Ready(idx, Ok(())));
            e
        }
        Err(e) => {
            let _ = events.send(Event::Ready(idx, Err(e)));
            return;
        }
    };
    let mut sched = Scheduler::new(engine, max_seq, eos_id);
    let mut saw_work = false;
    let mut announced_idle = false;
    loop {
        live[idx].store(sched.outstanding_tokens(), Ordering::Relaxed);
        if saw_work && !announced_idle && sched.queue_len() == 0
            && sched.free_slots() > 0
        {
            announced_idle = true;
            if events.send(Event::Idle(idx)).is_err() {
                return;
            }
        }
        let cmd = if sched.pending() == 0 {
            // idle: park until the next command (or service drop)
            match cmds.recv() {
                Ok(c) => Some(c),
                Err(_) => return,
            }
        } else {
            match cmds.try_recv() {
                Ok(c) => Some(c),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => return,
            }
        };
        if let Some(cmd) = cmd {
            match cmd {
                Command::Submit(reqs) => {
                    saw_work = true;
                    announced_idle = false; // re-arm the idle announcement
                    for r in reqs {
                        sched.submit(r);
                    }
                }
                Command::Cancel(uid) => {
                    let out = sched.cancel(uid);
                    if events.send(Event::CancelOutcome(uid, out)).is_err() {
                        return;
                    }
                }
                Command::SwapWeights(w, epoch) => {
                    sched.swap_weights(w, epoch.0);
                }
                Command::Configure {
                    min_prefill_batch,
                    share_prefix,
                    prefill_chunk,
                } => {
                    sched.min_prefill_batch = min_prefill_batch.max(1);
                    sched.share_prefix = share_prefix;
                    sched.prefill_chunk = prefill_chunk;
                }
                Command::ConfigureKv(cfg) => {
                    sched.set_kv(cfg);
                }
                Command::TakeStats => {
                    let st = sched.take_stats();
                    if events.send(Event::Stats(idx, st)).is_err() {
                        return;
                    }
                }
                Command::Steal { thief, candidates } => {
                    // victim side: hand over the first candidate that is
                    // still fully queued here (the service's view can be
                    // stale — members may have admitted since the probe)
                    let mut reqs = Vec::new();
                    for cand in candidates {
                        if let Some(r) = sched.extract_queued(&cand) {
                            reqs = r;
                            break;
                        }
                    }
                    live[idx].store(sched.outstanding_tokens(),
                                    Ordering::Relaxed);
                    let ev = Event::Stolen { victim: idx, thief, reqs };
                    if events.send(ev).is_err() {
                        return;
                    }
                }
                Command::AbortAll => {
                    sched.abort_all();
                    if events.send(Event::Aborted(idx)).is_err() {
                        return;
                    }
                }
                Command::Shutdown => return,
            }
            continue; // drain every queued command before the next tick
        }
        match sched.tick() {
            Ok(done) => {
                for r in done {
                    if events.send(Event::Finished(idx, r)).is_err() {
                        return;
                    }
                }
            }
            Err(e) => {
                // leave no half-decoded state behind: abort everything
                // (slots recycle, ledger balances) before reporting, so
                // this worker stays servable for the next run
                sched.abort_all();
                if events.send(Event::TickError(idx, e)).is_err() {
                    return;
                }
            }
        }
    }
}

fn new_live_load(n: usize) -> Arc<Vec<AtomicU64>> {
    Arc::new((0..n).map(|_| AtomicU64::new(0)).collect())
}

pub struct RolloutService<E: DecodeEngine> {
    backend: Backend<E>,
    groups: Vec<GroupState>,
    /// request id -> (group index, member index)
    by_uid: HashMap<u64, (usize, usize)>,
    next_uid: u64,
    /// round-robin placement cursor
    next_engine: usize,
    /// estimated outstanding decode tokens per engine, accumulated from
    /// submissions and reset when a run drains.  Under plain least-loaded
    /// it is NEVER decremented on completion (that would make placement
    /// depend on thread timing); with stealing or replay active the
    /// [`PlacementLog`] carries the determinism story instead, so the
    /// estimate tracks live drain ([`Self::debit_if_resolved`])
    est_load: Vec<u64>,
    /// live outstanding-token counters, one per engine, shared with the
    /// worker threads (inline: refreshed by the service loop itself) —
    /// the signal steal victim selection reads
    live_load: Arc<Vec<AtomicU64>>,
    pub stripe: StripePolicy,
    pub steal: StealPolicy,
    /// ordered record of every placement and steal (service-lifetime;
    /// survives runs and stats drains)
    log: PlacementLog,
    /// recorded log driving placement when `stripe == Replay`
    replay: Option<PlacementLog>,
    /// service-lifetime group counter backing [`PlacementRecord::group_uid`]
    /// — never reset, so a multi-run log lines up with the same
    /// submission sequence
    next_group_uid: u64,
    /// whole groups stolen *into* each engine since the last stats drain
    steal_count: Vec<usize>,
    /// engines that announced `Idle` and still wait for work (threaded)
    idle_workers: HashSet<usize>,
    /// thieves with a `Steal` probe in flight (threaded; one per thief)
    steal_inflight: HashSet<usize>,
    epoch: WeightEpoch,
    /// groups whose in-flight remainder was pruned, per engine; folded
    /// into the drained stats (service-side so both backends agree)
    pruned_groups: Vec<usize>,
    /// per-engine view of the last [`Self::take_stats`] drain
    last_engine_stats: Vec<SchedulerStats>,
    max_seq: usize,
    /// last applied scheduler knobs — threaded Configure commands resend
    /// absolute values, so each setter must know the other's current state
    cfg_min_prefill: usize,
    cfg_share_prefix: bool,
    cfg_prefill_chunk: usize,
    pub prune: PrunePolicy,
    /// service-loop wall time, merged into the drained stats
    wall_s: f64,
}

impl<E: DecodeEngine> RolloutService<E> {
    /// Inline backend: the calling thread drives all schedulers
    /// round-robin.  Reference semantics; works for borrowed engines.
    pub fn new(engines: Vec<E>, max_seq: usize, eos_id: i32) -> Self {
        assert!(!engines.is_empty(), "service needs at least one engine");
        let scheds: Vec<Scheduler<E>> = engines
            .into_iter()
            .map(|e| Scheduler::new(e, max_seq, eos_id))
            .collect();
        let n = scheds.len();
        let live = new_live_load(n);
        Self::with_backend(Backend::Inline(scheds), n, max_seq, live)
    }

    fn with_backend(backend: Backend<E>, n: usize, max_seq: usize,
                    live_load: Arc<Vec<AtomicU64>>) -> Self {
        RolloutService {
            backend,
            groups: Vec::new(),
            by_uid: HashMap::new(),
            next_uid: 0,
            next_engine: 0,
            est_load: vec![0; n],
            live_load,
            stripe: StripePolicy::RoundRobin,
            steal: StealPolicy::Off,
            log: PlacementLog::default(),
            replay: None,
            next_group_uid: 0,
            steal_count: vec![0; n],
            idle_workers: HashSet::new(),
            steal_inflight: HashSet::new(),
            epoch: WeightEpoch::default(),
            pruned_groups: vec![0; n],
            last_engine_stats: Vec::new(),
            max_seq,
            cfg_min_prefill: 1,
            cfg_share_prefix: true,
            cfg_prefill_chunk: 0,
            prune: PrunePolicy::off(),
            wall_s: 0.0,
        }
    }

    pub fn engines(&self) -> usize {
        self.est_load.len()
    }

    /// True when engine replicas decode on their own worker threads.
    pub fn is_threaded(&self) -> bool {
        matches!(self.backend, Backend::Threaded { .. })
    }

    /// Current weight generation (bumped by [`Self::push_weights`]).
    pub fn weight_epoch(&self) -> WeightEpoch {
        self.epoch
    }

    /// Per-engine counters from the last [`Self::take_stats`] drain — the
    /// per-replica observability view (striping imbalance, per-engine
    /// decode volume, weight epoch).
    pub fn last_engine_stats(&self) -> &[SchedulerStats] {
        &self.last_engine_stats
    }

    /// Ordered record of every placement and steal this service has made
    /// (service-lifetime; dump with [`PlacementLog::save`] and replay it
    /// via [`Self::set_replay`] on a fresh service).
    pub fn placement_log(&self) -> &PlacementLog {
        &self.log
    }

    /// Install a recorded log and switch to [`StripePolicy::Replay`]:
    /// every group goes straight to the engine the log finally put it
    /// on, so a stolen run's completed members reproduce bit-for-bit
    /// without any live timing in the loop.
    pub fn set_replay(&mut self, log: PlacementLog) {
        self.replay = Some(log);
        self.stripe = StripePolicy::Replay;
    }

    /// Apply the dynamic-batching admission floor to every engine queue.
    pub fn set_min_prefill_batch(&mut self, n: usize) {
        self.configure(n.max(1), None, None);
    }

    /// Toggle group-shared prefix prefill (on by default; off reproduces
    /// the per-request PR-1 prefill for baselines).
    pub fn set_share_prefix(&mut self, on: bool) {
        self.configure(0, Some(on), None);
    }

    /// Set the chunked-prefill unit on every engine queue: prompts longer
    /// than `n` positions prefill in `n`-sized chunks interleaved with
    /// decode ticks (0 = whole-prompt prefill, the default).  Outputs are
    /// bit-identical either way; chunking only bounds per-call prefill
    /// latency so decode ticks keep flowing under long prompts.
    pub fn set_prefill_chunk(&mut self, n: usize) {
        self.configure(0, None, Some(n));
    }

    /// Apply a KV layout/page-size/budget to every engine replica.
    /// Rebuilds each engine's page ledger from scratch (tables dropped,
    /// counters reset), so call it before submitting work — mid-flight the
    /// pager self-heals on the next admission but the page stats restart.
    pub fn set_kv(&mut self, cfg: KvConfig) {
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for s in scheds.iter_mut() {
                    s.set_kv(cfg);
                }
            }
            Backend::Threaded { workers, .. } => {
                for w in workers.iter() {
                    let _ = w.cmd.send(Command::ConfigureKv(cfg));
                }
            }
        }
    }

    fn configure(&mut self, min_prefill_batch: usize, share: Option<bool>,
                 chunk: Option<usize>) {
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for s in scheds.iter_mut() {
                    if min_prefill_batch > 0 {
                        s.min_prefill_batch = min_prefill_batch;
                    }
                    if let Some(on) = share {
                        s.share_prefix = on;
                    }
                    if let Some(c) = chunk {
                        s.prefill_chunk = c;
                    }
                }
            }
            Backend::Threaded { workers, .. } => {
                // workers need absolute values: resend every knob
                for w in workers.iter() {
                    let _ = w.cmd.send(Command::Configure {
                        min_prefill_batch: if min_prefill_batch > 0 {
                            min_prefill_batch
                        } else {
                            self.cfg_min_prefill
                        },
                        share_prefix: share.unwrap_or(self.cfg_share_prefix),
                        prefill_chunk: chunk.unwrap_or(self.cfg_prefill_chunk),
                    });
                }
            }
        }
        if min_prefill_batch > 0 {
            self.cfg_min_prefill = min_prefill_batch;
        }
        if let Some(on) = share {
            self.cfg_share_prefix = on;
        }
        if let Some(c) = chunk {
            self.cfg_prefill_chunk = c;
        }
    }

    /// Push freshly (re)quantized weights to every engine replica and bump
    /// the [`WeightEpoch`].  Inline engines swap immediately; threaded
    /// workers swap between ticks when the command reaches them — either
    /// way no KV cache, slot state or thread is rebuilt (this replaces the
    /// old requantize path's full service teardown).  Returns the new
    /// epoch.
    pub fn push_weights(&mut self, w: E::Weights) -> WeightEpoch {
        self.epoch.0 += 1;
        let epoch = self.epoch;
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for s in scheds.iter_mut() {
                    s.swap_weights(w.clone(), epoch.0);
                }
            }
            Backend::Threaded { workers, .. } => {
                for wk in workers.iter() {
                    let _ = wk.cmd.send(Command::SwapWeights(w.clone(), epoch));
                }
            }
        }
        epoch
    }

    /// Placement for one group; updates the load estimate and appends
    /// the decision to the placement log.  Returns `(engine, cost)`.
    fn place(&mut self, spec: &GroupSpec, group_uid: u64) -> (usize, u64) {
        let n = self.est_load.len();
        let engine = match self.stripe {
            StripePolicy::RoundRobin => {
                let e = self.next_engine;
                self.next_engine = (e + 1) % n;
                e
            }
            StripePolicy::LeastLoaded => {
                let mut best = 0;
                for e in 1..n {
                    if self.est_load[e] < self.est_load[best] {
                        best = e;
                    }
                }
                best
            }
            StripePolicy::Replay => {
                match self
                    .replay
                    .as_ref()
                    .and_then(|l| l.final_engine(group_uid))
                {
                    Some(e) if e < n => e,
                    // unlogged group (or a log from a wider service):
                    // fall back to round-robin rather than refusing work
                    _ => {
                        let e = self.next_engine;
                        self.next_engine = (e + 1) % n;
                        e
                    }
                }
            }
        };
        let per_member = spec
            .prompt
            .len()
            .saturating_add(spec.max_new)
            .min(self.max_seq) as u64;
        let cost = per_member.saturating_mul(spec.group_size as u64);
        self.est_load[engine] = self.est_load[engine].saturating_add(cost);
        self.log.push(group_uid, engine, engine, PlacementReason::Place);
        (engine, cost)
    }

    /// Submit a group.  All members land on one engine (fork_kv is an
    /// intra-engine cache copy) contiguously, so they admit together and
    /// share one prefill whenever slots allow; groups are placed per
    /// [`Self::stripe`].  Threaded workers may start prefilling
    /// immediately — submission streams.
    pub fn submit_group(&mut self, spec: GroupSpec) {
        assert!(spec.group_size > 0, "empty group");
        let group_uid = self.next_group_uid;
        self.next_group_uid += 1;
        let (engine, cost) = self.place(&spec, group_uid);
        let gi = self.groups.len();
        // one allocation for the whole group: members carry Arc clones, and
        // the scheduler's shared-prefix clustering recognizes them by
        // pointer identity
        let prompt = Arc::new(spec.prompt);
        let mut uids = Vec::with_capacity(spec.group_size);
        let mut reqs = Vec::with_capacity(spec.group_size);
        for member in 0..spec.group_size {
            let uid = self.next_uid;
            self.next_uid += 1;
            self.by_uid.insert(uid, (gi, member));
            reqs.push(RolloutRequest {
                id: uid,
                prompt: prompt.clone(),
                max_new: spec.max_new,
                temperature: spec.temperature,
                top_p: spec.top_p,
                seed: member_seed(spec.seed, member),
            });
            uids.push(uid);
        }
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for r in reqs {
                    scheds[engine].submit(r);
                }
            }
            Backend::Threaded { workers, .. } => {
                let _ = workers[engine].cmd.send(Command::Submit(reqs));
            }
        }
        self.groups.push(GroupState {
            group_id: spec.group_id,
            uid: group_uid,
            engine,
            size: spec.group_size,
            cost,
            uids,
            outcomes: vec![None; spec.group_size],
            finished: 0,
            cancelled: 0,
            pruned: false,
            cancel_requested: false,
        });
    }

    /// Debit a fully resolved group's cost from its engine's estimate —
    /// but only when the [`PlacementLog`] carries the determinism story
    /// (stealing or replay active).  Plain least-loaded keeps the legacy
    /// never-decrement semantics: its placements are *derived from* the
    /// monotone estimate, and the parity tests pin them down.
    fn debit_if_resolved(&mut self, gi: usize) {
        if self.steal == StealPolicy::Off
            && self.stripe != StripePolicy::Replay
        {
            return;
        }
        let g = &self.groups[gi];
        if g.finished + g.cancelled == g.size {
            let e = g.engine;
            self.est_load[e] = self.est_load[e].saturating_sub(g.cost);
        }
    }

    /// A steal succeeded: re-attribute the group to the thief, move its
    /// cost, count it and log it.
    fn note_steal(&mut self, gi: usize, thief: usize) {
        let victim = self.groups[gi].engine;
        let cost = self.groups[gi].cost;
        let uid = self.groups[gi].uid;
        self.groups[gi].engine = thief;
        self.est_load[victim] = self.est_load[victim].saturating_sub(cost);
        self.est_load[thief] = self.est_load[thief].saturating_add(cost);
        self.steal_count[thief] += 1;
        self.log.push(uid, victim, thief, PlacementReason::Steal);
    }

    /// Whole groups on `victim` that are stealable *from the service's
    /// view*: nothing finished, nothing cancelled, no cancel in flight —
    /// so prune cancels (which only fire after finishes) can never race a
    /// steal.  Newest first: the oldest queued groups are next to admit
    /// on the victim anyway, the newest would otherwise wait longest.
    /// Whether a candidate is *actually* still fully queued is decided by
    /// the victim scheduler ([`Scheduler::extract_queued`] is
    /// all-or-nothing), so a stale view only wastes a probe.
    fn steal_candidates(&self, victim: usize) -> Vec<(usize, Vec<u64>)> {
        self.groups
            .iter()
            .enumerate()
            .rev()
            .filter(|(_, g)| {
                g.engine == victim
                    && g.finished == 0
                    && g.cancelled == 0
                    && !g.cancel_requested
            })
            .map(|(gi, g)| (gi, g.uids.clone()))
            .take(8)
            .collect()
    }

    /// Most-loaded replica (live outstanding tokens) that has stealable
    /// candidates for `thief`.
    fn pick_victim(&self, thief: usize)
                   -> Option<(usize, Vec<(usize, Vec<u64>)>)> {
        let mut best: Option<(usize, u64, Vec<(usize, Vec<u64>)>)> = None;
        for e in 0..self.engines() {
            if e == thief {
                continue;
            }
            let cands = self.steal_candidates(e);
            if cands.is_empty() {
                continue;
            }
            let load = self.live_load[e].load(Ordering::Relaxed);
            let better = match &best {
                Some((_, l, _)) => load > *l,
                None => true,
            };
            if better {
                best = Some((e, load, cands));
            }
        }
        best.map(|(e, _, c)| (e, c))
    }

    /// Drive every engine to completion, scoring members with `reward_fn`
    /// (called once per completed member, with the caller's `group_id`) and
    /// pruning decided groups in flight per [`Self::prune`].  Returns the
    /// resolved groups in submission order.
    ///
    /// On an engine error the service aborts every outstanding request,
    /// clears its group ledger and returns the error — internal state stays
    /// consistent and the service is immediately reusable (tested).
    pub fn run<F>(&mut self, mut reward_fn: F) -> Result<Vec<GroupResult>>
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        let t0 = Instant::now();
        let threaded = self.is_threaded();
        let out = if threaded {
            self.run_threaded(&mut reward_fn)
        } else {
            self.run_inline(&mut reward_fn)
        };
        self.wall_s += t0.elapsed().as_secs_f64();
        out
    }

    /// Refresh the shared live-load counters from the inline schedulers
    /// (the threaded workers publish their own).
    fn refresh_live_inline(&mut self) {
        let Backend::Inline(scheds) = &self.backend else {
            return;
        };
        for (e, s) in scheds.iter().enumerate() {
            self.live_load[e].store(s.outstanding_tokens(),
                                    Ordering::Relaxed);
        }
    }

    /// One steal round for the inline backend: every idle engine (empty
    /// queue, free slots) takes one whole queued group from the
    /// most-loaded replica.  Thieves act in engine order, so inline
    /// stealing is fully deterministic in the workload — the property
    /// tests replay it against its own log.
    fn inline_steal_pass(&mut self) {
        if self.steal != StealPolicy::Idle || self.engines() < 2 {
            return;
        }
        self.refresh_live_inline();
        for thief in 0..self.engines() {
            let idle = {
                let Backend::Inline(scheds) = &self.backend else {
                    return;
                };
                scheds[thief].queue_len() == 0
                    && scheds[thief].free_slots() > 0
            };
            if !idle {
                continue;
            }
            let Some((victim, cands)) = self.pick_victim(thief) else {
                continue;
            };
            for (gi, uids) in cands {
                let stolen = {
                    let Backend::Inline(scheds) = &mut self.backend else {
                        return;
                    };
                    scheds[victim].extract_queued(&uids)
                };
                let Some(reqs) = stolen else {
                    continue;
                };
                {
                    let Backend::Inline(scheds) = &mut self.backend else {
                        return;
                    };
                    for r in reqs {
                        scheds[thief].submit(r);
                    }
                }
                self.note_steal(gi, thief);
                break; // one group per thief per round
            }
        }
    }

    fn run_inline<F>(&mut self, reward_fn: &mut F) -> Result<Vec<GroupResult>>
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        loop {
            self.inline_steal_pass();
            let mut progressed = false;
            for e in 0..self.engines() {
                let finished = {
                    let Backend::Inline(scheds) = &mut self.backend else {
                        return Err(anyhow!(
                            "inline run called on a threaded backend"));
                    };
                    if scheds[e].pending() == 0 {
                        continue;
                    }
                    match scheds[e].tick() {
                        Ok(f) => f,
                        Err(err) => return self.fail(err),
                    }
                };
                progressed = true;
                for res in finished {
                    let directives = self.absorb(res, reward_fn);
                    for (engine, uid) in directives {
                        let partial = {
                            let Backend::Inline(scheds) = &mut self.backend
                            else {
                                return Err(anyhow!(
                                    "inline run called on a threaded \
                                     backend"));
                            };
                            scheds[engine].cancel(uid)
                        };
                        if let Some(p) = partial {
                            self.record_cancel(uid, p);
                        }
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        self.drain_groups()
    }

    /// Send a `Steal` probe on behalf of an idle thief (threaded
    /// backend).  At most one probe in flight per thief; the victim's
    /// `Stolen` reply resolves it.  A dead victim channel is left to the
    /// main loop's dead-worker detection.
    fn try_steal_threaded(&mut self, thief: usize) {
        if self.steal != StealPolicy::Idle
            || self.steal_inflight.contains(&thief)
            || !self.idle_workers.contains(&thief)
        {
            return;
        }
        let Some((victim, cands)) = self.pick_victim(thief) else {
            return;
        };
        let candidates: Vec<Vec<u64>> =
            cands.into_iter().map(|(_, uids)| uids).collect();
        let sent = {
            let Backend::Threaded { workers, .. } = &self.backend else {
                return;
            };
            workers[victim]
                .cmd
                .send(Command::Steal { thief, candidates })
                .is_ok()
        };
        if sent {
            self.steal_inflight.insert(thief);
        }
    }

    /// Re-probe on behalf of every registered-idle thief.  Called when
    /// state has actually changed (a finish, a cancel, a successful
    /// steal) — never on an empty `Stolen` reply, so probes are bounded
    /// by real progress events and can't livelock.
    fn retry_steals_threaded(&mut self) {
        if self.steal != StealPolicy::Idle || self.idle_workers.is_empty() {
            return;
        }
        let idle: Vec<usize> = self.idle_workers.iter().copied().collect();
        for t in idle {
            self.try_steal_threaded(t);
        }
    }

    fn run_threaded<F>(&mut self, reward_fn: &mut F)
                       -> Result<Vec<GroupResult>>
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        // steal bookkeeping never carries across runs (stale Idle events
        // from a previous run's tail are harmless: a probe just comes
        // back empty)
        self.idle_workers.clear();
        self.steal_inflight.clear();
        if self.steal == StealPolicy::Idle {
            // a worker only announces Idle once per Submit generation, and
            // a previous drain may have discarded that event — so seed the
            // set from the service's own view: an engine holding none of
            // this run's groups is idle by construction
            let busy: HashSet<usize> =
                self.groups.iter().map(|g| g.engine).collect();
            for e in 0..self.engines() {
                if !busy.contains(&e) {
                    self.idle_workers.insert(e);
                    self.try_steal_threaded(e);
                }
            }
        }
        let mut unresolved: usize = self
            .groups
            .iter()
            .map(|g| g.size - g.finished - g.cancelled)
            .sum();
        while unresolved > 0 {
            let ev = {
                let Backend::Threaded { events, .. } = &self.backend else {
                    return Err(anyhow!(
                        "threaded run called on an inline backend"));
                };
                // bounded wait so a dead worker (thread panic = contract
                // violation in its engine) can't wedge the control loop
                events.recv_timeout(Duration::from_secs(1))
            };
            match ev {
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let dead = {
                        let Backend::Threaded { workers, .. } = &self.backend
                        else {
                            return Err(anyhow!(
                                "threaded run called on an inline \
                                 backend"));
                        };
                        workers.iter().any(|w| match &w.join {
                            Some(j) => j.is_finished(),
                            None => true,
                        })
                    };
                    if dead {
                        return self.fail(anyhow!(
                            "engine worker thread died with requests \
                             outstanding"));
                    }
                }
                // a Finished/CancelOutcome for a uid no longer in by_uid is
                // a straggler from an aborted previous run (fail() clears
                // the ledger; a >10s-wedged worker can deliver after the
                // abort drain gave up) — drop it, never count it against
                // this run.  uids are globally unique (next_uid never
                // resets), so a stale uid can't collide with a live one.
                Ok(Event::Finished(_, res))
                    if !self.by_uid.contains_key(&res.id) => {}
                Ok(Event::Finished(_, res)) => {
                    let directives = self.absorb(res, reward_fn);
                    unresolved -= 1;
                    for (engine, uid) in directives {
                        let sent = {
                            let Backend::Threaded { workers, .. } =
                                &self.backend
                            else {
                                return Err(anyhow!(
                                    "threaded run called on an inline \
                                     backend"));
                            };
                            workers[engine]
                                .cmd
                                .send(Command::Cancel(uid))
                                .is_ok()
                        };
                        if !sent {
                            return self.fail(anyhow!(
                                "engine worker {engine} disappeared"));
                        }
                    }
                    // a finish may have idled another replica's victim
                    // view; give registered-idle thieves another look
                    self.retry_steals_threaded();
                }
                Ok(Event::CancelOutcome(uid, Some(partial))) => {
                    if self.by_uid.contains_key(&uid) {
                        self.record_cancel(uid, partial);
                        unresolved -= 1;
                        self.retry_steals_threaded();
                    }
                }
                // the member completed before the cancel landed; its
                // Finished event resolves it
                Ok(Event::CancelOutcome(_, None)) => {}
                Ok(Event::Idle(i)) => {
                    if self.steal == StealPolicy::Idle {
                        self.idle_workers.insert(i);
                        self.try_steal_threaded(i);
                    }
                }
                Ok(Event::Stolen { thief, reqs, .. }) => {
                    self.steal_inflight.remove(&thief);
                    if reqs.is_empty() {
                        // victim had nothing fully queued; the thief
                        // stays registered and is re-probed on the next
                        // progress event (never immediately — that would
                        // spin probe→empty→probe)
                    } else if self.by_uid.contains_key(&reqs[0].id) {
                        let gi = self.by_uid[&reqs[0].id].0;
                        let sent = {
                            let Backend::Threaded { workers, .. } =
                                &self.backend
                            else {
                                return Err(anyhow!(
                                    "threaded run called on an inline \
                                     backend"));
                            };
                            workers[thief]
                                .cmd
                                .send(Command::Submit(reqs))
                                .is_ok()
                        };
                        if !sent {
                            return self.fail(anyhow!(
                                "engine worker {thief} disappeared with \
                                 stolen requests in hand"));
                        }
                        self.note_steal(gi, thief);
                        self.idle_workers.remove(&thief);
                        self.retry_steals_threaded();
                    }
                    // uids cleared from by_uid can only come from an
                    // aborted ledger — the run already failed; drop them
                }
                Ok(Event::TickError(i, e)) => {
                    return self.fail(
                        e.context(format!("engine worker {i} tick failed")));
                }
                // stale acks from a previous abort/stats exchange
                Ok(Event::Stats(..)) | Ok(Event::Aborted(..))
                | Ok(Event::Ready(..)) => {}
                Err(_) => {
                    return self.fail(anyhow!(
                        "all engine workers disconnected"));
                }
            }
        }
        self.drain_groups()
    }

    /// Record one completed member; returns `(engine, uid)` cancel
    /// directives for the group's outstanding siblings when the prune
    /// policy decides the group (at most once per group).
    fn absorb<F>(&mut self, res: RolloutResult, reward_fn: &mut F)
                 -> Vec<(usize, u64)>
    where
        F: FnMut(usize, &RolloutResult) -> f32,
    {
        let (gi, mi) = self.by_uid[&res.id];
        let reward = reward_fn(self.groups[gi].group_id, &res);
        {
            let g = &mut self.groups[gi];
            g.finished += 1;
            g.outcomes[mi] =
                Some(GroupMember { result: res, reward: Some(reward) });
        }
        self.debit_if_resolved(gi);
        if !self.prune.enabled {
            return Vec::new();
        }
        let g = &mut self.groups[gi];
        if g.cancel_requested
            || g.finished < self.prune.min_finished
            || g.finished + g.cancelled >= g.size
        {
            return Vec::new();
        }
        let rewards: Vec<f32> = g
            .outcomes
            .iter()
            .flatten()
            .filter_map(|m| m.reward)
            .collect();
        let uniform =
            rewards.iter().all(|&r| (r - rewards[0]).abs() <= 1e-6);
        if !uniform {
            return Vec::new();
        }
        g.cancel_requested = true;
        g.uids
            .iter()
            .enumerate()
            .filter(|&(m, _)| g.outcomes[m].is_none())
            .map(|(_, &u)| (g.engine, u))
            .collect()
    }

    /// A cancel directive landed: record the partial.  The group counts as
    /// pruned only now — a directive that raced with completion saved
    /// nothing and must not flag the group (same semantics as the old
    /// synchronous path, where `cancel` returning `None` left the flag
    /// unset).
    fn record_cancel(&mut self, uid: u64, partial: RolloutResult) {
        let (gi, mi) = self.by_uid[&uid];
        let g = &mut self.groups[gi];
        g.cancelled += 1;
        g.outcomes[mi] =
            Some(GroupMember { result: partial, reward: None });
        if !g.pruned {
            g.pruned = true;
            self.pruned_groups[g.engine] += 1;
        }
        self.debit_if_resolved(gi);
    }

    /// Error recovery: cancel everything outstanding on every engine and
    /// clear the group ledger, so `by_uid`/`groups` are never left
    /// half-absorbed and the service is reusable after a failed run.
    fn fail(&mut self, err: anyhow::Error) -> Result<Vec<GroupResult>> {
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for s in scheds.iter_mut() {
                    s.abort_all();
                }
            }
            Backend::Threaded { workers, events } => {
                let mut expect = 0usize;
                for w in workers.iter() {
                    if w.cmd.send(Command::AbortAll).is_ok() {
                        expect += 1;
                    }
                }
                // drain in-flight completions until every live worker has
                // acknowledged the abort (per-sender FIFO: an ack follows
                // everything that worker sent before it)
                let mut acked = 0usize;
                while acked < expect {
                    match events.recv_timeout(Duration::from_secs(10)) {
                        Ok(Event::Aborted(_)) => acked += 1,
                        Ok(_) => {}
                        Err(_) => break, // dead/wedged worker: stop waiting
                    }
                }
            }
        }
        self.groups.clear();
        self.by_uid.clear();
        self.idle_workers.clear();
        self.steal_inflight.clear();
        for l in &mut self.est_load {
            *l = 0;
        }
        Err(err)
    }

    /// Resolve the drained groups in submission order and reset per-run
    /// placement state.
    fn drain_groups(&mut self) -> Result<Vec<GroupResult>> {
        self.by_uid.clear();
        for l in &mut self.est_load {
            *l = 0;
        }
        let mut out = Vec::with_capacity(self.groups.len());
        for g in self.groups.drain(..) {
            if g.finished + g.cancelled != g.size {
                return Err(anyhow!(
                    "group {} resolved {}/{} members at drain",
                    g.group_id, g.finished + g.cancelled, g.size));
            }
            let gid = g.group_id;
            let mut members = Vec::with_capacity(g.outcomes.len());
            for (mi, o) in g.outcomes.into_iter().enumerate() {
                members.push(o.ok_or_else(|| anyhow!(
                    "group {gid} member {mi} unresolved at drain"))?);
            }
            out.push(GroupResult {
                group_id: gid,
                engine: g.engine,
                members,
                pruned: g.pruned,
            });
        }
        Ok(out)
    }

    /// Drain the merged per-engine counters (plus the service-loop wall
    /// time), resetting them for the next run — the trainer logs one
    /// `sched_*` Recorder row per RL step from this.  The undrained
    /// per-replica breakdown stays available via
    /// [`Self::last_engine_stats`].
    ///
    /// Errors with a typed [`OutstandingGroupsError`] when called with
    /// groups outstanding: the threaded drain would swallow in-flight
    /// `Finished` events and the members could never resolve, so a stats
    /// drain is only legal between runs (every event still in the
    /// channel is then a stale straggler and safe to drop).  The inline
    /// backend enforces the same contract so callers behave identically
    /// across backends.
    pub fn take_stats(&mut self) -> Result<SchedulerStats> {
        if !self.groups.is_empty() {
            return Err(OutstandingGroupsError {
                outstanding: self.groups.len(),
            }
            .into());
        }
        let mut per: Vec<SchedulerStats> = match &mut self.backend {
            Backend::Inline(scheds) => {
                scheds.iter_mut().map(|s| s.take_stats()).collect()
            }
            Backend::Threaded { workers, events } => {
                let mut expect = 0usize;
                for w in workers.iter() {
                    if w.cmd.send(Command::TakeStats).is_ok() {
                        expect += 1;
                    }
                }
                let mut per =
                    vec![SchedulerStats::default(); workers.len()];
                let mut got = 0usize;
                while got < expect {
                    match events.recv_timeout(Duration::from_secs(10)) {
                        Ok(Event::Stats(i, st)) => {
                            per[i] = st;
                            got += 1;
                        }
                        Ok(_) => {} // stale stragglers from an aborted run
                        Err(_) => break,
                    }
                }
                per
            }
        };
        for (p, n) in per.iter_mut().zip(self.pruned_groups.iter_mut()) {
            p.pruned_groups += *n;
            *n = 0;
        }
        for (p, n) in per.iter_mut().zip(self.steal_count.iter_mut()) {
            p.steals += *n;
            *n = 0;
        }
        // per-drain starvation gap: ticks each replica sat out while the
        // busiest replica still decoded.  Computed from drained counters,
        // so it is deterministic and backend-uniform — exactly the
        // straggler gap work stealing exists to close.
        let max_steps =
            per.iter().map(|p| p.decode_steps).max().unwrap_or(0);
        for p in per.iter_mut() {
            p.idle_ticks += max_steps - p.decode_steps;
        }
        let mut out = SchedulerStats::default();
        for p in &per {
            out.merge(p);
        }
        out.wall_s += self.wall_s;
        self.wall_s = 0.0;
        self.last_engine_stats = per;
        Ok(out)
    }

    // ---- checkpoint support ------------------------------------------------

    /// Capture the cross-run service state for a checkpoint (see
    /// [`ServiceSnapshot`] for exactly what is and isn't included).  Only
    /// legal between runs — with groups outstanding the uid ledgers are
    /// mid-flight and the snapshot would be unreplayable; that is the same
    /// typed [`OutstandingGroupsError`] contract as [`Self::take_stats`].
    pub fn snapshot(&self) -> Result<ServiceSnapshot> {
        if !self.groups.is_empty() {
            return Err(OutstandingGroupsError {
                outstanding: self.groups.len(),
            }
            .into());
        }
        Ok(ServiceSnapshot {
            next_uid: self.next_uid,
            next_engine: self.next_engine,
            est_load: self.est_load.clone(),
            next_group_uid: self.next_group_uid,
            epoch: self.epoch.0,
            log: self.log.clone(),
        })
    }

    /// Install a checkpointed [`ServiceSnapshot`] on a freshly built
    /// service, after which placement, member seeding, and the placement
    /// log continue bit-identically to the service the snapshot was taken
    /// from.  Typed errors when the snapshot's replica count does not
    /// match this service (a resume under a silently changed `--engines`)
    /// or when groups are outstanding.
    ///
    /// The restored [`WeightEpoch`] is the *counter* only; the engines
    /// themselves were just rebuilt and still carry epoch-0 bookkeeping.
    /// Callers complete the resume with [`Self::reissue_weights`] (stamp
    /// the current weights with the restored epoch) and one discarded
    /// [`Self::take_stats`] drain, so post-resume stats rows match an
    /// uninterrupted run's post-drain state.
    pub fn restore(&mut self, snap: &ServiceSnapshot) -> Result<()> {
        if !self.groups.is_empty() {
            return Err(OutstandingGroupsError {
                outstanding: self.groups.len(),
            }
            .into());
        }
        if snap.est_load.len() != self.est_load.len() {
            return Err(anyhow!(
                "service snapshot was taken with {} engine replicas but \
                 this service has {} — resume with the same --engines",
                snap.est_load.len(),
                self.est_load.len()
            ));
        }
        self.next_uid = snap.next_uid;
        self.next_engine = snap.next_engine;
        self.est_load = snap.est_load.clone();
        self.next_group_uid = snap.next_group_uid;
        self.epoch = WeightEpoch(snap.epoch);
        self.log = snap.log.clone();
        Ok(())
    }

    /// Re-install weights at the *current* epoch without bumping it — the
    /// resume path's counterpart to [`Self::push_weights`].  After
    /// [`Self::restore`] the epoch counter says generation `k` but the
    /// rebuilt engines still decode with their construction weights at
    /// epoch-0 bookkeeping; this stamps them with generation `k` so
    /// `sched_weight_epoch` (and the swap protocol) continue exactly as
    /// in the uninterrupted run.
    pub fn reissue_weights(&mut self, w: E::Weights) {
        let epoch = self.epoch;
        match &mut self.backend {
            Backend::Inline(scheds) => {
                for s in scheds.iter_mut() {
                    s.swap_weights(w.clone(), epoch.0);
                }
            }
            Backend::Threaded { workers, .. } => {
                for wk in workers.iter() {
                    let _ = wk.cmd.send(Command::SwapWeights(w.clone(), epoch));
                }
            }
        }
    }
}

impl<E: DecodeEngine + 'static> RolloutService<E> {
    /// Threaded backend: one worker thread per factory, each owning the
    /// engine its factory builds *inside the thread* plus that engine's
    /// [`Scheduler`].  Fails fast if any factory errors (all spawned
    /// workers are shut down and joined before returning).
    pub fn threaded(factories: Vec<EngineFactory<E>>, max_seq: usize,
                    eos_id: i32) -> Result<Self> {
        assert!(!factories.is_empty(), "service needs at least one engine");
        let n = factories.len();
        let (evt_tx, evt_rx) = mpsc::channel();
        let live = new_live_load(n);
        let mut workers: Vec<WorkerHandle<E::Weights>> =
            Vec::with_capacity(n);
        for (i, f) in factories.into_iter().enumerate() {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let tx = evt_tx.clone();
            let lv = live.clone();
            let join = std::thread::Builder::new()
                .name(format!("rollout-w{i}"))
                .spawn(move || {
                    worker_loop::<E>(i, f, cmd_rx, tx, max_seq, eos_id, lv)
                })?;
            workers.push(WorkerHandle { cmd: cmd_tx, join: Some(join) });
        }
        // the service holds no event sender: recv() erroring from here on
        // means every worker is gone
        drop(evt_tx);
        let mut failed: Option<anyhow::Error> = None;
        for _ in 0..n {
            // bounded: a panicking factory never sends its Ready, and a
            // hung handshake must fail the build, not wedge the caller
            match evt_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(Event::Ready(_, Ok(()))) => {}
                Ok(Event::Ready(i, Err(e))) => {
                    failed = Some(e.context(format!(
                        "engine worker {i} failed to start")));
                }
                Ok(_) => {
                    failed = failed.or_else(|| {
                        Some(anyhow!("unexpected non-handshake event \
                                      during worker startup"))
                    });
                }
                Err(_) => {
                    failed = failed.or_else(|| {
                        Some(anyhow!("engine workers died or hung during \
                                      startup"))
                    });
                    break;
                }
            }
        }
        if let Some(e) = failed {
            // tell the healthy workers to exit, then join only threads
            // that are already done — a hung factory must not convert a
            // failed build into a deadlocked one (its thread is detached
            // and exits when its command channel drops)
            for w in workers.iter() {
                let _ = w.cmd.send(Command::Shutdown);
            }
            for w in workers.iter_mut() {
                let finished = match &w.join {
                    Some(j) => j.is_finished(),
                    None => true,
                };
                if finished {
                    if let Some(j) = w.join.take() {
                        let _ = j.join();
                    }
                }
            }
            return Err(e);
        }
        Ok(Self::with_backend(
            Backend::Threaded { workers, events: evt_rx }, n, max_seq,
            live))
    }
}

impl<E: DecodeEngine> Drop for RolloutService<E> {
    /// Join worker threads on the way out (inline backend: no-op).
    fn drop(&mut self) {
        if let Backend::Threaded { workers, .. } = &mut self.backend {
            for w in workers.iter() {
                let _ = w.cmd.send(Command::Shutdown);
            }
            for w in workers.iter_mut() {
                if let Some(j) = w.join.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::kv::{KvConfig, KvLayout};
    use super::super::mock::MockEngine;
    use super::*;

    const MAX_SEQ: usize = 24;
    const VOCAB: usize = 8;
    const EOS: i32 = 2;

    fn spec(group_id: usize, prompt_sig: i32, g: usize, temp: f32)
            -> GroupSpec {
        GroupSpec {
            group_id,
            prompt: vec![1, 3 + (prompt_sig % 5), 4, 5],
            group_size: g,
            max_new: 12,
            temperature: temp,
            top_p: 1.0,
            seed: 0x5eed ^ ((group_id as u64) << 8),
        }
    }

    fn service(n_engines: usize, slots: usize)
               -> RolloutService<MockEngine> {
        let engines: Vec<MockEngine> = (0..n_engines)
            .map(|_| MockEngine::new(slots, VOCAB, MAX_SEQ, EOS))
            .collect();
        RolloutService::new(engines, MAX_SEQ, EOS)
    }

    fn threaded_service(n_engines: usize, slots: usize)
                        -> RolloutService<MockEngine> {
        let factories: Vec<EngineFactory<MockEngine>> = (0..n_engines)
            .map(|_| {
                Box::new(move || Ok(MockEngine::new(slots, VOCAB, MAX_SEQ,
                                                    EOS)))
                    as EngineFactory<MockEngine>
            })
            .collect();
        RolloutService::threaded(factories, MAX_SEQ, EOS).unwrap()
    }

    /// (tokens, logprob bits, finish, reward, engine) per member — the
    /// cross-backend comparison key.  Logprobs compare as bit patterns:
    /// parity is *bit-for-bit*, not approximate.
    fn fingerprint(results: &[GroupResult])
                   -> Vec<(Vec<i32>, Vec<u32>, FinishReason, Option<u32>,
                           usize)> {
        results
            .iter()
            .flat_map(|gr| {
                gr.members.iter().map(move |m| {
                    (m.result.generated.clone(),
                     m.result.logprobs.iter().map(|l| l.to_bits()).collect(),
                     m.result.finish,
                     m.reward.map(|r| r.to_bits()),
                     gr.engine)
                })
            })
            .collect()
    }

    /// Striping over several engines: every group resolves completely, on
    /// its round-robin engine, and the merged ledger balances.
    #[test]
    fn striped_groups_all_complete() {
        let mut svc = service(3, 4);
        let (n_groups, g) = (7, 4);
        for gid in 0..n_groups {
            svc.submit_group(spec(gid, gid as i32, g, 1.0));
        }
        let results = svc.run(|_, res| res.generated.len() as f32).unwrap();
        assert_eq!(results.len(), n_groups);
        for (i, gr) in results.iter().enumerate() {
            assert_eq!(gr.group_id, i, "submission order preserved");
            assert_eq!(gr.engine, i % 3, "round-robin striping");
            assert_eq!(gr.members.len(), g);
            assert!(gr.complete());
            assert!(!gr.pruned);
            assert!(gr.members.iter().all(|m| m.reward.is_some()));
        }
        let st = svc.take_stats().unwrap();
        assert_eq!(st.submitted, n_groups * g);
        assert_eq!(st.completed, st.submitted);
        assert_eq!(st.cancelled, 0);
        // shared prefill: members share prompts, so rows < submissions
        assert!(st.prefill_rows < st.submitted);
        assert_eq!(st.prefill_rows + st.forked, st.submitted);
        // per-engine breakdown covers every replica and sums to the merge
        assert_eq!(svc.last_engine_stats().len(), 3);
        let sub: usize =
            svc.last_engine_stats().iter().map(|s| s.submitted).sum();
        assert_eq!(sub, st.submitted);
        // second take_stats is empty (drained)
        assert_eq!(svc.take_stats().unwrap().submitted, 0);
    }

    /// A reward that is constant for some groups and member-dependent for
    /// others: pruning must cancel only the uniform groups' remainders,
    /// keep the ledger balanced, and strictly reduce decoded tokens vs the
    /// same workload without pruning.
    #[test]
    fn pruning_cancels_uniform_groups_and_saves_tokens() {
        let run = |prune: bool| {
            let mut svc = service(1, 3); // B=3 < g: siblings queue
            svc.prune = if prune { PrunePolicy::online(2) } else {
                PrunePolicy::off()
            };
            let (n_groups, g) = (6, 6);
            for gid in 0..n_groups {
                svc.submit_group(spec(gid, gid as i32, g, 1.0));
            }
            // groups 0, 2, 4 uniform (uninformative); 1, 3, 5 vary by member
            let results = svc
                .run(|gid, res| {
                    if gid % 2 == 0 {
                        1.0
                    } else {
                        (res.generated.len() % 3) as f32
                    }
                })
                .unwrap();
            let tokens: usize =
                results.iter().map(|r| r.generated_tokens()).sum();
            (results, svc.take_stats().unwrap(), tokens)
        };
        let (pruned_res, pruned_st, pruned_tokens) = run(true);
        let (plain_res, plain_st, plain_tokens) = run(false);
        assert_eq!(plain_st.cancelled, 0);
        assert_eq!(pruned_st.completed + pruned_st.cancelled,
                   pruned_st.submitted);
        assert!(pruned_st.cancelled > 0, "nothing was pruned");
        assert!(pruned_st.pruned_groups >= 3,
                "uniform groups not pruned: {}", pruned_st.pruned_groups);
        assert!(pruned_tokens < plain_tokens,
                "pruning saved no decode tokens: {pruned_tokens} vs \
                 {plain_tokens}");
        for gr in &pruned_res {
            if gr.pruned {
                assert!(!gr.complete());
                assert!(gr.members.iter().any(
                    |m| m.result.finish == FinishReason::Cancelled));
                // cancelled members are unscored
                assert!(gr
                    .members
                    .iter()
                    .filter(|m| m.result.finish == FinishReason::Cancelled)
                    .all(|m| m.reward.is_none()));
            }
        }
        // un-pruned run: informativeness matches the reward construction
        for gr in &plain_res {
            assert!(gr.complete());
        }
        assert!(plain_res.iter().filter(|r| !r.informative()).count() >= 3);
    }

    /// With pruning off and greedy decode, all members of a group are
    /// identical (fork ≡ fresh prefill at the service level too).
    #[test]
    fn greedy_group_members_identical() {
        let mut svc = service(2, 4);
        for gid in 0..4 {
            svc.submit_group(spec(gid, gid as i32, 4, 0.0));
        }
        let results = svc.run(|_, _| 0.0).unwrap();
        for gr in &results {
            let first = &gr.members[0].result.generated;
            for m in &gr.members {
                assert_eq!(&m.result.generated, first,
                           "greedy siblings diverged in group {}",
                           gr.group_id);
            }
        }
    }

    /// The tentpole parity contract: a threaded run (one worker thread per
    /// engine) produces bit-for-bit the same completed members — tokens,
    /// logprobs, finish reasons, rewards, engine placement — as the inline
    /// single-threaded run, for greedy AND sampled decode.  Threading may
    /// only change wall-clock.
    #[test]
    fn threaded_matches_inline_bitwise() {
        let workload = |svc: &mut RolloutService<MockEngine>| {
            for gid in 0..8 {
                // mix greedy and sampled groups
                let temp = if gid % 2 == 0 { 0.0 } else { 1.0 };
                svc.submit_group(spec(gid, gid as i32, 4, temp));
            }
            svc.run(|gid, res| {
                (gid % 3) as f32 + (res.generated.len() % 2) as f32
            })
            .unwrap()
        };
        let mut inline = service(3, 3);
        let mut threaded = threaded_service(3, 3);
        assert!(threaded.is_threaded() && !inline.is_threaded());
        let a = workload(&mut inline);
        let b = workload(&mut threaded);
        assert_eq!(fingerprint(&a), fingerprint(&b),
                   "threaded execution changed rollout outputs");
        let (sa, sb) = (inline.take_stats().unwrap(),
                        threaded.take_stats().unwrap());
        assert_eq!(sa.submitted, sb.submitted);
        assert_eq!(sa.completed, sb.completed);
        assert_eq!(sa.generated_tokens, sb.generated_tokens);
    }

    /// Least-loaded placement: a heavy group stops attracting neighbors
    /// until the other replica catches up, placement is deterministic, and
    /// outputs are identical to round-robin placement (requests are
    /// engine-independent by the isolation contract).
    #[test]
    fn least_loaded_balances_and_preserves_outputs() {
        let heavy = GroupSpec {
            group_id: 0,
            prompt: vec![1, 3, 4, 5],
            group_size: 6,
            max_new: 12, // cost = min(4+12, 24) * 6 = 96
            temperature: 0.0,
            top_p: 1.0,
            seed: 7,
        };
        let small = |gid: usize| GroupSpec {
            group_id: gid,
            prompt: vec![1, 3, 4, 5],
            group_size: 1,
            max_new: 2, // cost = 6
            temperature: 0.0,
            top_p: 1.0,
            seed: 7 + gid as u64,
        };
        let run = |stripe: StripePolicy| {
            let mut svc = service(2, 4);
            svc.stripe = stripe;
            svc.submit_group(heavy.clone());
            for gid in 1..5 {
                svc.submit_group(small(gid));
            }
            let results = svc.run(|_, _| 0.0).unwrap();
            let engines: Vec<usize> =
                results.iter().map(|r| r.engine).collect();
            (engines, fingerprint(&results)
                 .into_iter()
                 .map(|(t, l, f, r, _)| (t, l, f, r)) // drop engine field
                 .collect::<Vec<_>>())
        };
        let (ll_engines, ll_out) = run(StripePolicy::LeastLoaded);
        let (rr_engines, rr_out) = run(StripePolicy::RoundRobin);
        // the heavy group (cost 96) pins engine 0; all four small groups
        // (cost 6 each) flow to engine 1
        assert_eq!(ll_engines, vec![0, 1, 1, 1, 1]);
        assert_eq!(rr_engines, vec![0, 1, 0, 1, 0]);
        assert_eq!(ll_out, rr_out,
                   "stripe policy changed rollout outputs");
    }

    /// Hot requantization, inline backend: push_weights swaps engine
    /// weights in place (epoch visible in the drained stats, per replica
    /// and merged) and changes subsequent greedy outputs, with no service
    /// rebuild.
    #[test]
    fn hot_swap_changes_outputs_and_bumps_epoch() {
        let submit_all = |svc: &mut RolloutService<MockEngine>| {
            for gid in 0..4 {
                svc.submit_group(spec(gid, gid as i32, 3, 0.0));
            }
        };
        let mut baseline = service(2, 4);
        submit_all(&mut baseline);
        let out0 = fingerprint(&baseline.run(|_, _| 0.0).unwrap());
        assert_eq!(baseline.take_stats().unwrap().weight_epoch, 0);

        let mut swapped = service(2, 4);
        assert_eq!(swapped.weight_epoch(), WeightEpoch(0));
        let e = swapped.push_weights(0xD00D_F00D);
        assert_eq!(e, WeightEpoch(1));
        submit_all(&mut swapped);
        let out1 = fingerprint(&swapped.run(|_, _| 0.0).unwrap());
        assert_ne!(out0, out1, "weight swap did not change outputs");
        let st = swapped.take_stats().unwrap();
        assert_eq!(st.weight_epoch, 1);
        assert!(swapped
            .last_engine_stats()
            .iter()
            .all(|s| s.weight_epoch == 1), "a replica missed the swap");
        // the epoch level survives the drain (it is not a per-run delta)
        swapped.submit_group(spec(9, 9, 2, 0.0));
        swapped.run(|_, _| 0.0).unwrap();
        assert_eq!(swapped.take_stats().unwrap().weight_epoch, 1);
    }

    /// Hot requantization, threaded backend: a swap pushed while groups
    /// are already streaming to the workers lands between ticks —
    /// mid-step, in flight, no teardown — and every group still resolves.
    #[test]
    fn threaded_mid_flight_swap_resolves_with_epoch() {
        let mut svc = threaded_service(2, 3);
        for gid in 0..6 {
            svc.submit_group(spec(gid, gid as i32, 4, 1.0));
        }
        // workers may already be decoding the early groups
        assert_eq!(svc.push_weights(0xBEEF), WeightEpoch(1));
        let results = svc.run(|_, res| res.generated.len() as f32).unwrap();
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.complete()));
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed, st.submitted);
        assert_eq!(st.weight_epoch, 1);
    }

    /// Error hardening, inline backend: a failing engine tick aborts the
    /// run with an error, but leaves the service internally consistent —
    /// the ledger balances and the very same service serves the next
    /// workload.
    #[test]
    fn inline_tick_error_leaves_service_reusable() {
        // eos outside the vocab: every member must decode, so the injected
        // failure cannot be dodged by an immediate greedy EOS
        let mut eng = MockEngine::new(3, VOCAB, MAX_SEQ, 127);
        eng.fail_decodes = 1;
        let mut svc = RolloutService::new(vec![eng], MAX_SEQ, 127);
        for gid in 0..2 {
            svc.submit_group(spec(gid, gid as i32, 2, 0.0));
        }
        assert!(svc.run(|_, _| 0.0).is_err(), "injected failure vanished");
        let st = svc.take_stats().unwrap();
        assert_eq!(st.submitted, 4);
        assert_eq!(st.completed + st.cancelled, st.submitted,
                   "aborted run unbalanced the ledger");
        // reusable: the injected failure is consumed, next run completes
        for gid in 0..3 {
            svc.submit_group(spec(10 + gid, gid as i32, 2, 0.0));
        }
        let results = svc.run(|_, _| 0.0).unwrap();
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.complete()));
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed, st.submitted);
    }

    /// Error hardening, threaded backend: one worker's tick failure fails
    /// the run; every other worker is aborted and acknowledged, state is
    /// drained consistently, and the same workers serve the next run.
    #[test]
    fn threaded_tick_error_leaves_service_reusable() {
        let factories: Vec<EngineFactory<MockEngine>> = (0..2)
            .map(|i| {
                Box::new(move || {
                    // eos outside the vocab: no lucky early EOS can dodge
                    // the injected decode failure on worker 0
                    let mut e = MockEngine::new(2, VOCAB, MAX_SEQ, 127);
                    if i == 0 {
                        e.fail_decodes = 1;
                    }
                    Ok(e)
                }) as EngineFactory<MockEngine>
            })
            .collect();
        let mut svc =
            RolloutService::<MockEngine>::threaded(factories, MAX_SEQ, 127)
                .unwrap();
        for gid in 0..4 {
            svc.submit_group(spec(gid, gid as i32, 2, 0.0));
        }
        assert!(svc.run(|_, _| 0.0).is_err(), "worker failure vanished");
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed + st.cancelled, st.submitted,
                   "aborted threaded run unbalanced the ledger");
        // same workers, fresh workload
        for gid in 0..4 {
            svc.submit_group(spec(20 + gid, gid as i32, 2, 0.0));
        }
        let results = svc.run(|_, _| 0.0).unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.complete()));
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed, st.submitted);
    }

    /// KV paging and chunked prefill are serving-time memory/latency
    /// knobs, never semantics: the same workload produces bit-identical
    /// members under the default dense layout and under paged KV with a
    /// small page size, a tight page budget and chunked prefill — on both
    /// backends.  The dense run is the parity oracle.
    #[test]
    fn paged_chunked_matches_dense_bitwise() {
        let run = |paged: bool, threaded: bool| {
            let mut svc = if threaded {
                threaded_service(2, 4)
            } else {
                service(2, 4)
            };
            if paged {
                svc.set_kv(KvConfig {
                    layout: KvLayout::Paged,
                    page_size: 4,
                    budget_pages: Some(8), // tight: forces admission gating
                });
                svc.set_prefill_chunk(2); // prompts are 4 long: 2 chunks
            }
            for gid in 0..6 {
                let temp = if gid % 2 == 0 { 0.0 } else { 0.8 };
                svc.submit_group(spec(gid, gid as i32, 3, temp));
            }
            let results = svc.run(|_, res| res.generated.len() as f32);
            let fp = fingerprint(&results.unwrap());
            (fp, svc.take_stats().unwrap())
        };
        let (dense, dense_st) = run(false, false);
        let (paged, paged_st) = run(true, false);
        let (paged_thr, _) = run(true, true);
        assert_eq!(dense, paged,
                   "paged KV + chunked prefill changed rollout outputs");
        assert_eq!(dense, paged_thr,
                   "threaded paged run diverged from the dense oracle");
        assert_eq!(dense_st.prefill_chunks, 0, "dense path must not chunk");
        assert!(paged_st.prefill_chunks > 0, "chunking never engaged");
        assert!(paged_st.kv_pages_shared > 0, "siblings never aliased");
        assert_eq!(paged_st.kv_pages_freed, paged_st.kv_pages_allocated,
                   "drained paged run leaked pages");
    }

    /// A factory error at spawn time fails construction fast (no orphaned
    /// worker threads, no half-built service).
    #[test]
    fn threaded_startup_failure_fails_fast() {
        let factories: Vec<EngineFactory<MockEngine>> = (0..2)
            .map(|i| {
                Box::new(move || {
                    if i == 1 {
                        anyhow::bail!("no artifacts on this worker");
                    }
                    Ok(MockEngine::new(2, VOCAB, MAX_SEQ, EOS))
                }) as EngineFactory<MockEngine>
            })
            .collect();
        let err =
            RolloutService::<MockEngine>::threaded(factories, MAX_SEQ, EOS);
        assert!(err.is_err(), "startup failure was swallowed");
    }

    // ---- work stealing + placement log -------------------------------

    /// Straggler workload: `long` groups decode ~22 ticks per member,
    /// `short` groups ~2, but both carry the same submission-time cost
    /// estimate (`min(prompt+max_new, max_seq) × group_size = 48`), so
    /// least-loaded deterministically alternates them — every long group
    /// piles onto engine 0 while engine 1 drains early and sits idle.
    /// eos 127 is outside the vocab: lengths are exact, no lucky EOS.
    fn long_spec(gid: usize) -> GroupSpec {
        GroupSpec {
            group_id: gid,
            prompt: vec![1, 5],
            group_size: 2,
            max_new: 24, // budget min(2+24, 24) = 24 → 22 decode ticks
            temperature: 1.0,
            top_p: 1.0,
            seed: 0xA11CE ^ ((gid as u64) << 8),
        }
    }

    fn short_spec(gid: usize) -> GroupSpec {
        GroupSpec {
            group_id: gid,
            prompt: (0..22i32).map(|t| 1 + (t % 5)).collect(),
            group_size: 2,
            max_new: 24, // budget min(22+24, 24) = 24 → 2 decode ticks
            temperature: 0.0,
            top_p: 1.0,
            seed: 0xBEE ^ ((gid as u64) << 8),
        }
    }

    fn skew_service() -> RolloutService<MockEngine> {
        let engines: Vec<MockEngine> = (0..2)
            .map(|_| MockEngine::new(4, VOCAB, MAX_SEQ, 127))
            .collect();
        RolloutService::new(engines, MAX_SEQ, 127)
    }

    fn submit_skew(svc: &mut RolloutService<MockEngine>) {
        for k in 0..4 {
            svc.submit_group(long_spec(2 * k));
            svc.submit_group(short_spec(2 * k + 1));
        }
    }

    #[test]
    fn policy_parsing_roundtrips() {
        assert_eq!(StripePolicy::parse("replay"),
                   Some(StripePolicy::Replay));
        assert_eq!(StripePolicy::parse("bogus"), None);
        for p in [StripePolicy::RoundRobin, StripePolicy::LeastLoaded,
                  StripePolicy::Replay] {
            assert_eq!(StripePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(StealPolicy::parse("bogus"), None);
        for p in [StealPolicy::Off, StealPolicy::Idle] {
            assert_eq!(StealPolicy::parse(p.name()), Some(p));
        }
        for r in [PlacementReason::Place, PlacementReason::Steal] {
            assert_eq!(PlacementReason::parse(r.name()), Some(r));
        }
    }

    /// Satellite: `take_stats` mid-run is a typed error — the caller can
    /// downcast it, the count is reported, and draining the run makes
    /// the stats drain legal again.  Both backends enforce the contract.
    #[test]
    fn take_stats_mid_run_is_a_typed_error() {
        let mut svc = service(1, 2);
        svc.submit_group(spec(0, 0, 2, 0.0));
        let err = svc.take_stats().unwrap_err();
        let typed = err
            .downcast_ref::<OutstandingGroupsError>()
            .expect("error is not the typed OutstandingGroupsError");
        assert_eq!(typed.outstanding, 1);
        svc.run(|_, _| 0.0).unwrap();
        assert_eq!(svc.take_stats().unwrap().submitted, 2);

        let mut thr = threaded_service(2, 2);
        thr.submit_group(spec(1, 1, 2, 0.0));
        let err = thr.take_stats().unwrap_err();
        assert!(err.downcast_ref::<OutstandingGroupsError>().is_some());
        thr.run(|_, _| 0.0).unwrap();
        assert!(thr.take_stats().is_ok());
    }

    #[test]
    fn placement_log_json_roundtrip_and_final_engine() {
        let mut log = PlacementLog::default();
        log.push(0, 0, 0, PlacementReason::Place);
        log.push(1, 1, 1, PlacementReason::Place);
        log.push(1, 1, 0, PlacementReason::Steal);
        let text = log.to_json().to_string();
        let back =
            PlacementLog::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(log, back, "JSON round trip changed the log");
        assert_eq!(back.steals(), 1);
        assert_eq!(back.final_engine(0), Some(0));
        assert_eq!(back.final_engine(1), Some(0), "last record wins");
        assert_eq!(back.final_engine(7), None);
        assert!(PlacementLog::from_json(&Json::parse("{}").unwrap())
                    .is_err());
    }

    /// Checkpoint contract: a fresh service with a restored snapshot
    /// places, seeds, and logs the *next* run bit-identically to the
    /// service the snapshot came from; the snapshot JSON round-trips; and
    /// the failure modes (snapshot mid-run, replica-count mismatch) are
    /// typed errors.
    #[test]
    fn service_snapshot_restore_continues_bit_identically() {
        let run_more = |svc: &mut RolloutService<MockEngine>| {
            for gid in 10..16 {
                svc.submit_group(spec(gid, gid as i32, 3, 1.0));
            }
            let res = svc.run(|_, r| r.generated.len() as f32).unwrap();
            svc.take_stats().unwrap();
            fingerprint(&res)
        };
        // phase 1: a warm-up run establishes non-trivial cursors/log
        let mut original = service(3, 4);
        original.stripe = StripePolicy::LeastLoaded;
        for gid in 0..5 {
            original.submit_group(spec(gid, gid as i32, 4, 1.0));
        }
        original.run(|_, r| r.generated.len() as f32).unwrap();
        original.take_stats().unwrap();
        let snap = original.snapshot().unwrap();
        assert!(snap.next_uid > 0 && snap.next_group_uid == 5);
        // JSON round trip preserves every field
        let text = snap.to_json().to_string();
        let back =
            ServiceSnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(snap, back, "snapshot JSON round trip drifted");
        // phase 2: restore onto a fresh service; both continue identically
        let mut resumed = service(3, 4);
        resumed.stripe = StripePolicy::LeastLoaded;
        resumed.restore(&back).unwrap();
        let a = run_more(&mut original);
        let b = run_more(&mut resumed);
        assert_eq!(a, b, "restored service diverged from the original");
        assert_eq!(original.placement_log(), resumed.placement_log(),
                   "placement logs diverged after restore");
        // failure modes are typed
        let mut narrow = service(2, 4);
        assert!(narrow.restore(&back).is_err(),
                "replica-count mismatch must refuse");
        let mut busy = service(3, 4);
        busy.submit_group(spec(0, 0, 2, 0.0));
        assert!(busy.snapshot().unwrap_err()
                    .downcast_ref::<OutstandingGroupsError>().is_some());
        assert!(busy.restore(&back).unwrap_err()
                    .downcast_ref::<OutstandingGroupsError>().is_some());
        busy.run(|_, _| 0.0).unwrap();
    }

    /// The tentpole perf claim, enforced: on the skewed straggler
    /// workload, `steal idle` strictly beats plain least-loaded on decode
    /// ticks-to-drain (max per-engine decode steps), while producing
    /// bit-identical member outputs — stealing moves *where* queued work
    /// runs, never *what* it produces.
    #[test]
    fn steal_rebalances_stragglers_and_beats_least_loaded() {
        let run = |steal: StealPolicy| {
            let mut svc = skew_service();
            svc.stripe = StripePolicy::LeastLoaded;
            svc.steal = steal;
            submit_skew(&mut svc);
            let results = svc.run(|_, res| res.generated.len() as f32)
                             .unwrap();
            let st = svc.take_stats().unwrap();
            let per: Vec<usize> = svc
                .last_engine_stats()
                .iter()
                .map(|s| s.decode_steps)
                .collect();
            let out: Vec<_> = fingerprint(&results)
                .into_iter()
                .map(|(t, l, f, r, _)| (t, l, f, r)) // placement may move
                .collect();
            (out, st, per)
        };
        let (ll_out, ll_st, ll_per) = run(StealPolicy::Off);
        let (steal_out, steal_st, steal_per) = run(StealPolicy::Idle);
        assert_eq!(ll_out, steal_out,
                   "stealing changed member outputs");
        assert_eq!(steal_st.completed, steal_st.submitted);
        assert_eq!(ll_st.steals, 0);
        assert!(steal_st.steals >= 1, "no group was ever stolen");
        // least-loaded piles all long groups on engine 0; engine 1 idles
        assert!(ll_st.idle_ticks > 0, "straggler gap not observed");
        let ll_ticks = *ll_per.iter().max().unwrap();
        let steal_ticks = *steal_per.iter().max().unwrap();
        assert!(steal_ticks < ll_ticks,
                "stealing did not cut ticks-to-drain: {steal_ticks} vs \
                 {ll_ticks}");
        let ll_imb = SchedulerStats::load_imbalance(
            &ll_per.iter().map(|&d| SchedulerStats {
                decode_steps: d,
                ..SchedulerStats::default()
            }).collect::<Vec<_>>());
        let steal_imb = SchedulerStats::load_imbalance(
            &steal_per.iter().map(|&d| SchedulerStats {
                decode_steps: d,
                ..SchedulerStats::default()
            }).collect::<Vec<_>>());
        assert!(steal_imb < ll_imb,
                "stealing did not reduce load imbalance: {steal_imb} vs \
                 {ll_imb}");
    }

    /// The tentpole determinism claim, inline: replaying a stolen run's
    /// placement log (through a JSON round trip) reproduces the run
    /// bit-for-bit — tokens, logprobs, rewards AND engine attribution —
    /// with stealing off.  Placement became data.
    #[test]
    fn replay_reproduces_stolen_run_bitwise() {
        let mut stolen = skew_service();
        stolen.stripe = StripePolicy::LeastLoaded;
        stolen.steal = StealPolicy::Idle;
        submit_skew(&mut stolen);
        let a = stolen.run(|_, res| res.generated.len() as f32).unwrap();
        assert!(stolen.placement_log().steals() > 0,
                "workload produced no steals to replay");
        let text = stolen.placement_log().to_json().to_string();
        let log =
            PlacementLog::from_json(&Json::parse(&text).unwrap()).unwrap();

        let mut replayed = skew_service();
        replayed.set_replay(log.clone());
        assert_eq!(replayed.stripe, StripePolicy::Replay);
        // steal stays Off: the log alone must reproduce the placement
        submit_skew(&mut replayed);
        let b = replayed.run(|_, res| res.generated.len() as f32).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b),
                   "replay diverged from the recorded stolen run");
        // groups land directly on their recorded final engines
        for (uid, gr) in b.iter().enumerate() {
            assert_eq!(log.final_engine(uid as u64), Some(gr.engine));
        }
        // replaying needs no steals of its own
        assert_eq!(replayed.placement_log().steals(), 0);
    }

    /// Threaded stealing: whatever the thread timing did, the ledger
    /// balances, the steal count matches the log, and replaying the log
    /// on an inline service reproduces every member bit-for-bit —
    /// including engine attribution of stolen groups.
    #[test]
    fn threaded_steal_keeps_ledger_and_replays_bitwise() {
        let reward = |gid: usize, res: &RolloutResult| {
            (gid % 3) as f32 + (res.generated.len() % 2) as f32
        };
        let mut svc = threaded_service(3, 3);
        svc.stripe = StripePolicy::LeastLoaded;
        svc.steal = StealPolicy::Idle;
        for gid in 0..9 {
            let temp = if gid % 2 == 0 { 0.0 } else { 1.0 };
            svc.submit_group(spec(gid, gid as i32, 4, temp));
        }
        let a = svc.run(&mut |gid, res: &RolloutResult| reward(gid, res))
                   .unwrap();
        assert_eq!(a.len(), 9);
        assert!(a.iter().all(|g| g.complete()));
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed, st.submitted,
                   "stealing unbalanced the ledger");
        assert_eq!(st.steals, svc.placement_log().steals(),
                   "stats and log disagree on steal count");

        let mut replayed = service(3, 3);
        replayed.set_replay(svc.placement_log().clone());
        for gid in 0..9 {
            let temp = if gid % 2 == 0 { 0.0 } else { 1.0 };
            replayed.submit_group(spec(gid, gid as i32, 4, temp));
        }
        let b = replayed
            .run(&mut |gid, res: &RolloutResult| reward(gid, res))
            .unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b),
                   "inline replay diverged from the threaded stolen run");
    }

    /// Stealing composed with online pruning and paged KV: whole-group
    /// moves never race a prune cancel (candidates have zero finishes),
    /// the merged ledger balances, and the page ledger stays leak-free.
    #[test]
    fn steal_with_pruning_and_paged_kv_stays_leak_free() {
        let engines: Vec<MockEngine> = (0..2)
            .map(|_| MockEngine::new(3, VOCAB, MAX_SEQ, EOS))
            .collect();
        let mut svc = RolloutService::new(engines, MAX_SEQ, EOS);
        svc.stripe = StripePolicy::LeastLoaded;
        svc.steal = StealPolicy::Idle;
        svc.prune = PrunePolicy::online(2);
        svc.set_kv(KvConfig {
            layout: KvLayout::Paged,
            page_size: 4,
            budget_pages: Some(8),
        });
        for gid in 0..6 {
            svc.submit_group(spec(gid, gid as i32, 6, 1.0));
        }
        let results = svc
            .run(|gid, res| {
                if gid % 2 == 0 {
                    1.0 // uniform → pruned once decided
                } else {
                    (res.generated.len() % 3) as f32
                }
            })
            .unwrap();
        assert_eq!(results.len(), 6);
        let st = svc.take_stats().unwrap();
        assert_eq!(st.completed + st.cancelled, st.submitted,
                   "steal + prune unbalanced the ledger");
        assert!(st.cancelled > 0, "pruning never engaged");
        assert_eq!(st.kv_pages_freed, st.kv_pages_allocated,
                   "steal + prune leaked KV pages");
    }
}
