//! # QuRL — Efficient Reinforcement Learning with Quantized Rollout
//!
//! Rust + JAX + Pallas reproduction of the QuRL paper (Li et al., 2026):
//! RL training for LLMs where the *rollout* runs on a quantized actor
//! (INT8/FP8) while policy updates stay full-precision, stabilized by
//! Adaptive Clipping Range (ACR) and Update-Aware Quantization (UAQ).
//!
//! Layer map (see DESIGN.md):
//! * [`runtime`] — PJRT execution of AOT artifacts (the L2/L1 compute),
//! * [`coordinator`] — rollout engine: scheduling, batching, sampling,
//! * [`rl`] — advantages, objectives (naive/TIS/ACR), the training loop,
//! * [`quant`] — Rust mirrors of the quantizers + UAQ + analysis metrics,
//! * [`tasks`] — synthetic verifiable-reward workloads + tokenizer,
//! * [`perfmodel`] — GPU roofline simulator (paper Fig. 8),
//! * [`metrics`], [`config`], [`util`] — support substrate,
//! * [`analysis`] — repo-aware lint (`qurl lint`): catalog/config drift,
//!   protocol gaps, and hot-path panics as build failures.

pub mod analysis;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod perfmodel;
pub mod quant;
pub mod rl;
pub mod runtime;
pub mod tasks;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
