//! QuRL command-line interface (the L3 leader entrypoint).
//!
//! Subcommands:
//!   pretrain   — SFT the base model the RL experiments start from
//!   train      — run an RL experiment (preset or config file)
//!   eval       — evaluate a checkpoint (greedy Avg@1 and Avg@K)
//!   serve      — rollout-service demo over random requests (continuous
//!                batching, group-shared prefill, multi-engine striping)
//!   throughput — Fig. 8 roofline sweep (+ measured CPU decode)
//!   quantize   — quantize a checkpoint and report error statistics
//!   info       — artifact/manifest summary
//!   lint       — repo-aware static analysis (catalog drift, config
//!                drift, protocol gaps, hot-path panics, Send-safety)

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use qurl::config;
use qurl::coordinator::{EngineFactory, GroupSpec, KvConfig, KvLayout,
                        PlacementLog, RolloutService, StealPolicy,
                        StepEngine, StripePolicy};
use qurl::metrics::Recorder;
use qurl::perfmodel::{self, DecodeConfig, Precision};
use qurl::quant::analysis;
use qurl::rl::{self, eval as rleval, RolloutExec, RolloutPath, Trainer,
               TrainerConfig};
use qurl::runtime::{ParamStore, QuantMode, Runtime};
use qurl::tasks::{Suite, Tokenizer};
use qurl::util::cli::Cli;
use qurl::util::timer::print_table;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "pretrain" => cmd_pretrain(rest),
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "throughput" => cmd_throughput(rest),
        "quantize" => cmd_quantize(rest),
        "info" => cmd_info(rest),
        "lint" => cmd_lint(rest),
        _ => {
            eprintln!(
                "qurl {} — Quantized Reinforcement Learning (QuRL) reproduction\n\n\
                 usage: qurl <command> [--help]\n\n\
                 commands:\n\
                 \x20 pretrain    SFT the base model (required before RL)\n\
                 \x20 train       run an RL experiment (presets: {})\n\
                 \x20 eval        evaluate a checkpoint\n\
                 \x20 serve       rollout-service demo (continuous batching,\n\
                 \x20             shared prefill, multi-engine striping)\n\
                 \x20 throughput  Fig. 8 roofline sweep\n\
                 \x20 quantize    quantization error report\n\
                 \x20 info        manifest summary\n\
                 \x20 lint        repo lint: drift/protocol/panic passes",
                qurl::version(),
                config::PRESETS.join(", ")
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &qurl::util::cli::Args) -> PathBuf {
    PathBuf::from(args.str("artifacts"))
}

/// Load the shared base checkpoint, or SFT-pretrain + cache it on demand.
pub fn base_model(rt: &Runtime, path: &Path, sft_steps: usize, seed: u64)
                  -> Result<ParamStore> {
    if path.exists() {
        let ps = ParamStore::load(path)?;
        anyhow::ensure!(ps.params.len() == rt.manifest().n_params,
                        "checkpoint size mismatch (rebuild with pretrain)");
        return Ok(ps);
    }
    qurl::info!("main", "no base checkpoint at {path:?}; running SFT \
                 pretraining ({sft_steps} steps)");
    let init = rt.init_params(seed as i32)?;
    let mut ps = ParamStore::new(rt.manifest(), init);
    let suite = Suite::by_name("deepscaler").unwrap();
    let mut rec = Recorder::ephemeral("sft");
    let loss = rl::pretrain_sft(rt, &mut ps, &suite, sft_steps, 3e-4, seed,
                                &mut rec)?;
    qurl::info!("main", "SFT done, final loss {loss:.4}");
    ps.reset_optimizer();
    ps.save(path)?;
    Ok(ps)
}

fn cmd_pretrain(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl pretrain", "SFT-train the RL base model")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("out", "results/base_model.bin", "checkpoint path")
        .opt("steps", "600", "SFT steps")
        .opt("lr", "3e-4", "learning rate")
        .opt("seed", "0", "seed")
        .opt("suite", "deepscaler", "task suite");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::open(&artifacts_dir(&args))?;
    let init = rt.init_params(args.u64("seed") as i32)?;
    let mut ps = ParamStore::new(rt.manifest(), init);
    let suite = Suite::by_name(&args.str("suite")).context("unknown suite")?;
    let mut rec = Recorder::create(Path::new("results"), "pretrain")?;
    let loss = rl::pretrain_sft(&rt, &mut ps, &suite, args.usize("steps"),
                                args.f32("lr"), args.u64("seed"), &mut rec)?;
    ps.reset_optimizer();
    let out = PathBuf::from(args.str("out"));
    ps.save(&out)?;
    // quick greedy eval of the base model
    let tk = Tokenizer::new();
    let w = rt.engine_weights(QuantMode::Bf16, &ps.params)?;
    let acc = rleval::greedy_accuracy(&rt, &w, &tk, &suite, 1234, 32)?;
    println!("base model: sft_loss={loss:.4} greedy_acc={acc:.3} -> {out:?}");
    Ok(())
}

fn train_cli() -> Cli {
    // --rollout-path fused:     lockstep waves via the fused generate
    //                           artifact (the paper's baseline serving).
    // --rollout-path scheduler: continuous batching — prompts become
    //                           RolloutRequests, early-finished sequences
    //                           free KV slots immediately, and each step's
    //                           Recorder row gains sched_occupancy,
    //                           sched_queue_wait_s, sched_prefill_calls,
    //                           sched_decode_calls, sched_generated_tokens
    //                           and sched_tokens_per_s.
    Cli::new("qurl train", "run a QuRL RL experiment (rollouts served by \
              the fused artifact or the continuous-batching scheduler)")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("preset", "deepscaler_grpo", "preset name or path to .json")
        .opt("base", "results/base_model.bin", "base checkpoint")
        .opt("run", "", "run name (default: derived)")
        .opt("steps", "0", "override steps (0 = preset)")
        .opt("objective", "", "override objective (onpolicy|naive|decoupled|tis|acr)")
        .opt("rollout", "", "override rollout mode (bf16|int8|fp8)")
        .opt("rollout-path", "",
             "rollout serving path: fused waves or the group-aware rollout \
              service over continuous-batching schedulers, with sched_* \
              metrics (fused|scheduler; default preset)")
        .opt("rollout-engines", "0",
             "engine replicas behind the rollout service (scheduler path; \
              0 = preset)")
        .opt("rollout-exec", "",
             "rollout service execution: inline (one thread ticks all \
              schedulers) or threaded (one worker thread per engine \
              replica, parallel decode; outputs bit-identical) \
              (inline|threaded; default preset)")
        .opt("stripe", "",
             "group placement across engine replicas: rr (round-robin), \
              least-loaded (fewest estimated outstanding decode tokens, \
              prompt-length + max_new aware) or replay (re-execute a \
              recorded --placement-log bit-identically) \
              (rr|least-loaded|replay; default preset)")
        .opt("steal", "",
             "work stealing across engine replicas: idle replicas pull \
              whole queued groups off the most-loaded one, using live \
              outstanding-token counters (off|idle; default preset)")
        .opt("placement-log", "",
             "placement log JSON path: with --stripe replay it is loaded \
              and re-executed; otherwise every placement/steal is recorded \
              there after each rollout wave (empty = off)")
        .opt("min-prefill-batch", "0",
             "scheduler admission floor: wait until this many requests can \
              prefill together (0 = preset)")
        .opt("kv", "",
             "KV bookkeeping layout on the scheduler path: dense (full \
              sequence reserved per slot) or paged (fixed-size pages, \
              prefix aliasing + copy-on-write, demand-based admission; \
              outputs bit-identical) (dense|paged; default preset)")
        .opt("kv-page-size", "0",
             "cache positions per KV page under --kv paged (0 = preset)")
        .opt("prefill-chunk", "0",
             "chunked prefill: prompts longer than this prefill in chunks \
              interleaved with decode ticks (0 = preset, preset 0 = whole-\
              prompt prefill)")
        .opt("prune", "",
             "in-flight rollout pruning under DAPO dynamic sampling on the \
              scheduler path (on|off; default preset)")
        .opt("prune-min-finished", "0",
             "members that must finish with identical reward before a group \
              is pruned (0 = auto: max(2, group_size/2))")
        .opt("requant-delta", "",
             "delta requantization: reuse the previous epoch's payload for \
              every tensor whose quantized form is bit-identical, so a \
              weight refresh re-stages only what changed (off = full \
              requant oracle; outputs bit-identical) (on|off; default on)")
        .opt("uaq", "-1", "override UAQ scale (-1 = preset)")
        .opt("lr", "0", "override learning rate (0 = preset)")
        .opt("seed", "0", "seed")
        .opt("engine-noise", "-1", "override engine noise std (-1 = preset)")
        .opt("sft-steps", "600", "SFT steps if base model missing")
        .opt("save", "", "save final checkpoint here")
        .opt("ckpt-every", "0",
             "write a crash-safe run checkpoint every k steps (atomic \
              versioned snapshot: params + optimizer + RNG + service \
              state; 0 = off)")
        .opt("ckpt-dir", "",
             "checkpoint directory for --ckpt-every / --resume (empty = \
              off)")
        .opt("ckpt-keep", "-1",
             "retention: keep the newest k good checkpoints, never \
              deleting the newest good one (0 = keep all; -1 = preset, \
              preset 3)")
        .opt("resume", "",
             "resume from the newest good checkpoint under --ckpt-dir, \
              bit-identically; refused if the config changed (on|off; \
              default off)")
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let args = train_cli().parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Arc::new(Runtime::open(&artifacts_dir(&args))?);
    let preset_name = args.str("preset");
    let mut cfg: TrainerConfig = if preset_name.ends_with(".json") {
        config::load(Path::new(&preset_name))?
    } else {
        config::preset(&preset_name)
            .with_context(|| format!("unknown preset {preset_name:?}"))?
    };
    if args.usize("steps") > 0 {
        cfg.steps = args.usize("steps");
    }
    if !args.str("objective").is_empty() {
        cfg.objective.kind = rl::ObjectiveKind::parse(&args.str("objective"))
            .context("bad --objective")?;
    }
    if !args.str("rollout").is_empty() {
        cfg.rollout_mode =
            QuantMode::parse(&args.str("rollout")).context("bad --rollout")?;
    }
    if !args.str("rollout-path").is_empty() {
        cfg.rollout_path = RolloutPath::parse(&args.str("rollout-path"))
            .context("bad --rollout-path (fused|scheduler)")?;
    }
    if args.usize("rollout-engines") > 0 {
        cfg.rollout_engines = args.usize("rollout-engines");
    }
    if !args.str("rollout-exec").is_empty() {
        cfg.rollout_exec = RolloutExec::parse(&args.str("rollout-exec"))
            .context("bad --rollout-exec (inline|threaded)")?;
    }
    if !args.str("stripe").is_empty() {
        cfg.rollout_stripe = StripePolicy::parse(&args.str("stripe"))
            .context("bad --stripe (rr|least-loaded|replay)")?;
    }
    if !args.str("steal").is_empty() {
        cfg.rollout_steal = StealPolicy::parse(&args.str("steal"))
            .context("bad --steal (off|idle)")?;
    }
    if !args.str("placement-log").is_empty() {
        cfg.placement_log = args.str("placement-log");
    }
    if args.usize("min-prefill-batch") > 0 {
        cfg.min_prefill_batch = args.usize("min-prefill-batch");
    }
    if !args.str("kv").is_empty() {
        cfg.kv_layout = KvLayout::parse(&args.str("kv"))
            .context("bad --kv (dense|paged)")?;
    }
    if args.usize("kv-page-size") > 0 {
        cfg.kv_page_size = args.usize("kv-page-size");
    }
    if args.usize("prefill-chunk") > 0 {
        cfg.prefill_chunk = args.usize("prefill-chunk");
    }
    match args.str("prune").as_str() {
        "" => {}
        "on" | "true" | "1" => cfg.prune_rollouts = true,
        "off" | "false" | "0" => cfg.prune_rollouts = false,
        other => anyhow::bail!("bad --prune {other:?} (on|off)"),
    }
    if args.usize("prune-min-finished") > 0 {
        cfg.prune_min_finished = args.usize("prune-min-finished");
    }
    match args.str("requant-delta").as_str() {
        "" => {}
        "on" | "true" | "1" => cfg.requant_delta = true,
        "off" | "false" | "0" => cfg.requant_delta = false,
        other => anyhow::bail!("bad --requant-delta {other:?} (on|off)"),
    }
    if args.f64("uaq") >= 0.0 {
        cfg.uaq_scale = args.f32("uaq");
    }
    if args.f64("lr") > 0.0 {
        cfg.objective.lr = args.f32("lr");
    }
    if args.f64("engine-noise") >= 0.0 {
        cfg.engine_noise = args.f32("engine-noise");
    }
    if args.usize("ckpt-every") > 0 {
        cfg.ckpt_every = args.usize("ckpt-every");
    }
    if !args.str("ckpt-dir").is_empty() {
        cfg.ckpt_dir = args.str("ckpt-dir");
    }
    if args.f64("ckpt-keep") >= 0.0 {
        cfg.ckpt_keep = args.f64("ckpt-keep") as usize;
    }
    match args.str("resume").as_str() {
        "" => {}
        "on" | "true" | "1" => cfg.resume = true,
        "off" | "false" | "0" => cfg.resume = false,
        other => anyhow::bail!("bad --resume {other:?} (on|off)"),
    }
    cfg.seed = args.u64("seed");
    let run = if args.str("run").is_empty() {
        format!("{}_{}_{}_uaq{}", preset_name.trim_end_matches(".json"),
                cfg.objective.kind.name(), cfg.rollout_mode.tag(),
                cfg.uaq_scale)
    } else {
        args.str("run")
    };
    let base = base_model(&rt, Path::new(&args.str("base")),
                          args.usize("sft-steps"), 0)?;
    let rec = Recorder::create(Path::new("results"), &run)?;
    config::save(&cfg, &Path::new("results").join(format!("{run}.config.json")))?;
    let mut trainer = Trainer::new(&rt, cfg, base, rec)?;
    let final_reward = trainer.run()?;
    println!("run {run}: final training reward (tail mean) = {final_reward:.3}");
    if !args.str("save").is_empty() {
        trainer.ps.save(Path::new(&args.str("save")))?;
    }
    // artifact execution profile (L3 perf accounting)
    for (name, st) in rt.store.stats().into_iter().take(6) {
        qurl::info!("perf", "{name}: {} calls, {:.1}s, {:.1} MB h2d / \
                     {:.1} MB d2h",
                    st.calls, st.secs, st.bytes_h2d as f64 / 1e6,
                    st.bytes_d2h as f64 / 1e6);
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl eval", "evaluate a checkpoint")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("ckpt", "results/base_model.bin", "checkpoint to evaluate")
        .opt("suite", "deepscaler", "task suite")
        .opt("mode", "bf16", "engine precision for eval rollouts")
        .opt("k", "1", "Avg@K samples (1 = greedy)")
        .opt("temp", "0.6", "sampling temperature for K>1")
        .opt("top-p", "0.7", "nucleus for K>1")
        .opt("n", "32", "problems per family")
        .opt("seed", "1234", "test-set seed");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::open(&artifacts_dir(&args))?;
    let ps = ParamStore::load(Path::new(&args.str("ckpt")))?;
    let mode = QuantMode::parse(&args.str("mode")).context("bad --mode")?;
    let w = rt.engine_weights(mode, &ps.params)?;
    let suite = Suite::by_name(&args.str("suite")).context("unknown suite")?;
    let tk = Tokenizer::new();
    let k = args.usize("k");
    let (temp, top_p) = if k <= 1 {
        (0.0, 1.0)
    } else {
        (args.f32("temp"), args.f32("top-p"))
    };
    let per = rleval::per_family_accuracy(&rt, &w, &tk, &suite,
                                          args.u64("seed"), args.usize("n"),
                                          k.max(1), temp, top_p)?;
    let mut rows = Vec::new();
    let mut total = 0.0;
    for (fam, (acc, n)) in &per {
        rows.push(vec![fam.to_string(), format!("{:.3}", acc),
                       n.to_string()]);
        total += acc;
    }
    rows.push(vec!["AVG".into(), format!("{:.3}", total / per.len() as f64),
                   String::new()]);
    print_table(&format!("Avg@{k} ({} rollouts, {})", args.str("mode"),
                         args.str("suite")),
                &["family", "accuracy", "n"], &rows);
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl serve",
                       "rollout-service demo: continuous batching, \
                        group-shared prefill, multi-engine execution")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("base", "results/base_model.bin", "checkpoint")
        .opt("mode", "int8", "engine precision")
        .opt("requests", "96", "number of requests")
        .opt("group", "1", "rollouts per request prompt (shared prefill)")
        .opt("engines", "1", "engine replicas")
        .opt("exec", "inline",
             "execution backend: inline or threaded (one worker thread \
              per engine replica)")
        .opt("stripe", "rr", "group placement: rr|least-loaded|replay")
        .opt("steal", "off",
             "work stealing: idle replicas pull queued groups off the \
              most-loaded one (off|idle)")
        .opt("placement-log", "",
             "placement log JSON: loaded under --stripe replay, dumped \
              after the run otherwise (empty = off)")
        .opt("max-new", "48", "max generated tokens per request")
        .opt("min-batch", "8", "dynamic-batching admission threshold")
        .opt("kv", "dense", "KV bookkeeping layout: dense|paged")
        .opt("kv-page-size", "16", "cache positions per KV page")
        .opt("kv-budget", "0",
             "page budget gating admission, per engine (0 = derived from \
              slots x max_seq; only binds under --kv paged vs the dense \
              full-sequence reservation)")
        .opt("prefill-chunk", "0",
             "prefill prompts in chunks of this many positions interleaved \
              with decode ticks (0 = whole-prompt prefill)")
        .opt("seed", "0", "seed");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Arc::new(Runtime::open(&artifacts_dir(&args))?);
    let ps = base_model(&rt, Path::new(&args.str("base")), 600, 0)?;
    let mode = QuantMode::parse(&args.str("mode")).context("bad --mode")?;
    let w = rt.engine_weights(mode, &ps.params)?;
    let man = rt.manifest().clone();
    let n_engines = args.usize("engines").max(1);
    let exec = RolloutExec::parse(&args.str("exec"))
        .context("bad --exec (inline|threaded)")?;
    let stripe = StripePolicy::parse(&args.str("stripe"))
        .context("bad --stripe (rr|least-loaded|replay)")?;
    let steal = StealPolicy::parse(&args.str("steal"))
        .context("bad --steal (off|idle)")?;
    let log_path = args.str("placement-log");
    let mut svc = match exec {
        RolloutExec::Inline => {
            let engines: Vec<StepEngine> = (0..n_engines)
                // lint: allow(send, inline backend — engines are built and ticked on this thread only, PJRT state never crosses)
                .map(|_| StepEngine::new(&rt, w.clone()))
                .collect();
            RolloutService::new(engines, man.max_seq, man.eos_id)
        }
        RolloutExec::Threaded => {
            let dir = artifacts_dir(&args);
            let factories: Vec<EngineFactory<StepEngine>> = (0..n_engines)
                .map(|_| StepEngine::factory(dir.clone(), w.clone()))
                .collect();
            RolloutService::threaded(factories, man.max_seq, man.eos_id)?
        }
    };
    svc.stripe = stripe;
    svc.steal = steal;
    if stripe == StripePolicy::Replay {
        anyhow::ensure!(!log_path.is_empty(),
                        "--stripe replay needs --placement-log <path>");
        svc.set_replay(PlacementLog::load(Path::new(&log_path))?);
    }
    svc.set_min_prefill_batch(args.usize("min-batch"));
    let kv_layout = KvLayout::parse(&args.str("kv"))
        .context("bad --kv (dense|paged)")?;
    svc.set_kv(KvConfig {
        layout: kv_layout,
        page_size: args.usize("kv-page-size").max(1),
        budget_pages: match args.usize("kv-budget") {
            0 => None,
            b => Some(b),
        },
    });
    svc.set_prefill_chunk(args.usize("prefill-chunk"));
    let tk = Tokenizer::new();
    let suite = Suite::by_name("deepscaler").unwrap();
    let mut sampler = suite.train_sampler(args.u64("seed"));
    let group = args.usize("group").max(1);
    let n = args.usize("requests").div_ceil(group);
    for gid in 0..n {
        let (_, prob) = sampler.next();
        svc.submit_group(GroupSpec {
            group_id: gid,
            prompt: tk.encode_prompt(&prob.prompt),
            group_size: group,
            max_new: args.usize("max-new"),
            temperature: 1.0,
            top_p: 1.0,
            seed: (gid as u64) ^ 0x5eed,
        });
    }
    let results = svc.run(|_, _| 0.0)?;
    if !log_path.is_empty() && stripe != StripePolicy::Replay {
        svc.placement_log().save(Path::new(&log_path))?;
        println!("placement log ({} records, {} steals) -> {log_path}",
                 svc.placement_log().records.len(),
                 svc.placement_log().steals());
    }
    let st = svc.take_stats()?;
    let served: usize = results.iter().map(|g| g.members.len()).sum();
    println!("served {served} requests ({n} groups x {group}, {n_engines} \
              engine(s), {} exec, {} striping): {:.1} tok/s, mean \
              occupancy {:.2}, {} prefill calls ({:.1} rows/call, {} rows \
              forked), {} decode calls, {:.1} MB h2d / {:.1} MB d2h staged",
             exec.name(), stripe.name(), st.tokens_per_s(),
             st.mean_occupancy(), st.prefill_calls,
             st.mean_prefill_batch(), st.forked, st.decode_calls,
             st.bytes_h2d as f64 / 1e6, st.bytes_d2h as f64 / 1e6);
    println!("  kv ({}, page {}): {} pages allocated / {} freed, {} \
              aliased, {} CoW-copied, high water {} pages, {} chunked \
              prefill rounds",
             kv_layout.name(), args.usize("kv-page-size").max(1),
             st.kv_pages_allocated, st.kv_pages_freed, st.kv_pages_shared,
             st.kv_pages_cow, st.kv_pages_high_water, st.prefill_chunks);
    println!("  placement (steal {}): {} steals, {} summed idle ticks",
             steal.name(), st.steals, st.idle_ticks);
    if n_engines > 1 {
        for (i, es) in svc.last_engine_stats().iter().enumerate() {
            println!("  engine {i}: {} decode calls, {} tokens, occupancy \
                      {:.2}", es.decode_calls, es.generated_tokens,
                     es.mean_occupancy());
        }
    }
    Ok(())
}

fn cmd_throughput(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl throughput", "Fig. 8 roofline sweep")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("batch", "64", "decode batch")
        .opt("ctx", "2048", "mean context length")
        .opt("gen-len", "1024", "mean generation length");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let cfg = DecodeConfig {
        batch: args.usize("batch"),
        ctx: args.usize("ctx"),
        gen_len: args.usize("gen-len"),
    };
    let mut rows = Vec::new();
    for gpu in perfmodel::ALL_GPUS {
        for scale in perfmodel::roofline::ALL_SCALES {
            let bf16 = perfmodel::decode_throughput(gpu, scale, Precision::Bf16, &cfg);
            let int8 = perfmodel::decode_throughput(gpu, scale, Precision::Int8, &cfg);
            rows.push(vec![
                gpu.spec().name.to_string(),
                scale.name().to_string(),
                format!("{bf16:.2}"),
                format!("{int8:.2}"),
                format!("+{:.0}%", (int8 / bf16 - 1.0) * 100.0),
            ]);
        }
    }
    print_table("Fig. 8 analog: decode throughput (queries/s, roofline)",
                &["gpu", "model", "bf16 q/s", "int8 q/s", "speedup"], &rows);
    let _ = artifacts_dir(&args); // measured CPU numbers live in the bench
    println!("\n(measured CPU-testbed decode rates: cargo bench --bench \
              fig8_throughput)");
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl quantize", "quantization error report")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("ckpt", "results/base_model.bin", "checkpoint")
        .opt("uaq", "1", "UAQ scale to compare (1 = off)");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::open(&artifacts_dir(&args))?;
    let ps = ParamStore::load(Path::new(&args.str("ckpt")))?;
    let man = rt.manifest().clone();
    let mut rows = Vec::new();
    for (label, params) in [
        ("plain".to_string(), ps.params.clone()),
        (format!("uaq_s={}", args.str("uaq")),
         rt.uaq_scale(&ps.params, args.f32("uaq"))?),
    ] {
        let b = &params[man.a_size..];
        for mode in [QuantMode::Int8, QuantMode::Fp8] {
            let err = analysis::normalized_quant_error(&man, b, mode);
            rows.push(vec![label.clone(), mode.tag().into(),
                           format!("{err:.3e}")]);
        }
    }
    print_table("normalized weight quantization error (Eq. 14)",
                &["params", "mode", "error"], &rows);
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl info", "artifact/manifest summary")
        .opt("artifacts", "artifacts", "artifact directory");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let dir = artifacts_dir(&args);
    let rt = Runtime::open(&dir)?;
    let m = rt.manifest();
    println!("platform     : {}", rt.store.platform());
    println!("model        : {} params ({} layers, d={}, {} heads, ff={})",
             m.n_params, m.n_layers, m.d_model, m.n_heads, m.d_ff);
    println!("context      : {} (prompt <= {}, max_new {})", m.max_seq,
             m.max_prompt, m.max_new);
    println!("rollout batch: {}", m.rollout_batch);
    println!("quantized    : {} weights in {} matrices ({} scales)",
             m.b_size, m.qscales.len(), m.n_qscales);
    println!("artifacts    : {}", m.artifacts.len());
    for (name, sig) in &m.artifacts {
        println!("  {name:16} {} in / {} out", sig.inputs.len(),
                 sig.outputs.len());
    }
    Ok(())
}

/// `qurl lint` — run the five repo-aware static-analysis passes over a
/// Rust source tree and exit nonzero on findings.  The same passes run
/// as tier-1 unit tests (`src/analysis/passes.rs` fixtures plus the
/// repo-clean gate in `tests/lint.rs`); this subcommand is the CI
/// entrypoint, and `--report` writes the findings table to a file so
/// CI can upload it as a build artifact.  See `src/analysis/mod.rs`
/// for the lint catalog and escape hatches.
fn cmd_lint(argv: &[String]) -> Result<()> {
    let cli = Cli::new("qurl lint",
                       "repo-aware static analysis (see src/analysis/)")
        .opt("src", "",
             "source root to scan (default: the src/ tree this binary \
              was built from)")
        .opt("report", "", "also write the findings table to this path");
    let args = cli.parse_from(argv).map_err(|e| anyhow::anyhow!(e))?;
    let root = match args.str("src") {
        s if s.is_empty() => {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
        }
        s => PathBuf::from(s),
    };
    let set = qurl::analysis::SourceSet::load(&root)
        .with_context(|| format!("scanning {}", root.display()))?;
    let findings = qurl::analysis::run_all(&set);
    let table = qurl::analysis::report(&findings);
    println!("{table}");
    let report_path = args.str("report");
    if !report_path.is_empty() {
        if let Some(dir) = Path::new(&report_path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&report_path, &table)
            .with_context(|| format!("writing {report_path}"))?;
    }
    anyhow::ensure!(findings.is_empty(), "qurl lint: {} finding(s)",
                    findings.len());
    Ok(())
}
