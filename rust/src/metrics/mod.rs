//! Experiment metric recording: JSONL event streams + CSV curves + run
//! summaries.  Every bench/example writes through this module so
//! EXPERIMENTS.md can be regenerated from `results/`.

pub mod recorder;

pub use recorder::{Recorder, Row};
