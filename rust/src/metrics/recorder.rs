//! JSONL/CSV metric recorder.
//!
//! # Rollout-serving field catalog (`sched_*`)
//!
//! When the trainer serves rollouts through the scheduler path, every RL
//! step emits one `phase = "rollout"` row with the merged counters of all
//! engine replicas:
//!
//! | field                     | meaning                                     |
//! |---------------------------|---------------------------------------------|
//! | `sched_occupancy`         | mean occupied-slot fraction per decode call |
//! | `sched_queue_wait_s`      | mean seconds a request queued before prefill|
//! | `sched_submitted`         | requests admitted to scheduler queues this  |
//! |                           | step (pre-prefill; queue inflow)            |
//! | `sched_completed`         | requests that finished (EOS or max_new)     |
//! | `sched_decode_steps`      | summed per-replica decode ticks — the raw   |
//! |                           | series `sched_load_imbalance` max/min-      |
//! |                           | reduces (vs. `sched_decode_calls`, which    |
//! |                           | counts lockstep artifact calls)             |
//! | `sched_prefill_calls`     | batched prefill artifact calls              |
//! | `sched_prefill_rows`      | rows actually prefilled (post prefix-share) |
//! | `sched_mean_prefill_batch`| rows per prefill call (admission health)    |
//! | `sched_forked`            | KV rows forked instead of prefilled         |
//! | `sched_cancelled`         | requests cancelled in flight (pruning)      |
//! | `sched_pruned_groups`     | groups whose remainder was pruned           |
//! | `sched_steals`            | whole queued groups an idle replica pulled  |
//! |                           | off the most-loaded one (`--steal idle`;    |
//! |                           | every steal is in the placement log)        |
//! | `sched_idle_ticks`        | summed decode-tick deficit vs. the busiest  |
//! |                           | replica per drain (0 = replicas drained in  |
//! |                           | lockstep — the straggler gap stealing       |
//! |                           | exists to close)                            |
//! | `sched_decode_calls`      | lockstep decode artifact calls              |
//! | `sched_generated_tokens`  | decode tokens emitted (incl. partials)      |
//! | `sched_tokens_per_s`      | tokens / service wall time                  |
//! | `sched_weight_epoch`      | weight generation serving this step (max    |
//! |                           | over replicas; bumps on hot requantization) |
//! | `sched_bytes_h2d`         | bytes newly converted host→device-format    |
//! |                           | (resident weights/KV riding a cached        |
//! |                           | conversion count 0 — the copy-tax ledger)   |
//! | `sched_bytes_d2h`         | bytes copied device-format→host (logits;    |
//! |                           | KV only at merge/fork boundaries)           |
//! | `sched_swap_bytes_h2d`    | weight bytes swaps scheduled for re-staging |
//! |                           | (pointer-unequal payloads only — the delta- |
//! |                           | requantization swap cost; 0 on a refresh    |
//! |                           | whose tensors all requantized identically)  |
//! | `sched_requant_tensors_changed` | manifest tensors whose requantized    |
//! |                           | payload differed from the previous epoch's  |
//! |                           | (delta refresh re-staged them)              |
//! | `sched_requant_tensors_skipped` | manifest tensors reused Arc-for-Arc   |
//! |                           | because quantization masked their update    |
//! |                           | (the paper's masking effect, per refresh)   |
//! | `sched_h2d_per_decode`    | `sched_bytes_h2d / sched_decode_calls`.  On |
//! |                           | the resident path WEIGHT bytes are ~0       |
//! |                           | between swaps; what remains is per-tick     |
//! |                           | control tensors plus one full-KV re-stage   |
//! |                           | after each admission merge/fork — so this   |
//! |                           | scales with admission rate, and only the    |
//! |                           | admission-free steady state collapses to    |
//! |                           | control-tensor size (integration-tested)    |
//! | `sched_prefill_chunks`    | chunked-prefill work units: truncated       |
//! |                           | prefill calls + chunk-continuation decode   |
//! |                           | rounds (0 with `prefill_chunk` off)         |
//! | `sched_kv_pages_allocated`| KV pages newly acquired this step           |
//! | `sched_kv_pages_freed`    | KV pages returned to the free list; equals  |
//! |                           | `allocated` on every drained step (no leaks)|
//! | `sched_kv_pages_shared`   | prompt pages forked siblings aliased        |
//! |                           | instead of allocating (prefix sharing win)  |
//! | `sched_kv_pages_cow`      | copy-on-write page copies (first write into |
//! |                           | a shared page)                              |
//! | `sched_kv_pages_active`   | live KV pages at the drain — a *level* like |
//! |                           | `sched_weight_epoch`: max over replicas,    |
//! |                           | preserved across drains                     |
//! | `sched_kv_pages_high_water`| lifetime peak of active pages (page-memory |
//! |                           | pressure; above the configured budget =     |
//! |                           | admission overdraw from in-flight growth)   |
//!
//! With more than one engine replica the same row carries
//! `sched_load_imbalance` — the max/min ratio of per-replica decode
//! ticks ([`SchedulerStats::load_imbalance`]
//! (crate::coordinator::SchedulerStats::load_imbalance); 1.0 = perfectly
//! balanced) — plus a per-replica breakdown so striping imbalance is
//! visible at a glance:
//! `sched_e{i}_occupancy`, `sched_e{i}_idle_ticks`,
//! `sched_e{i}_decode_calls`,
//! `sched_e{i}_generated_tokens`, `sched_e{i}_pruned_groups`,
//! `sched_e{i}_weight_epoch`, `sched_e{i}_kv_pages_active` and
//! `sched_e{i}_kv_pages_high_water` for engine index `i` (0-based,
//! submission placement order — `rl::trainer` writes them,
//! `coordinator::service` produces the per-engine stats).  The per-replica
//! page levels are the ground truth the merged `sched_kv_pages_active`
//! max-reduces; per-replica high-water exposes which replica is memory-
//! bound under uneven striping.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One metric row: step index + named values.
#[derive(Clone, Debug, Default)]
pub struct Row {
    pub step: u64,
    pub values: BTreeMap<String, f64>,
    pub tags: BTreeMap<String, String>,
}

impl Row {
    pub fn new(step: u64) -> Row {
        Row { step, ..Default::default() }
    }

    pub fn set(mut self, key: &str, v: f64) -> Row {
        self.values.insert(key.to_string(), v);
        self
    }

    pub fn tag(mut self, key: &str, v: &str) -> Row {
        self.tags.insert(key.to_string(), v.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("step".into(), Json::Num(self.step as f64));
        for (k, v) in &self.values {
            m.insert(k.clone(), Json::Num(*v));
        }
        for (k, v) in &self.tags {
            m.insert(k.clone(), Json::Str(v.clone()));
        }
        Json::Obj(m)
    }
}

/// Appends rows to a `.jsonl` file and keeps them in memory for summaries.
pub struct Recorder {
    path: Option<PathBuf>,
    pub rows: Vec<Row>,
    pub run_name: String,
}

impl Recorder {
    /// Recorder writing under `results/<run_name>.jsonl` (created).
    pub fn create(dir: &Path, run_name: &str) -> Result<Recorder> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {dir:?}"))?;
        let path = dir.join(format!("{run_name}.jsonl"));
        std::fs::write(&path, "").context("truncating metric file")?;
        Ok(Recorder {
            path: Some(path),
            rows: Vec::new(),
            run_name: run_name.to_string(),
        })
    }

    /// In-memory only (unit tests, quick benches).
    pub fn ephemeral(run_name: &str) -> Recorder {
        Recorder { path: None, rows: Vec::new(), run_name: run_name.to_string() }
    }

    pub fn log(&mut self, row: Row) {
        if let Some(path) = &self.path {
            if let Ok(mut f) =
                std::fs::OpenOptions::new().append(true).open(path)
            {
                let _ = writeln!(f, "{}", row.to_json().to_string());
            }
        }
        self.rows.push(row);
    }

    /// Series of one metric over steps (missing rows skipped).
    pub fn series(&self, key: &str) -> Vec<(u64, f64)> {
        self.rows
            .iter()
            .filter_map(|r| r.values.get(key).map(|&v| (r.step, v)))
            .collect()
    }

    /// Series filtered by a tag value.
    pub fn series_where(&self, key: &str, tag: &str, value: &str) -> Vec<(u64, f64)> {
        self.rows
            .iter()
            .filter(|r| r.tags.get(tag).map(|t| t == value).unwrap_or(false))
            .filter_map(|r| r.values.get(key).map(|&v| (r.step, v)))
            .collect()
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.series(key).last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` values of a series (end-of-training estimate).
    pub fn tail_mean(&self, key: &str, k: usize) -> Option<f64> {
        let s = self.series(key);
        if s.is_empty() {
            return None;
        }
        let tail = &s[s.len().saturating_sub(k)..];
        Some(tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    /// Dump selected series as CSV (step,<keys...>) for plotting.
    pub fn write_csv(&self, dir: &Path, keys: &[&str]) -> Result<PathBuf> {
        std::fs::create_dir_all(dir).ok();
        let path = dir.join(format!("{}.csv", self.run_name));
        let mut out = String::from("step");
        for k in keys {
            out.push(',');
            out.push_str(k);
        }
        out.push('\n');
        for r in &self.rows {
            if keys.iter().all(|k| !r.values.contains_key(*k)) {
                continue;
            }
            out.push_str(&r.step.to_string());
            for k in keys {
                out.push(',');
                if let Some(v) = r.values.get(*k) {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        std::fs::write(&path, out).context("writing csv")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_and_tail() {
        let mut r = Recorder::ephemeral("t");
        for i in 0..10 {
            r.log(Row::new(i).set("x", i as f64));
        }
        assert_eq!(r.series("x").len(), 10);
        assert_eq!(r.last("x"), Some(9.0));
        assert!((r.tail_mean("x", 4).unwrap() - 7.5).abs() < 1e-12);
    }

    #[test]
    fn tagged_series() {
        let mut r = Recorder::ephemeral("t");
        r.log(Row::new(0).set("acc", 0.5).tag("mode", "int8"));
        r.log(Row::new(0).set("acc", 0.7).tag("mode", "bf16"));
        let s = r.series_where("acc", "mode", "int8");
        assert_eq!(s, vec![(0, 0.5)]);
    }

    #[test]
    fn jsonl_file_roundtrip() {
        let dir = std::env::temp_dir().join("qurl_rec_test");
        let mut r = Recorder::create(&dir, "run1").unwrap();
        r.log(Row::new(3).set("loss", 1.25).tag("phase", "rl"));
        let text = std::fs::read_to_string(dir.join("run1.jsonl")).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(j.req("step").as_usize(), Some(3));
        assert_eq!(j.req("loss").as_f64(), Some(1.25));
        assert_eq!(j.req("phase").as_str(), Some("rl"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
