//! Public datasheet specs for the GPUs in the paper's Fig. 8.

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gpu {
    A6000,
    A100,
    H100,
}

pub const ALL_GPUS: [Gpu; 3] = [Gpu::A6000, Gpu::A100, Gpu::H100];

#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM/GDDR bandwidth, bytes/s
    pub mem_bw: f64,
    /// dense fp16/bf16 tensor-core peak, FLOP/s
    pub fp16_flops: f64,
    /// dense INT8 tensor-core peak, OP/s
    pub int8_ops: f64,
    /// dense FP8 peak, FLOP/s (0 where unsupported — pre-Hopper)
    pub fp8_flops: f64,
    /// per-step kernel/runtime overhead, seconds (vLLM-like decode launch)
    pub step_overhead: f64,
}

impl Gpu {
    pub fn spec(&self) -> GpuSpec {
        match self {
            // RTX A6000: 768 GB/s GDDR6, 155 TFLOPS fp16 TC, 310 TOPS int8
            Gpu::A6000 => GpuSpec {
                name: "A6000",
                mem_bw: 768e9,
                fp16_flops: 155e12,
                int8_ops: 310e12,
                fp8_flops: 0.0,
                step_overhead: 35e-6,
            },
            // A100-80GB SXM: 2039 GB/s HBM2e, 312 TFLOPS fp16, 624 TOPS int8
            Gpu::A100 => GpuSpec {
                name: "A100",
                mem_bw: 2039e9,
                fp16_flops: 312e12,
                int8_ops: 624e12,
                fp8_flops: 0.0,
                step_overhead: 30e-6,
            },
            // H100 SXM: 3350 GB/s HBM3, 990 TFLOPS fp16, 1979 TOPS int8/fp8
            Gpu::H100 => GpuSpec {
                name: "H100",
                mem_bw: 3350e9,
                fp16_flops: 990e12,
                int8_ops: 1979e12,
                fp8_flops: 1979e12,
                step_overhead: 25e-6,
            },
        }
    }

    pub fn parse(s: &str) -> Option<Gpu> {
        match s.to_ascii_lowercase().as_str() {
            "a6000" => Some(Gpu::A6000),
            "a100" => Some(Gpu::A100),
            "h100" => Some(Gpu::H100),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_ordering_sane() {
        let a6000 = Gpu::A6000.spec();
        let a100 = Gpu::A100.spec();
        let h100 = Gpu::H100.spec();
        assert!(a6000.mem_bw < a100.mem_bw && a100.mem_bw < h100.mem_bw);
        assert!(a6000.fp16_flops < a100.fp16_flops);
        assert!(h100.fp8_flops > 0.0 && a100.fp8_flops == 0.0);
    }
}
