//! GPU decode-throughput roofline simulator — regenerates the paper's
//! Fig. 8 (INT8 rollout acceleration across model sizes and GPUs).
//!
//! The paper measures vLLM + GuideLLM on real A6000/A100/H100 hardware; this
//! testbed has none, so Fig. 8 is reproduced from first principles
//! (DESIGN.md §2): autoregressive decode is modeled as
//!
//! ```text
//! t_step = max(t_mem, t_compute) + t_overhead
//! t_mem  = (weight_bytes + kv_bytes(batch, ctx)) / mem_bw
//! t_comp = 2 * params * batch / peak_flops(precision)
//! ```
//!
//! INT8 halves weight bytes and doubles peak math throughput; the KV cache
//! stays 16-bit (the paper explicitly excludes KV quantization).  The
//! paper's qualitative findings fall out of this model: larger models gain
//! more (weight traffic dominates the un-quantized KV traffic) and
//! higher-end GPUs gain more at large batch (compute roofline lifts).

pub mod gpu;
pub mod roofline;
pub mod sweep;

pub use gpu::{Gpu, GpuSpec, ALL_GPUS};
pub use roofline::{decode_throughput, speedup, DecodeConfig, ModelScale, Precision};
