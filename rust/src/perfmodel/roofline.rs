//! Decode roofline model (see module docs in mod.rs).

use super::gpu::Gpu;

/// Model sizes from the paper's throughput test (DeepSeek-Distill-Qwen).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelScale {
    B7,
    B14,
    B32,
}

pub const ALL_SCALES: [ModelScale; 3] = [ModelScale::B7, ModelScale::B14, ModelScale::B32];

impl ModelScale {
    pub fn name(&self) -> &'static str {
        match self {
            ModelScale::B7 => "7B",
            ModelScale::B14 => "14B",
            ModelScale::B32 => "32B",
        }
    }

    pub fn params(&self) -> f64 {
        match self {
            ModelScale::B7 => 7.0e9,
            ModelScale::B14 => 14.0e9,
            ModelScale::B32 => 32.0e9,
        }
    }

    /// (n_layers, d_model, n_kv_heads * head_dim) — Qwen2.5-style configs,
    /// used to size the KV cache.
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            ModelScale::B7 => (28, 3584, 512),   // 4 KV heads x 128
            ModelScale::B14 => (48, 5120, 1024),
            ModelScale::B32 => (64, 5120, 1024),
        }
    }

    /// Tensor-parallel degree in the paper's setup (32B ran TP=2).
    pub fn tp(&self) -> usize {
        match self {
            ModelScale::B32 => 2,
            _ => 1,
        }
    }

    pub fn parse(s: &str) -> Option<ModelScale> {
        match s.to_ascii_uppercase().as_str() {
            "7B" => Some(ModelScale::B7),
            "14B" => Some(ModelScale::B14),
            "32B" => Some(ModelScale::B32),
            _ => None,
        }
    }
}

/// Rollout precision in the roofline model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Bf16,
    Int8,
    Fp8,
}

impl Precision {
    pub fn weight_bytes_per_param(&self) -> f64 {
        match self {
            Precision::Bf16 => 2.0,
            Precision::Int8 | Precision::Fp8 => 1.0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct DecodeConfig {
    /// concurrent sequences (continuous-batching occupancy)
    pub batch: usize,
    /// mean context length during decode (prompt + generated so far)
    pub ctx: usize,
    /// mean generated tokens per query (sets queries/s from tokens/s)
    pub gen_len: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        // GuideLLM-style serving load: moderate batch, reasoning-length outputs
        DecodeConfig { batch: 64, ctx: 2048, gen_len: 1024 }
    }
}

/// Per-decode-step latency in seconds.
pub fn step_latency(gpu: Gpu, scale: ModelScale, prec: Precision,
                    cfg: &DecodeConfig) -> f64 {
    let spec = gpu.spec();
    let tp = scale.tp() as f64;
    let params = scale.params();
    let (layers, _d, kv_dim) = scale.dims();

    // memory traffic per step, per GPU: all weights once + the KV cache of
    // every active sequence (fp16 K and V per layer), split across TP
    let weight_bytes = params * prec.weight_bytes_per_param() / tp;
    let kv_bytes = cfg.batch as f64
        * layers as f64
        * 2.0            // K and V
        * kv_dim as f64
        * cfg.ctx as f64
        * 2.0            // fp16 (paper excludes KV quantization)
        / tp;
    let t_mem = (weight_bytes + kv_bytes) / spec.mem_bw;

    // compute per step, per GPU: 2 * params MACs per token
    let peak = match prec {
        Precision::Bf16 => spec.fp16_flops,
        Precision::Int8 => spec.int8_ops,
        Precision::Fp8 => {
            if spec.fp8_flops > 0.0 {
                spec.fp8_flops
            } else {
                // pre-Hopper FP8 falls back to fp16 math (weight-only gain)
                spec.fp16_flops
            }
        }
    };
    // GEMMs at decode batch sizes reach only a fraction of peak; vLLM decode
    // kernels land around 40-60% — model with a flat 50% efficiency.
    let t_comp = 2.0 * params * cfg.batch as f64 / tp / (peak * 0.5);

    t_mem.max(t_comp) + spec.step_overhead
}

/// Serving throughput in queries/s (a GuideLLM-style figure of merit).
pub fn decode_throughput(gpu: Gpu, scale: ModelScale, prec: Precision,
                         cfg: &DecodeConfig) -> f64 {
    let t = step_latency(gpu, scale, prec, cfg);
    let tokens_per_s = cfg.batch as f64 / t;
    tokens_per_s / cfg.gen_len as f64
}

/// INT8 (or FP8) speedup over BF16 — the Fig. 8 y-axis.
pub fn speedup(gpu: Gpu, scale: ModelScale, prec: Precision,
               cfg: &DecodeConfig) -> f64 {
    decode_throughput(gpu, scale, prec, cfg)
        / decode_throughput(gpu, scale, Precision::Bf16, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_always_helps() {
        let cfg = DecodeConfig::default();
        for gpu in super::super::ALL_GPUS {
            for scale in ALL_SCALES {
                let s = speedup(gpu, scale, Precision::Int8, &cfg);
                assert!(s > 1.0, "{gpu:?} {scale:?}: {s}");
                assert!(s < 2.05, "{gpu:?} {scale:?}: {s}");
            }
        }
    }

    #[test]
    fn larger_models_gain_more() {
        // the paper's headline qualitative claim (Fig. 8): 7B ~20-30%,
        // 32B ~70-90%
        let cfg = DecodeConfig::default();
        for gpu in super::super::ALL_GPUS {
            let s7 = speedup(gpu, ModelScale::B7, Precision::Int8, &cfg);
            let s32 = speedup(gpu, ModelScale::B32, Precision::Int8, &cfg);
            assert!(s32 > s7, "{gpu:?}: 7B {s7} vs 32B {s32}");
        }
    }

    #[test]
    fn paper_band_rough_match() {
        let cfg = DecodeConfig::default();
        let s7 = speedup(Gpu::A100, ModelScale::B7, Precision::Int8, &cfg);
        let s32 = speedup(Gpu::A100, ModelScale::B32, Precision::Int8, &cfg);
        assert!((1.1..1.6).contains(&s7), "7B A100 speedup {s7}");
        assert!((1.4..2.0).contains(&s32), "32B A100 speedup {s32}");
    }

    #[test]
    fn throughput_positive_and_finite() {
        let cfg = DecodeConfig { batch: 1, ctx: 128, gen_len: 64 };
        let q = decode_throughput(Gpu::A6000, ModelScale::B7, Precision::Bf16, &cfg);
        assert!(q.is_finite() && q > 0.0);
    }
}
