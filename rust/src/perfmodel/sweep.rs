//! Fig. 8 sweep driver: full grids over GPU x scale x precision x batch x
//! context, with CSV export for plotting — the machine-readable counterpart
//! of the `fig8_throughput` bench.

use std::path::Path;

use anyhow::{Context, Result};

use super::gpu::{Gpu, ALL_GPUS};
use super::roofline::{decode_throughput, speedup, DecodeConfig, ModelScale,
                      Precision, ALL_SCALES};

/// One grid point of the Fig. 8 sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub gpu: Gpu,
    pub scale: ModelScale,
    pub precision: Precision,
    pub batch: usize,
    pub ctx: usize,
    pub queries_per_s: f64,
    pub speedup_vs_bf16: f64,
}

/// The paper's grid: {7,14,32}B x {A6000,A100,H100} x {bf16,int8,fp8} at a
/// fixed serving load.
pub fn paper_grid(cfg: &DecodeConfig) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for scale in ALL_SCALES {
        for gpu in ALL_GPUS {
            for precision in [Precision::Bf16, Precision::Int8, Precision::Fp8] {
                out.push(SweepPoint {
                    gpu,
                    scale,
                    precision,
                    batch: cfg.batch,
                    ctx: cfg.ctx,
                    queries_per_s: decode_throughput(gpu, scale, precision, cfg),
                    speedup_vs_bf16: speedup(gpu, scale, precision, cfg),
                });
            }
        }
    }
    out
}

/// Sensitivity grid over batch and context (the "why bigger models gain
/// more" decomposition).
pub fn sensitivity_grid(gpu: Gpu, scale: ModelScale,
                        batches: &[usize], ctxs: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &batch in batches {
        for &ctx in ctxs {
            let cfg = DecodeConfig { batch, ctx, gen_len: 1024 };
            out.push(SweepPoint {
                gpu,
                scale,
                precision: Precision::Int8,
                batch,
                ctx,
                queries_per_s: decode_throughput(gpu, scale, Precision::Int8,
                                                 &cfg),
                speedup_vs_bf16: speedup(gpu, scale, Precision::Int8, &cfg),
            });
        }
    }
    out
}

/// Dump a sweep as CSV (plot-ready).
pub fn write_csv(points: &[SweepPoint], path: &Path) -> Result<()> {
    let mut s = String::from("gpu,model,precision,batch,ctx,queries_per_s,\
                              speedup_vs_bf16\n");
    for p in points {
        s.push_str(&format!("{},{},{:?},{},{},{:.4},{:.4}\n",
                            p.gpu.spec().name, p.scale.name(), p.precision,
                            p.batch, p.ctx, p.queries_per_s,
                            p.speedup_vs_bf16));
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    std::fs::write(path, s).context("writing sweep csv")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_shape() {
        let pts = paper_grid(&DecodeConfig::default());
        assert_eq!(pts.len(), 3 * 3 * 3);
        // bf16 rows must have speedup exactly 1
        for p in pts.iter().filter(|p| p.precision == Precision::Bf16) {
            assert!((p.speedup_vs_bf16 - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn speedup_monotone_in_scale_on_every_gpu() {
        let cfg = DecodeConfig::default();
        for gpu in ALL_GPUS {
            let pts = paper_grid(&cfg);
            let s = |scale| {
                pts.iter()
                    .find(|p| p.gpu == gpu && p.scale == scale
                          && p.precision == Precision::Int8)
                    .unwrap()
                    .speedup_vs_bf16
            };
            assert!(s(ModelScale::B32) > s(ModelScale::B7), "{gpu:?}");
        }
    }

    #[test]
    fn longer_context_erodes_speedup() {
        // the fp16 KV cache is not quantized; more of it means less gain
        let pts = sensitivity_grid(Gpu::A100, ModelScale::B7, &[64],
                                   &[512, 8192]);
        assert!(pts[0].speedup_vs_bf16 > pts[1].speedup_vs_bf16);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("qurl_sweep_test");
        let path = dir.join("grid.csv");
        let pts = paper_grid(&DecodeConfig::default());
        write_csv(&pts, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), pts.len() + 1);
        assert!(text.starts_with("gpu,model,precision"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
