//! The paper's weight-update-vs-quantization-noise analysis (§4.3, Fig. 4,
//! Appendix A Fig. 9).
//!
//! * NormalizedWeightUpdate(t)    = ||θ^{t+1} − θ^t||_F² / ||θ^t||_F²   (Eq. 13)
//! * NormalizedWeightQuantError   = ||Q(θ^t) − θ^t||_F² / ||θ^t||_F²    (Eq. 14)
//! * masked-update fraction: how many section-B weights change their INT8
//!   code between steps — the paper's "quantization masks nearly all weight
//!   updates" observation, measured directly.

use crate::runtime::manifest::Manifest;
use crate::runtime::QuantMode;

use super::{delta, int8};

fn sq_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Eq. 13 over the full flat parameter vector.
pub fn normalized_weight_update(theta_t: &[f32], theta_t1: &[f32]) -> f64 {
    assert_eq!(theta_t.len(), theta_t1.len());
    let num: f64 = theta_t
        .iter()
        .zip(theta_t1)
        .map(|(&a, &b)| {
            let d = (b - a) as f64;
            d * d
        })
        .sum();
    num / sq_norm(theta_t).max(1e-30)
}

/// Dequantized section-B weights under `mode` (identity for Bf16).
///
/// Quantization fans out one tensor per scoped worker thread
/// ([`delta::quant_int8_parallel`]/[`delta::quant_fp8_parallel`]) — this
/// runs per RL step under `analyze_every`, and the per-tensor host quant
/// is embarrassingly parallel.  Bit-identical to the old serial loop for
/// every worker count.
pub fn effective_weights(manifest: &Manifest, flat_b: &[f32],
                         mode: QuantMode) -> Vec<f32> {
    let workers = delta::default_workers(manifest.params.len());
    match mode {
        QuantMode::Bf16 => flat_b.to_vec(),
        QuantMode::Int8 => {
            let (q, s) = delta::quant_int8_parallel(manifest, flat_b, workers);
            let mut out = vec![0.0f32; flat_b.len()];
            for m in delta::mat_layout(manifest) {
                let w = m.w_off..m.w_off + m.numel();
                out[w.clone()].copy_from_slice(&int8::dequant(
                    &q[w], &s[m.s_off..m.s_off + m.n], m.k, m.n));
            }
            out
        }
        QuantMode::Fp8 => delta::quant_fp8_parallel(manifest, flat_b, workers),
    }
}

/// Eq. 14 over section B under the given quantization mode.
pub fn normalized_quant_error(manifest: &Manifest, flat_b: &[f32],
                              mode: QuantMode) -> f64 {
    let deq = effective_weights(manifest, flat_b, mode);
    let num: f64 = flat_b
        .iter()
        .zip(&deq)
        .map(|(&a, &b)| {
            let d = (b - a) as f64;
            d * d
        })
        .sum();
    num / sq_norm(flat_b).max(1e-30)
}

/// Fraction of section-B weights whose INT8 code actually changed between
/// two parameter snapshots — the paper's "update masked by quantization"
/// effect (near 0 without UAQ at small lr; UAQ raises it).
pub fn int8_code_change_fraction(manifest: &Manifest, b_t: &[f32],
                                 b_t1: &[f32]) -> f64 {
    assert_eq!(b_t.len(), b_t1.len());
    let workers = delta::default_workers(manifest.params.len());
    let (q0, _) = delta::quant_int8_parallel(manifest, b_t, workers);
    let (q1, _) = delta::quant_int8_parallel(manifest, b_t1, workers);
    let changed = q0.iter().zip(&q1).filter(|(a, b)| a != b).count();
    changed as f64 / q0.len().max(1) as f64
}

/// Iterate section-B matrices as (name, offset_in_b, K, N).
pub fn for_each_mat(manifest: &Manifest, mut f: impl FnMut(&str, usize, usize, usize)) {
    for p in &manifest.params {
        if p.offset >= manifest.a_size {
            assert_eq!(p.shape.len(), 2, "section B must be matrices");
            f(&p.name, p.offset - manifest.a_size, p.shape[0], p.shape[1]);
        }
    }
}

/// Host-side UAQ mirror (Eq. 11) for tests: W/s on LN-fed matrices, gain*s
/// on the feeding norms.  The runtime path uses the uaq_scale artifact.
pub fn uaq_scale_host(manifest: &Manifest, params: &mut [f32], s: f32) {
    for l in 0..manifest.n_layers {
        for (name, div) in [
            (format!("layer{l}.ln1"), false),
            (format!("layer{l}.qkv"), true),
            (format!("layer{l}.ln2"), false),
            (format!("layer{l}.mlp_up"), true),
        ] {
            let p = manifest.param(&name).expect("manifest param");
            let sl = &mut params[p.offset..p.offset + p.numel()];
            if div {
                sl.iter_mut().for_each(|x| *x /= s);
            } else {
                sl.iter_mut().for_each(|x| *x *= s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_update_scales_quadratically() {
        let a = vec![1.0f32; 100];
        let mut b = a.clone();
        b[0] += 0.1;
        let u1 = normalized_weight_update(&a, &b);
        let mut c = a.clone();
        c[0] += 0.2;
        let u2 = normalized_weight_update(&a, &c);
        assert!((u2 / u1 - 4.0).abs() < 1e-6);
    }

    #[test]
    fn zero_update_is_zero() {
        let a = vec![0.5f32; 10];
        assert_eq!(normalized_weight_update(&a, &a), 0.0);
    }
}
