//! Change-aware ("delta") requantization support.
//!
//! The paper's weight-update analysis (§4.3, Fig. 4/9) shows per-step RL
//! weight deltas are tiny, and per-channel quantization masks most of
//! them: a tensor usually requantizes to a bit-identical `(q, scale)`
//! payload.  This module turns that observation into machinery:
//!
//! * [`mat_layout`] — the per-tensor view of the flat section-B buffers
//!   (each 2-D `params` entry paired with its `qscales` entry by name),
//!   shared by the parallel quantizer and the change accounting;
//! * [`quant_int8_parallel`] / [`quant_fp8_parallel`] — the serial host
//!   quant mirrors ([`int8::weight_quant`], [`fp8::weight_quant`]) fanned
//!   out across `std::thread::scope` workers, one tensor per work item;
//!   results are assembled on the calling thread in layout order, so the
//!   output is bit-identical to the serial mirrors for every worker
//!   count;
//! * [`DeltaReport`] plus the `*_delta` comparators — bitwise per-tensor
//!   change detection between two snapshots (`to_bits` on f32, so the
//!   comparison is representation equality, never float `==`).
//!
//! The engine-facing delta path
//! ([`Runtime::engine_weights_delta`](crate::runtime::Runtime::engine_weights_delta))
//! quantizes through the same XLA artifacts as the full path and uses the
//! comparators here only to DECIDE what changed — so a delta refresh is
//! bit-identical to a full one by construction (the host mirrors are
//! close but not bit-exact vs the fp8 artifact).  The parallel mirrors
//! serve the per-step host analysis (`quant::analysis`) and the
//! fig9/BENCH host-quant timing.

use crate::runtime::manifest::Manifest;

use super::{fp8, int8};

/// One section-B matrix paired with its per-channel scale run: the unit
/// of change detection and of the parallel quant fan-out.
#[derive(Clone, Debug)]
pub struct MatLayout {
    pub name: String,
    /// element offset into the flat section-B weight buffer
    pub w_off: usize,
    pub k: usize,
    pub n: usize,
    /// element offset into the flat per-channel scale buffer (int8 path;
    /// fp8 folds scales back into the fake-quantized payload)
    pub s_off: usize,
}

impl MatLayout {
    pub fn numel(&self) -> usize {
        self.k * self.n
    }
}

/// Pair every section-B `params` matrix with its `qscales` entry by name.
/// The manifest is the single source of layout truth (the runtime never
/// hard-codes model dims), so this is also the iteration order the
/// parallel quantizers and comparators share.
pub fn mat_layout(man: &Manifest) -> Vec<MatLayout> {
    man.params
        .iter()
        .filter(|p| p.offset >= man.a_size)
        .map(|p| {
            assert_eq!(p.shape.len(), 2, "section B must be matrices");
            let s = man
                .qscales
                .iter()
                .find(|s| s.name == p.name)
                .unwrap_or_else(|| panic!("no qscales entry for {}", p.name));
            assert_eq!(s.channels, p.shape[1],
                       "qscales channels != N for {}", p.name);
            MatLayout {
                name: p.name.clone(),
                w_off: p.offset - man.a_size,
                k: p.shape[0],
                n: p.shape[1],
                s_off: s.offset,
            }
        })
        .collect()
}

/// Worker count for the parallel fan-out: one per available core, capped
/// by the number of work items (extra threads would only sit idle).
pub fn default_workers(n_items: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(n_items.max(1))
}

/// Run `f(0..n)` across `workers` scoped threads (item `i` goes to worker
/// `i % workers`) and return the results in item order.  Per-item results
/// are independent, so the output is identical for every worker count —
/// parallelism changes wall-clock, never bits.
fn fan_out<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::new();
    out.resize_with(n, || None);
    std::thread::scope(|sc| {
        let handles: Vec<_> = (0..workers)
            .map(|wi| {
                let f = &f;
                sc.spawn(move || {
                    (wi..n)
                        .step_by(workers)
                        .map(|i| (i, f(i)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("quant worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter()
        .map(|v| v.expect("fan_out worker covered all items"))
        .collect()
}

/// Host INT8 quantization of the flat section-B buffer, one tensor per
/// work item across `workers` scoped threads.  Bit-identical to running
/// [`int8::weight_quant`] per matrix serially.
pub fn quant_int8_parallel(man: &Manifest, flat_b: &[f32], workers: usize)
                           -> (Vec<i8>, Vec<f32>) {
    assert_eq!(flat_b.len(), man.b_size);
    let mats = mat_layout(man);
    let per = fan_out(mats.len(), workers, |i| {
        let m = &mats[i];
        int8::weight_quant(&flat_b[m.w_off..m.w_off + m.numel()], m.k, m.n)
    });
    let mut q = vec![0i8; man.b_size];
    let mut s = vec![0.0f32; man.n_qscales];
    for (m, (qi, si)) in mats.iter().zip(per) {
        q[m.w_off..m.w_off + m.numel()].copy_from_slice(&qi);
        s[m.s_off..m.s_off + m.n].copy_from_slice(&si);
    }
    (q, s)
}

/// Host FP8 fake quantization of the flat section-B buffer, parallel per
/// tensor.  Bit-identical to [`fp8::weight_quant`] per matrix serially.
pub fn quant_fp8_parallel(man: &Manifest, flat_b: &[f32], workers: usize)
                          -> Vec<f32> {
    assert_eq!(flat_b.len(), man.b_size);
    let mats = mat_layout(man);
    let per = fan_out(mats.len(), workers, |i| {
        let m = &mats[i];
        fp8::weight_quant(&flat_b[m.w_off..m.w_off + m.numel()], m.k, m.n)
    });
    let mut out = vec![0.0f32; man.b_size];
    for (m, fq) in mats.iter().zip(per) {
        out[m.w_off..m.w_off + m.numel()].copy_from_slice(&fq);
    }
    out
}

/// Representation equality on f32 buffers: same length and same bits at
/// every position.  Bitwise (`to_bits`), not float `==` — a comparison
/// that drives `Arc` reuse must never conflate `-0.0` with `0.0` or
/// treat NaN as unequal to itself.
pub fn f32_bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Per-tensor outcome of one delta requantization:
/// `tensors_changed + tensors_skipped == manifest.params.len()`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaReport {
    /// manifest tensors whose payload changed and was rebuilt
    pub tensors_changed: usize,
    /// tensors whose quantized payload was bit-identical and was reused
    pub tensors_skipped: usize,
}

impl DeltaReport {
    /// Full-refresh (or no-previous-weights) report: every tensor rebuilt.
    pub fn all_changed(n_tensors: usize) -> DeltaReport {
        DeltaReport { tensors_changed: n_tensors, tensors_skipped: 0 }
    }

    pub fn note(&mut self, changed: bool) {
        if changed {
            self.tensors_changed += 1;
        } else {
            self.tensors_skipped += 1;
        }
    }

    pub fn merge(&mut self, other: DeltaReport) {
        self.tensors_changed += other.tensors_changed;
        self.tensors_skipped += other.tensors_skipped;
    }

    pub fn total(&self) -> usize {
        self.tensors_changed + self.tensors_skipped
    }

    /// Fraction of tensors that actually changed (0.0 on an empty report
    /// — guards the zero-denominator case like the scheduler stats do).
    pub fn changed_fraction(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.tensors_changed as f64 / self.total() as f64
        }
    }
}

/// Change detection over the section-A tensors (raw f32 bits — section A
/// stays full precision in every rollout mode).
pub fn section_a_delta(man: &Manifest, a0: &[f32], a1: &[f32]) -> DeltaReport {
    assert_eq!(a0.len(), man.a_size);
    assert_eq!(a1.len(), man.a_size);
    let mut rep = DeltaReport::default();
    for p in man.params.iter().filter(|p| p.offset < man.a_size) {
        let r = p.offset..p.offset + p.numel();
        rep.note(!f32_bits_eq(&a0[r.clone()], &a1[r]));
    }
    rep
}

/// Change detection over every manifest tensor of two full-precision
/// (Bf16-mode) flat parameter vectors.
pub fn flat_delta(man: &Manifest, f0: &[f32], f1: &[f32]) -> DeltaReport {
    assert_eq!(f0.len(), man.n_params);
    assert_eq!(f1.len(), man.n_params);
    let mut rep = DeltaReport::default();
    for p in &man.params {
        let r = p.offset..p.offset + p.numel();
        rep.note(!f32_bits_eq(&f0[r.clone()], &f1[r]));
    }
    rep
}

/// Change detection over the section-B matrices of two INT8 snapshots: a
/// tensor is unchanged iff BOTH its code block and its per-channel scale
/// run are bit-identical (a scale shift re-means every code, so it must
/// count as a change even when the codes happen to agree).
pub fn int8_delta(man: &Manifest, qw0: &[i8], qs0: &[f32],
                  qw1: &[i8], qs1: &[f32]) -> DeltaReport {
    assert_eq!(qw0.len(), man.b_size);
    assert_eq!(qw1.len(), man.b_size);
    assert_eq!(qs0.len(), man.n_qscales);
    assert_eq!(qs1.len(), man.n_qscales);
    let mut rep = DeltaReport::default();
    for m in mat_layout(man) {
        let w = m.w_off..m.w_off + m.numel();
        let s = m.s_off..m.s_off + m.n;
        rep.note(qw0[w.clone()] != qw1[w]
                 || !f32_bits_eq(&qs0[s.clone()], &qs1[s]));
    }
    rep
}

/// Change detection over the section-B matrices of two FP8 fake-quantized
/// snapshots (scales are folded into the payload, so one bitwise compare
/// per tensor covers both).
pub fn fp8_delta(man: &Manifest, fq0: &[f32], fq1: &[f32]) -> DeltaReport {
    assert_eq!(fq0.len(), man.b_size);
    assert_eq!(fq1.len(), man.b_size);
    let mut rep = DeltaReport::default();
    for m in mat_layout(man) {
        let r = m.w_off..m.w_off + m.numel();
        rep.note(!f32_bits_eq(&fq0[r.clone()], &fq1[r]));
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{FlagIndex, ParamEntry, ScaleEntry};
    use crate::util::rng::Pcg64;

    /// Synthetic manifest: section A = one [4] vector, section B = a
    /// [2,3] and a [3,2] matrix (qscales deliberately listed out of
    /// params order to exercise the by-name pairing).
    fn toy_manifest() -> Manifest {
        Manifest {
            vocab_size: 8,
            d_model: 2,
            n_heads: 1,
            n_layers: 1,
            d_ff: 3,
            head_dim: 2,
            max_seq: 8,
            max_prompt: 2,
            max_new: 2,
            rollout_batch: 1,
            train_batch: 1,
            a_size: 4,
            b_size: 12,
            n_params: 16,
            n_qscales: 5,
            params: vec![
                ParamEntry { name: "emb".into(), shape: vec![4], offset: 0 },
                ParamEntry { name: "w1".into(), shape: vec![2, 3], offset: 4 },
                ParamEntry { name: "w2".into(), shape: vec![3, 2], offset: 10 },
            ],
            qscales: vec![
                ScaleEntry { name: "w2".into(), offset: 3, channels: 2 },
                ScaleEntry { name: "w1".into(), offset: 0, channels: 3 },
            ],
            pad_id: 0,
            bos_id: 1,
            eos_id: 2,
            flags: FlagIndex {
                obj_mode: 0, eps_low: 1, eps_high: 2, tis_cap: 3,
                kl_coef: 4, vf_coef: 5, ent_coef: 6, token_mean: 7,
                lr: 8, beta1: 9, beta2: 10, adam_eps: 11,
                weight_decay: 12, value_clip: 13, max_grad_norm: 14,
                n: 15,
            },
            metric_names: vec![],
            artifacts: std::collections::BTreeMap::new(),
        }
    }

    fn rand_b(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..len).map(|_| rng.normal() as f32 * 0.05).collect()
    }

    #[test]
    fn mat_layout_pairs_scales_by_name() {
        let man = toy_manifest();
        let mats = mat_layout(&man);
        assert_eq!(mats.len(), 2);
        assert_eq!((mats[0].name.as_str(), mats[0].w_off, mats[0].s_off),
                   ("w1", 0, 0));
        assert_eq!((mats[1].name.as_str(), mats[1].w_off, mats[1].s_off),
                   ("w2", 6, 3));
    }

    #[test]
    fn parallel_quant_bit_identical_to_serial_for_any_worker_count() {
        let man = toy_manifest();
        let b = rand_b(11, man.b_size);
        // serial reference, assembled per mat
        let mut q_ref = vec![0i8; man.b_size];
        let mut s_ref = vec![0.0f32; man.n_qscales];
        for m in mat_layout(&man) {
            let (q, s) =
                int8::weight_quant(&b[m.w_off..m.w_off + m.numel()], m.k, m.n);
            q_ref[m.w_off..m.w_off + m.numel()].copy_from_slice(&q);
            s_ref[m.s_off..m.s_off + m.n].copy_from_slice(&s);
        }
        let mut fq_ref = vec![0.0f32; man.b_size];
        for m in mat_layout(&man) {
            fq_ref[m.w_off..m.w_off + m.numel()].copy_from_slice(
                &fp8::weight_quant(&b[m.w_off..m.w_off + m.numel()], m.k, m.n));
        }
        for workers in [1, 2, 3, 8] {
            let (q, s) = quant_int8_parallel(&man, &b, workers);
            assert_eq!(q, q_ref, "int8 codes drifted at workers={workers}");
            assert!(f32_bits_eq(&s, &s_ref),
                    "int8 scales drifted at workers={workers}");
            let fq = quant_fp8_parallel(&man, &b, workers);
            assert!(f32_bits_eq(&fq, &fq_ref),
                    "fp8 payload drifted at workers={workers}");
        }
    }

    #[test]
    fn change_detection_counts_moved_and_masked_tensors() {
        let man = toy_manifest();
        let b0 = rand_b(22, man.b_size);
        let mut b1 = b0.clone();
        b1[6] += 1.0; // first element of w2 — big enough to change its code
        let (qw0, qs0) = quant_int8_parallel(&man, &b0, 2);
        let (qw1, qs1) = quant_int8_parallel(&man, &b1, 2);
        let rep = int8_delta(&man, &qw0, &qs0, &qw1, &qs1);
        assert_eq!(rep, DeltaReport { tensors_changed: 1, tensors_skipped: 1 });
        let fq0 = quant_fp8_parallel(&man, &b0, 2);
        let fq1 = quant_fp8_parallel(&man, &b1, 2);
        assert_eq!(fp8_delta(&man, &fq0, &fq1),
                   DeltaReport { tensors_changed: 1, tensors_skipped: 1 });
        // zero-change snapshots skip everything
        let none = int8_delta(&man, &qw0, &qs0, &qw0, &qs0);
        assert_eq!(none, DeltaReport { tensors_changed: 0, tensors_skipped: 2 });
        assert_eq!(none.changed_fraction(), 0.0);
    }

    /// The paper's premise, measured on the detection path: a sub-step
    /// update (smaller than half a quant step, away from the per-channel
    /// absmax) requantizes bit-identically — fully masked.
    #[test]
    fn tiny_updates_are_fully_masked() {
        let man = toy_manifest();
        // Exact fp arithmetic: step = 2^-7, channel absmax = 127 * step
        // (last row), every other element an exact non-tie multiple of
        // step — so codes and scales are reproducible bit-for-bit.
        let step = 2.0_f32.powi(-7);
        let mut b0 = vec![0.0f32; man.b_size];
        for m in mat_layout(&man) {
            for r in 0..m.k {
                for c in 0..m.n {
                    let mult =
                        if r == m.k - 1 { 127.0 } else { 10.0 + r as f32 };
                    b0[m.w_off + r * m.n + c] = mult * step;
                }
            }
        }
        let (qw0, qs0) = quant_int8_parallel(&man, &b0, 1);
        // nudge a non-absmax element of each mat by a tenth of its step
        let mut b1 = b0.clone();
        for m in mat_layout(&man) {
            b1[m.w_off] += 0.1 * qs0[m.s_off];
        }
        let (qw1, qs1) = quant_int8_parallel(&man, &b1, 1);
        let rep = int8_delta(&man, &qw0, &qs0, &qw1, &qs1);
        assert_eq!(rep.tensors_changed, 0,
                   "sub-step update must be masked by quantization");
        assert_eq!(rep.tensors_skipped, 2);
    }

    #[test]
    fn section_and_flat_deltas_compare_bits_not_floats() {
        let man = toy_manifest();
        let a0 = vec![0.0f32, 1.0, 2.0, 3.0];
        let mut a1 = a0.clone();
        a1[0] = -0.0; // 0.0 == -0.0 as floats, different bits
        let rep = section_a_delta(&man, &a0, &a1);
        assert_eq!(rep, DeltaReport { tensors_changed: 1, tensors_skipped: 0 });
        let f0 = rand_b(33, man.n_params);
        let mut f1 = f0.clone();
        f1[5] += 1.0; // inside w1
        let rep = flat_delta(&man, &f0, &f1);
        assert_eq!(rep, DeltaReport { tensors_changed: 1, tensors_skipped: 2 });
        assert!((rep.changed_fraction() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn report_merge_and_all_changed() {
        let mut a = DeltaReport { tensors_changed: 1, tensors_skipped: 4 };
        a.merge(DeltaReport { tensors_changed: 2, tensors_skipped: 0 });
        assert_eq!(a, DeltaReport { tensors_changed: 3, tensors_skipped: 4 });
        assert_eq!(a.total(), 7);
        let full = DeltaReport::all_changed(9);
        assert_eq!((full.tensors_changed, full.tensors_skipped), (9, 0));
        assert_eq!(full.changed_fraction(), 1.0);
        assert_eq!(DeltaReport::default().changed_fraction(), 0.0);
    }
}
