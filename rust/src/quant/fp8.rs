//! Rust mirror of the e4m3fn fake quantizer (kernels/ref.py::quant_e4m3).
//!
//! Bit-level emulation: RNE onto the 3-mantissa-bit grid, exponent range
//! [-6, 8], subnormal quantum 2^-9, saturation at +-448 (e4m3fn has no inf).

pub const E4M3_MAX: f32 = 448.0;
pub const SCALE_EPS: f32 = 1e-8;

use super::int8::rne;

/// Round one value onto the e4m3fn grid.
pub fn quant_e4m3(x: f32) -> f32 {
    if x == 0.0 || x.is_nan() {
        return 0.0;
    }
    let a = x.abs();
    let mut e = a.log2().floor();
    e = e.clamp(-6.0, 8.0);
    let step = (e - 3.0).exp2();
    let q = rne(x / step) * step;
    q.clamp(-E4M3_MAX, E4M3_MAX)
}

/// Per-output-channel scaled e4m3 fake quantization of [K, N] (row-major),
/// matching ref.weight_quant_fp8 (scale folded back in).
pub fn weight_quant(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let mut absmax = vec![0.0f32; n];
    for row in w.chunks_exact(n) {
        for (j, &x) in row.iter().enumerate() {
            absmax[j] = absmax[j].max(x.abs());
        }
    }
    let scale: Vec<f32> = absmax
        .iter()
        .map(|&a| a.max(SCALE_EPS) / E4M3_MAX)
        .collect();
    // row-wise zip against the [N] scales, no per-element `i % n`
    let mut out = Vec::with_capacity(k * n);
    for row in w.chunks_exact(n) {
        out.extend(row.iter().zip(&scale).map(|(&x, &s)| quant_e4m3(x / s) * s));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn representable_values_fixed() {
        // exact e4m3 values stay fixed
        for v in [1.0f32, 1.125, 0.875, 448.0, -448.0, 2.0_f32.powi(-9),
                  2.0_f32.powi(-6), 240.0] {
            assert_eq!(quant_e4m3(v), v, "{v}");
        }
    }

    #[test]
    fn saturates_not_inf() {
        assert_eq!(quant_e4m3(1e6), 448.0);
        assert_eq!(quant_e4m3(-1e6), -448.0);
        assert_eq!(quant_e4m3(460.0), 448.0);
    }

    #[test]
    fn subnormal_quantum() {
        let q = 2.0_f32.powi(-9);
        // halfway between 0 and the smallest subnormal rounds to even (0)
        assert_eq!(quant_e4m3(q * 0.5), 0.0);
        assert_eq!(quant_e4m3(q * 0.75), q);
        assert_eq!(quant_e4m3(q * 1.4), q);
        assert_eq!(quant_e4m3(q * 1.6), 2.0 * q);
    }

    #[test]
    fn relative_error_bounded() {
        // normal range: relative error <= 2^-4 (half of 3-bit mantissa ulp)
        let mut x = 0.07f32;
        while x < 400.0 {
            let q = quant_e4m3(x);
            assert!(((q - x) / x).abs() <= 1.0 / 16.0 + 1e-6, "{x} -> {q}");
            x *= 1.37;
        }
    }

    #[test]
    fn weight_quant_idempotent() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(5);
        let (k, n) = (8, 4);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.1).collect();
        let q1 = weight_quant(&w, k, n);
        let q2 = weight_quant(&q1, k, n);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
