//! Rust mirror of the INT8 quantizer (kernels/ref.py::weight_quant_int8).
//!
//! The rollout engine quantizes through the `quantize_int8` artifact (so the
//! request path stays on XLA); this mirror exists for (a) the weight-update
//! vs quantization-noise analysis of Fig. 4/9, which runs per RL step on the
//! host, and (b) cross-checking the artifact bit-for-bit in tests.

pub const QMAX: f32 = 127.0;
pub const SCALE_EPS: f32 = 1e-8;

/// Round half to even (matches jnp.round / XLA round_nearest_even).
#[inline]
pub fn rne(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbor
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// Per-output-channel symmetric quantization of a [K, N] matrix (row-major).
/// Returns (q: len K*N, scale: len N).
pub fn weight_quant(w: &[f32], k: usize, n: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), k * n);
    let mut scale = vec![0.0f32; n];
    for row in w.chunks_exact(n) {
        for (j, &x) in row.iter().enumerate() {
            scale[j] = scale[j].max(x.abs());
        }
    }
    for s in scale.iter_mut() {
        *s = s.max(SCALE_EPS) / QMAX;
    }
    let mut q = vec![0i8; k * n];
    // row-wise: one pass per [N] row keeps the scale index a plain zip
    // instead of a per-element `i % n` division — this loop runs per RL
    // step in the fig4/fig9 host analysis
    for (qrow, wrow) in q.chunks_exact_mut(n).zip(w.chunks_exact(n)) {
        for ((qv, &x), &s) in qrow.iter_mut().zip(wrow).zip(&scale) {
            *qv = rne(x / s).clamp(-QMAX, QMAX) as i8;
        }
    }
    (q, scale)
}

/// Dequantize back to f32 (the effective rollout weights).
pub fn dequant(q: &[i8], scale: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(q.len(), k * n);
    assert_eq!(scale.len(), n);
    let mut out = Vec::with_capacity(k * n);
    for row in q.chunks_exact(n) {
        out.extend(row.iter().zip(scale).map(|(&v, &s)| v as f32 * s));
    }
    out
}

/// Token-wise symmetric activation quantization of [M, K] (for tests of the
/// Pallas kernel semantics).
pub fn act_quant(x: &[f32], m: usize, kk: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len(), m * kk);
    let mut q = vec![0i8; m * kk];
    let mut scale = vec![0.0f32; m];
    for (r, row) in x.chunks_exact(kk).enumerate() {
        let absmax = row.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let s = absmax.max(SCALE_EPS) / QMAX;
        scale[r] = s;
        for (j, &v) in row.iter().enumerate() {
            q[r * kk + j] = rne(v / s).clamp(-QMAX, QMAX) as i8;
        }
    }
    (q, scale)
}

/// Reference W8A8 matmul in integer arithmetic (i32 accumulate).
pub fn matmul(x: &[f32], wq: &[i8], wscale: &[f32], m: usize, k: usize,
              n: usize) -> Vec<f32> {
    let (xq, ascale) = act_quant(x, m, k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc: i32 = 0;
            for l in 0..k {
                acc += xq[i * k + l] as i32 * wq[l * n + j] as i32;
            }
            out[i * n + j] = acc as f32 * ascale[i] * wscale[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.05).collect()
    }

    #[test]
    fn rne_ties_to_even() {
        assert_eq!(rne(0.5), 0.0);
        assert_eq!(rne(1.5), 2.0);
        assert_eq!(rne(2.5), 2.0);
        assert_eq!(rne(-0.5), 0.0);
        assert_eq!(rne(-1.5), -2.0);
        assert_eq!(rne(1.4), 1.0);
        assert_eq!(rne(-1.6), -2.0);
    }

    #[test]
    fn quant_bounds_and_scale() {
        let mut rng = Pcg64::new(1);
        let (k, n) = (16, 8);
        let w = rand_mat(&mut rng, k * n);
        let (q, s) = weight_quant(&w, k, n);
        for &v in &q {
            assert!((-127..=127).contains(&(v as i32)));
        }
        // per-channel max maps to +-127
        for j in 0..n {
            let col_max = (0..k).map(|i| w[i * n + j].abs()).fold(0.0f32, f32::max);
            assert!((s[j] - col_max / QMAX).abs() < 1e-9);
        }
    }

    #[test]
    fn dequant_error_within_half_step() {
        let mut rng = Pcg64::new(2);
        let (k, n) = (32, 16);
        let w = rand_mat(&mut rng, k * n);
        let (q, s) = weight_quant(&w, k, n);
        let wd = dequant(&q, &s, k, n);
        for i in 0..w.len() {
            let step = s[i % n];
            assert!((w[i] - wd[i]).abs() <= 0.5 * step + 1e-9);
        }
    }

    #[test]
    fn matmul_close_to_f32() {
        let mut rng = Pcg64::new(3);
        let (m, k, n) = (4, 32, 8);
        let x = rand_mat(&mut rng, m * k);
        let w = rand_mat(&mut rng, k * n);
        let (q, s) = weight_quant(&w, k, n);
        let yq = matmul(&x, &q, &s, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..k {
                    acc += x[i * k + l] as f64 * w[l * n + j] as f64;
                }
                let err = (yq[i * n + j] as f64 - acc).abs();
                assert!(err < 0.02, "err {err} at ({i},{j})");
            }
        }
    }
}
