//! Quantization support: Rust mirrors of the L1 quantizers (bit-exact vs
//! kernels/ref.py), the UAQ driver, and the weight-update analysis behind
//! the paper's Fig. 4 / Fig. 9.

pub mod analysis;
pub mod fp8;
pub mod int8;
