//! Quantization support: Rust mirrors of the L1 quantizers (bit-exact vs
//! kernels/ref.py), the UAQ driver, the weight-update analysis behind
//! the paper's Fig. 4 / Fig. 9, and the change-aware delta-requantization
//! layer (per-tensor change detection + parallel per-tensor host quant).

pub mod analysis;
pub mod delta;
pub mod fp8;
pub mod int8;

pub use delta::DeltaReport;
