//! Advantage estimation: GRPO group normalization, RLOO, and GAE (PPO).
//!
//! Rewards here are RLVR-style: one scalar per sequence, granted at the
//! final generated token.  Advantages are broadcast per-token (GRPO/RLOO) or
//! computed per-token from values (GAE).

use crate::util::stats;

/// GRPO (Eq. 1 context): A_i = (r_i - mean(group)) / (std(group) + eps),
/// identical for every token of sequence i.  `group_size` consecutive
/// sequences share a prompt.
pub fn grpo(rewards: &[f32], group_size: usize) -> Vec<f32> {
    assert!(group_size > 0 && rewards.len() % group_size == 0,
            "rewards {} not divisible by group {group_size}", rewards.len());
    let mut adv = vec![0.0f32; rewards.len()];
    for (g, chunk) in rewards.chunks_exact(group_size).enumerate() {
        let xs: Vec<f64> = chunk.iter().map(|&r| r as f64).collect();
        let m = stats::mean(&xs);
        let s = stats::std_pop(&xs);
        for (i, &r) in chunk.iter().enumerate() {
            adv[g * group_size + i] = ((r as f64 - m) / (s + 1e-4)) as f32;
        }
    }
    adv
}

/// GRPO over an explicit group labeling: sequence `i` belongs to group
/// `groups[i]`, and each maximal contiguous run of equal labels is
/// normalized independently (runs are how the trainer lays groups out).
///
/// This is the shape-robust form the trainer uses when a minibatch is NOT
/// an exact multiple of `group_size` — the old fallback treated such
/// batches as singleton groups, whose advantages are identically zero
/// (r - mean(r) == 0), silently dropping the whole chunk's learning
/// signal.  Here a ragged tail group still normalizes over its actual
/// members; only true singletons degenerate to zero.
pub fn grpo_by_group(rewards: &[f32], groups: &[usize]) -> Vec<f32> {
    assert_eq!(rewards.len(), groups.len(),
               "rewards/groups length mismatch");
    let mut adv = vec![0.0f32; rewards.len()];
    let mut start = 0usize;
    while start < rewards.len() {
        let mut end = start + 1;
        while end < rewards.len() && groups[end] == groups[start] {
            end += 1;
        }
        let xs: Vec<f64> = rewards[start..end].iter().map(|&r| r as f64).collect();
        let m = stats::mean(&xs);
        let s = stats::std_pop(&xs);
        for i in start..end {
            adv[i] = ((rewards[i] as f64 - m) / (s + 1e-4)) as f32;
        }
        start = end;
    }
    adv
}

/// RLOO: leave-one-out baseline, no std normalization.
pub fn rloo(rewards: &[f32], group_size: usize) -> Vec<f32> {
    assert!(group_size > 1 && rewards.len() % group_size == 0);
    let mut adv = vec![0.0f32; rewards.len()];
    for (g, chunk) in rewards.chunks_exact(group_size).enumerate() {
        let sum: f64 = chunk.iter().map(|&r| r as f64).sum();
        for (i, &r) in chunk.iter().enumerate() {
            let baseline = (sum - r as f64) / (group_size - 1) as f64;
            adv[g * group_size + i] = (r as f64 - baseline) as f32;
        }
    }
    adv
}

/// Per-sequence GAE over the generated span (terminal-only reward).
///
/// `values[t]` is V(state before emitting token t) for t in the generated
/// span (as produced by the logprob artifact); the sequence reward lands on
/// the last generated token.  Returns (advantages, returns) aligned with
/// `values`.
pub fn gae(values: &[f32], reward: f32, gamma: f32, lam: f32)
           -> (Vec<f32>, Vec<f32>) {
    let n = values.len();
    let mut adv = vec![0.0f32; n];
    let mut ret = vec![0.0f32; n];
    if n == 0 {
        return (adv, ret);
    }
    let mut last_gae = 0.0f32;
    for t in (0..n).rev() {
        let next_v = if t + 1 < n { values[t + 1] } else { 0.0 };
        let r_t = if t + 1 == n { reward } else { 0.0 };
        let delta = r_t + gamma * next_v - values[t];
        last_gae = delta + gamma * lam * last_gae;
        adv[t] = last_gae;
        ret[t] = adv[t] + values[t];
    }
    (adv, ret)
}

/// Broadcast per-sequence advantages onto [B, T] token grids using the
/// generation mask.  Returns (adv_grid, returns_grid) where returns carry
/// the discounted-to-go reward for value regression when `use_gae` is off.
pub fn broadcast_sequence_adv(adv_seq: &[f32], rewards: &[f32], mask: &[f32],
                              b: usize, t: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(adv_seq.len(), b);
    assert_eq!(mask.len(), b * t);
    let mut adv = vec![0.0f32; b * t];
    let mut ret = vec![0.0f32; b * t];
    for r in 0..b {
        for c in 0..t {
            let i = r * t + c;
            if mask[i] > 0.5 {
                adv[i] = adv_seq[r];
                ret[i] = rewards[r]; // undiscounted terminal reward-to-go
            }
        }
    }
    (adv, ret)
}

/// Whiten advantages over masked tokens (PPO standard practice).
pub fn whiten(adv: &mut [f32], mask: &[f32]) {
    let vals: Vec<f64> = adv
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m > 0.5)
        .map(|(&a, _)| a as f64)
        .collect();
    if vals.len() < 2 {
        return;
    }
    let m = stats::mean(&vals);
    let s = stats::std_pop(&vals).max(1e-6);
    for (a, &mk) in adv.iter_mut().zip(mask) {
        if mk > 0.5 {
            *a = ((*a as f64 - m) / s) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grpo_zero_mean_per_group() {
        let rewards = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let adv = grpo(&rewards, 4);
        let g0: f32 = adv[..4].iter().sum();
        let g1: f32 = adv[4..].iter().sum();
        assert!(g0.abs() < 1e-5 && g1.abs() < 1e-5);
        // correct answers get positive advantage
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
    }

    #[test]
    fn grpo_uniform_group_is_zeroish() {
        let adv = grpo(&[1.0, 1.0, 1.0, 1.0], 4);
        for a in adv {
            assert!(a.abs() < 1e-3);
        }
    }

    #[test]
    fn grpo_by_group_matches_uniform_grouping() {
        let rewards = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let groups = [0, 0, 0, 0, 1, 1, 1, 1];
        assert_eq!(grpo_by_group(&rewards, &groups), grpo(&rewards, 4));
    }

    /// Regression for the `padded_g = 1` bug: a ragged tail (here 2 full
    /// groups of 4 plus a final group of 2 — sample count 10, not a
    /// multiple of 4) must still get a nonzero learning signal on the tail.
    /// The old modulo fallback normalized every sequence as its own
    /// singleton group, which makes ALL advantages identically zero.
    #[test]
    fn grpo_by_group_ragged_tail_nonzero() {
        let rewards = [1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, /* tail: */ 1.0, 0.0];
        let groups = [0, 0, 0, 0, 1, 1, 1, 1, 2, 2];
        let adv = grpo_by_group(&rewards, &groups);
        // tail group normalizes over its two actual members
        assert!(adv[8] > 0.5, "tail winner advantage {}", adv[8]);
        assert!(adv[9] < -0.5, "tail loser advantage {}", adv[9]);
        assert!((adv[8] + adv[9]).abs() < 1e-5, "tail zero-mean");
        // full groups are unaffected by the ragged tail
        assert_eq!(adv[..8], grpo(&rewards[..8], 4)[..]);
        // true singleton still degenerates to zero (no intra-group signal)
        let single = grpo_by_group(&[0.7], &[5]);
        assert!(single[0].abs() < 1e-6);
    }

    #[test]
    fn rloo_baseline() {
        let adv = rloo(&[1.0, 0.0], 2);
        assert!((adv[0] - 1.0).abs() < 1e-6);
        assert!((adv[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_telescopes_at_lambda_one() {
        // lambda=1, gamma=1: adv[t] = reward - values[t]
        let values = [0.3f32, 0.5, 0.1];
        let (adv, ret) = gae(&values, 1.0, 1.0, 1.0);
        for t in 0..3 {
            assert!((adv[t] - (1.0 - values[t])).abs() < 1e-5);
            assert!((ret[t] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gae_zero_reward_zero_values() {
        let (adv, ret) = gae(&[0.0; 5], 0.0, 1.0, 0.95);
        assert!(adv.iter().all(|&a| a.abs() < 1e-6));
        assert!(ret.iter().all(|&r| r.abs() < 1e-6));
    }

    #[test]
    fn broadcast_respects_mask() {
        let mask = [0., 1., 1., 0., 0., 0., 1., 0.];
        let (adv, ret) = broadcast_sequence_adv(&[2.0, -1.0], &[1.0, 0.0],
                                                &mask, 2, 4);
        assert_eq!(adv, vec![0., 2., 2., 0., 0., 0., -1., 0.]);
        assert_eq!(ret, vec![0., 1., 1., 0., 0., 0., 0., 0.]);
    }

    #[test]
    fn whiten_masked_stats() {
        let mut adv = vec![1.0, 2.0, 3.0, 100.0];
        let mask = vec![1.0, 1.0, 1.0, 0.0];
        whiten(&mut adv, &mask);
        let m: f32 = adv[..3].iter().sum::<f32>() / 3.0;
        assert!(m.abs() < 1e-5);
        assert_eq!(adv[3], 100.0); // untouched outside mask
    }
}
