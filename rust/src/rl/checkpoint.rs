//! Crash-safe checkpoint/resume: atomic versioned run snapshots with a
//! bit-identical deterministic-resume guarantee (ROADMAP item 3).
//!
//! # Snapshot layout
//!
//! One checkpoint is one directory under `--ckpt-dir`:
//!
//! ```text
//! ckpts/
//!   step_000004/
//!     manifest.json       versioned metadata + per-payload checksums
//!     params.bin          ParamStore (weights + Adam moments + step) — V2
//!     ref_params.bin      frozen KL reference policy        (vec payload)
//!     engine_params.bin   params the engine was last quantized from
//!     prev_params.bin     Fig. 9 analysis snapshot           (optional)
//!   step_000006/
//!     ...
//! ```
//!
//! The manifest captures everything the run's determinism depends on that
//! is not already in a payload: the trainer's [`Pcg64`] stream position
//! (`rng_state`/`rng_inc` — see [`Pcg64::snapshot`]), the rollout seed
//! cursor, the requant cadence position (`engine_age`; the requant
//! level/mode rides in the embedded config), the
//! [`DynamicSampler`](super::dapo::DynamicSampler) counters, the
//! [`Schedule`](super::schedule::Schedule) stage table, the
//! [`ServiceSnapshot`] (uid allocators, placement cursor and estimates,
//! [`WeightEpoch`](crate::coordinator::WeightEpoch), the full placement
//! log), the full `TrainerConfig` JSON, and a config fingerprint that
//! refuses resume under a silently-changed config
//! ([`check_config`] names the differing field; the `--ckpt-*`/`--resume`
//! control knobs themselves are excluded, since those legitimately differ
//! between the original and the resuming invocation).
//!
//! **RNG audit** (what makes the captured set complete): the trainer owns
//! exactly one long-lived stream, `Trainer::rng` (engine-noise draws) —
//! captured here.  Every rollout stream is *derived, not stored*: member
//! streams come from [`member_seed`](crate::util::rng::member_seed) applied
//! to the `GroupSpec` seed, which the trainer computes from the
//! `rollout_seed` cursor — captured here.  Problem samplers are re-seeded
//! per step from `cfg.seed` and the step number — derived.  `Pcg64::fork`
//! is not used on any rollout path.  So no RNG consumed during rollout
//! lives outside this manifest.
//!
//! # Crash-safety protocol
//!
//! Payloads are staged into a `.tmp_step_NNNNNN` sibling directory, each
//! written via temp-file + fsync + rename ([`ParamStore::save`] and the
//! vec payload codec share the protocol), the manifest is written last,
//! the staging directory is fsynced, and one atomic directory rename
//! publishes the checkpoint.  A crash at any point leaves either the
//! previous checkpoints untouched plus a `.tmp_*` straggler (garbage
//! collected on the next save) — never a torn `step_*` directory.
//! On load, [`latest_good`] walks checkpoints newest-first, re-verifying
//! every payload checksum, and falls back past corrupted snapshots; an
//! unknown `format_version` is a typed refusal
//! ([`CheckpointError::UnknownVersion`]), not a silent fallback — a newer
//! format means *this binary* is the wrong reader, not that the data is
//! bad.  Retention ([`gc`], `--ckpt-keep K`) keeps the newest K *good*
//! checkpoints and never deletes the newest good one.
//!
//! # What is NOT captured, and why that is sound
//!
//! * Per-step scheduler stats, the service wall clock, and Recorder rows —
//!   drained/emitted at every step boundary; checkpoints are taken right
//!   after a drain, so they are empty by construction.
//! * Engine-internal KV/slot state — empty between runs (every group
//!   resolves before `take_stats` is legal).
//! * `DynamicSampler` waves in progress — the trainer constructs its
//!   sampler fresh inside each step; at a boundary the counters are zero
//!   (the manifest still carries them for forward-compatibility).
//! * Prune policy — pure configuration, re-derived from the fingerprinted
//!   config.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::ServiceSnapshot;
use crate::runtime::ParamStore;
use crate::util::hash::{fnv1a64, fnv1a64_continue, FNV_OFFSET};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Manifest format version this binary writes and reads.
pub const FORMAT_VERSION: u64 = 1;

/// Config keys excluded from the resume fingerprint: the checkpoint
/// control knobs legitimately differ between the original invocation and
/// the one resuming it (`--resume` itself, most obviously).
pub const CKPT_CONTROL_KEYS: [&str; 4] =
    ["ckpt_every", "ckpt_dir", "ckpt_keep", "resume"];

const MANIFEST_FILE: &str = "manifest.json";
const VEC_MAGIC: &[u8; 8] = b"QURLVEC1";

/// Typed checkpoint failures — every failure path on the resume road is
/// one of these (the PR-8 panic wall applies to this module; nothing here
/// panics on bad input).
#[derive(Debug)]
pub enum CheckpointError {
    /// manifest declares a format this binary does not understand
    UnknownVersion { path: PathBuf, found: u64 },
    /// a payload's bytes do not hash to the manifest's checksum
    ChecksumMismatch {
        path: PathBuf,
        file: String,
        stored: u64,
        computed: u64,
    },
    /// manifest (or payload header) failed to parse
    Malformed { path: PathBuf, detail: String },
    /// a payload file named by the manifest is missing or unreadable
    MissingPayload { path: PathBuf, file: String },
    /// the resumed config differs from the checkpointed one
    ConfigMismatch {
        field: String,
        saved: String,
        current: String,
    },
    /// no good checkpoint exists under the directory
    NoCheckpoint { dir: PathBuf },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::UnknownVersion { path, found } => write!(
                f,
                "checkpoint {path:?} has manifest format_version {found}; \
                 this build reads version {FORMAT_VERSION} — refusing \
                 (was the checkpoint written by a newer build?)"
            ),
            CheckpointError::ChecksumMismatch {
                path,
                file,
                stored,
                computed,
            } => write!(
                f,
                "checkpoint {path:?}: payload {file:?} checksum mismatch \
                 (stored {stored:#018x}, computed {computed:#018x}) — torn \
                 or corrupted snapshot"
            ),
            CheckpointError::Malformed { path, detail } => {
                write!(f, "checkpoint {path:?}: malformed manifest: {detail}")
            }
            CheckpointError::MissingPayload { path, file } => write!(
                f,
                "checkpoint {path:?}: payload {file:?} missing or unreadable"
            ),
            CheckpointError::ConfigMismatch {
                field,
                saved,
                current,
            } => write!(
                f,
                "resume refused: config field {field:?} changed since the \
                 checkpoint (saved {saved}, current {current}); resume with \
                 the original config or start a fresh run"
            ),
            CheckpointError::NoCheckpoint { dir } => {
                write!(f, "no good checkpoint found under {dir:?}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Versioned checkpoint metadata (`manifest.json`).  Every field here
/// must appear in BOTH [`CheckpointManifest::to_json`] and
/// [`CheckpointManifest::from_json`] — the `qurl lint` config-drift pass
/// enforces the same save/load shape contract it enforces for
/// `TrainerConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointManifest {
    /// manifest format version ([`FORMAT_VERSION`])
    pub format_version: u64,
    /// next step the resumed run executes (steps `0..step` are complete)
    pub step: u64,
    /// trainer [`Pcg64`] stream state (hex string in JSON — u128 does not
    /// survive an f64 number)
    pub rng_state: u128,
    /// trainer [`Pcg64`] stream increment (hex string in JSON)
    pub rng_inc: u128,
    /// rollout seed cursor (bumped once per rollout call)
    pub rollout_seed: i32,
    /// requant cadence position (steps since the last engine refresh)
    pub engine_age: u64,
    /// [`DynamicSampler`](super::dapo::DynamicSampler) kept-groups counter
    pub sampler_kept: u64,
    /// sampler seen-groups counter
    pub sampler_seen: u64,
    /// sampler wave counter
    pub sampler_waves: u64,
    /// [`Schedule`](super::schedule::Schedule) stage table, when the run
    /// uses one (`Schedule::to_json` shape)
    pub schedule: Option<Json>,
    /// rollout-service cross-run state, when the scheduler path built one
    pub service: Option<ServiceSnapshot>,
    /// full `TrainerConfig` JSON at save time (`config::to_json` shape)
    pub config: Json,
    /// FNV-1a 64 over the fingerprint-relevant config (hex string in
    /// JSON); see [`config_fingerprint`]
    pub config_fingerprint: u64,
    /// `(file name, FNV-1a 64 over the file's bytes)` per payload
    pub payloads: Vec<(String, u64)>,
}

impl CheckpointManifest {
    pub fn to_json(&self) -> Json {
        let payloads = Json::Obj(
            self.payloads
                .iter()
                .map(|(f, sum)| (f.clone(), hex64(*sum)))
                .collect(),
        );
        Json::obj(vec![
            ("format_version", Json::num(self.format_version as f64)),
            ("step", Json::num(self.step as f64)),
            ("rng_state", hex128(self.rng_state)),
            ("rng_inc", hex128(self.rng_inc)),
            ("rollout_seed", Json::num(self.rollout_seed as f64)),
            ("engine_age", Json::num(self.engine_age as f64)),
            ("sampler_kept", Json::num(self.sampler_kept as f64)),
            ("sampler_seen", Json::num(self.sampler_seen as f64)),
            ("sampler_waves", Json::num(self.sampler_waves as f64)),
            ("schedule",
             self.schedule.clone().unwrap_or(Json::Null)),
            ("service",
             self.service.as_ref().map(|s| s.to_json())
                 .unwrap_or(Json::Null)),
            ("config", self.config.clone()),
            ("config_fingerprint", hex64(self.config_fingerprint)),
            ("payloads", payloads),
        ])
    }

    pub fn from_json(j: &Json, path: &Path) -> Result<CheckpointManifest> {
        let bad = |detail: &str| CheckpointError::Malformed {
            path: path.to_path_buf(),
            detail: detail.to_string(),
        };
        let num = |k: &str| -> Result<u64, CheckpointError> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .map(|x| x as u64)
                .ok_or_else(|| bad(&format!("bad numeric field {k:?}")))
        };
        let hex = |k: &str| -> Result<u128, CheckpointError> {
            j.get(k)
                .and_then(|v| v.as_str())
                .and_then(parse_hex)
                .ok_or_else(|| bad(&format!("bad hex field {k:?}")))
        };
        let format_version = num("format_version")?;
        if format_version != FORMAT_VERSION {
            return Err(CheckpointError::UnknownVersion {
                path: path.to_path_buf(),
                found: format_version,
            }
            .into());
        }
        let rollout_seed = j
            .get("rollout_seed")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| bad("bad numeric field \"rollout_seed\""))?
            as i32;
        let schedule = match j.get("schedule") {
            None | Some(Json::Null) => None,
            Some(s) => Some(s.clone()),
        };
        let service = match j.get("service") {
            None | Some(Json::Null) => None,
            Some(s) => Some(ServiceSnapshot::from_json(s).map_err(|e| {
                bad(&format!("bad \"service\" snapshot: {e}"))
            })?),
        };
        let config = j
            .get("config")
            .cloned()
            .ok_or_else(|| bad("missing \"config\" object"))?;
        let mut payloads = Vec::new();
        let pmap = j
            .get("payloads")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| bad("missing \"payloads\" object"))?;
        for (file, sum) in pmap {
            let sum = sum.as_str().and_then(parse_hex).ok_or_else(|| {
                bad(&format!("bad payload checksum for {file:?}"))
            })?;
            payloads.push((file.clone(), sum as u64));
        }
        Ok(CheckpointManifest {
            format_version,
            step: num("step")?,
            rng_state: hex("rng_state")?,
            rng_inc: hex("rng_inc")?,
            rollout_seed,
            engine_age: num("engine_age")?,
            sampler_kept: num("sampler_kept")?,
            sampler_seen: num("sampler_seen")?,
            sampler_waves: num("sampler_waves")?,
            schedule,
            service,
            config,
            config_fingerprint: hex("config_fingerprint")? as u64,
            payloads,
        })
    }
}

/// Borrowed view of everything one checkpoint captures — what the trainer
/// hands to [`save`].
pub struct CheckpointState<'a> {
    /// next step to execute after resume
    pub step: u64,
    /// full config JSON (`config::to_json` shape)
    pub config: Json,
    /// trainer RNG position ([`Pcg64::snapshot`])
    pub rng: (u128, u128),
    pub rollout_seed: i32,
    pub engine_age: u64,
    /// sampler counters (`DynamicSampler::snapshot`)
    pub sampler: (usize, usize, usize),
    /// stage table (`Schedule::to_json`), when the run uses one
    pub schedule: Option<Json>,
    /// rollout-service cross-run state, when a service exists
    pub service: Option<ServiceSnapshot>,
    /// actor weights + Adam moments + optimizer step
    pub ps: &'a ParamStore,
    /// frozen KL reference policy
    pub ref_params: &'a [f32],
    /// Fig. 9 analysis snapshot, when one is held
    pub prev_params: Option<&'a [f32]>,
    /// params the rollout engine was last quantized from — what makes a
    /// mid-requant-interval resume rebuild the *same* engine rather than
    /// requantizing newer params
    pub engine_params: Option<&'a [f32]>,
}

/// One checkpoint loaded back into owned state.
pub struct LoadedCheckpoint {
    pub manifest: CheckpointManifest,
    pub ps: ParamStore,
    pub ref_params: Vec<f32>,
    pub prev_params: Option<Vec<f32>>,
    pub engine_params: Option<Vec<f32>>,
    /// directory the checkpoint was read from
    pub dir: PathBuf,
}

impl LoadedCheckpoint {
    /// Rebuild the trainer RNG at its captured position.
    pub fn rng(&self) -> Pcg64 {
        Pcg64::restore(self.manifest.rng_state, self.manifest.rng_inc)
    }
}

// ---- fingerprint / config comparison --------------------------------------

/// FNV-1a 64 over the canonical (sorted-key, [`CKPT_CONTROL_KEYS`]
/// filtered) config JSON text.  The filter is what lets a `--resume`
/// invocation differ in its checkpoint knobs without tripping the
/// mismatch refusal.
pub fn config_fingerprint(config: &Json) -> u64 {
    fnv1a64(filtered_config(config).to_string().as_bytes())
}

fn filtered_config(config: &Json) -> Json {
    match config {
        Json::Obj(m) => Json::Obj(
            m.iter()
                .filter(|(k, _)| {
                    !CKPT_CONTROL_KEYS.contains(&k.as_str())
                })
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Refuse resume under a silently-changed config: compare the
/// checkpointed config JSON against the current one field by field
/// (checkpoint control knobs excluded) and name the first differing
/// field.  Field-wise rather than fingerprint-wise so the error says
/// *what* changed, not just that something did.
pub fn check_config(saved: &Json, current: &Json) -> Result<()> {
    let (a, b) = (filtered_config(saved), filtered_config(current));
    if a == b {
        return Ok(());
    }
    let absent = || "<absent>".to_string();
    let (am, bm) = (a.as_obj(), b.as_obj());
    let mut keys: Vec<&String> = Vec::new();
    if let (Some(am), Some(bm)) = (am, bm) {
        keys.extend(am.keys());
        keys.extend(bm.keys().filter(|k| !am.contains_key(*k)));
        for k in keys {
            let sv = am.get(k);
            let cv = bm.get(k);
            if sv != cv {
                return Err(CheckpointError::ConfigMismatch {
                    field: k.clone(),
                    saved: sv.map(|v| v.to_string()).unwrap_or_else(absent),
                    current: cv.map(|v| v.to_string()).unwrap_or_else(absent),
                }
                .into());
            }
        }
    }
    // non-object configs (should not happen) still refuse, just blunter
    Err(CheckpointError::ConfigMismatch {
        field: "<config>".to_string(),
        saved: a.to_string(),
        current: b.to_string(),
    }
    .into())
}

// ---- save ------------------------------------------------------------------

/// Directory name for a checkpoint of `step` (`step_000123`; fixed width
/// so lexicographic order is step order).
pub fn step_dir_name(step: u64) -> String {
    format!("step_{step:06}")
}

/// Write one checkpoint crash-safely and run retention GC.  Returns the
/// published checkpoint directory.
///
/// Protocol: stage every payload into `.tmp_step_NNNNNN` (each payload is
/// itself written temp+fsync+rename), write the manifest last, fsync the
/// staging directory, then one atomic rename publishes the snapshot.
/// `keep == 0` disables retention (keep everything); otherwise the newest
/// `keep` good checkpoints survive ([`gc`]).
pub fn save(dir: &Path, st: &CheckpointState<'_>, keep: usize)
            -> Result<PathBuf> {
    std::fs::create_dir_all(dir).map_err(|e| {
        CheckpointError::Malformed {
            path: dir.to_path_buf(),
            detail: format!("cannot create checkpoint dir: {e}"),
        }
    })?;
    let name = step_dir_name(st.step);
    let stage = dir.join(format!(".tmp_{name}"));
    if stage.exists() {
        std::fs::remove_dir_all(&stage).ok(); // crash leftover
    }
    std::fs::create_dir_all(&stage).map_err(|e| {
        CheckpointError::Malformed {
            path: stage.clone(),
            detail: format!("cannot create staging dir: {e}"),
        }
    })?;
    // payloads first (each internally atomic + checksummed)
    st.ps.save(&stage.join("params.bin"))?;
    save_vec(&stage.join("ref_params.bin"), st.ref_params)?;
    if let Some(p) = st.prev_params {
        save_vec(&stage.join("prev_params.bin"), p)?;
    }
    if let Some(p) = st.engine_params {
        save_vec(&stage.join("engine_params.bin"), p)?;
    }
    // whole-file digests into the manifest (the loader's torn-snapshot
    // detector; payload-internal checksums guard the single-file case)
    let mut payloads = Vec::new();
    let mut names = vec!["params.bin", "ref_params.bin"];
    if st.prev_params.is_some() {
        names.push("prev_params.bin");
    }
    if st.engine_params.is_some() {
        names.push("engine_params.bin");
    }
    for file in names {
        let bytes =
            std::fs::read(stage.join(file)).map_err(|_| {
                CheckpointError::MissingPayload {
                    path: stage.clone(),
                    file: file.to_string(),
                }
            })?;
        payloads.push((file.to_string(), fnv1a64(&bytes)));
    }
    let manifest = CheckpointManifest {
        format_version: FORMAT_VERSION,
        step: st.step,
        rng_state: st.rng.0,
        rng_inc: st.rng.1,
        rollout_seed: st.rollout_seed,
        engine_age: st.engine_age,
        sampler_kept: st.sampler.0 as u64,
        sampler_seen: st.sampler.1 as u64,
        sampler_waves: st.sampler.2 as u64,
        schedule: st.schedule.clone(),
        service: st.service.clone(),
        config: st.config.clone(),
        config_fingerprint: config_fingerprint(&st.config),
        payloads,
    };
    write_atomic(&stage.join(MANIFEST_FILE),
                 manifest.to_json().to_string().as_bytes())?;
    sync_dir(&stage);
    let dest = dir.join(&name);
    if dest.exists() {
        // re-checkpointing the same step (resume overlap): replace whole
        std::fs::remove_dir_all(&dest).ok();
    }
    std::fs::rename(&stage, &dest).map_err(|e| {
        CheckpointError::Malformed {
            path: dest.clone(),
            detail: format!("publishing rename failed: {e}"),
        }
    })?;
    sync_dir(dir);
    if keep > 0 {
        gc(dir, keep)?;
    }
    Ok(dest)
}

// ---- verify / load ---------------------------------------------------------

/// Parse and fully verify one checkpoint directory: manifest parses, the
/// format version is known, and every payload's bytes hash to the
/// manifest's checksum.  Typed errors throughout.
pub fn verify(step_dir: &Path) -> Result<CheckpointManifest> {
    let mpath = step_dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&mpath).map_err(|_| {
        CheckpointError::MissingPayload {
            path: step_dir.to_path_buf(),
            file: MANIFEST_FILE.to_string(),
        }
    })?;
    let j = Json::parse(&text).map_err(|e| CheckpointError::Malformed {
        path: mpath.clone(),
        detail: e.to_string(),
    })?;
    let manifest = CheckpointManifest::from_json(&j, &mpath)?;
    for (file, stored) in &manifest.payloads {
        let bytes = std::fs::read(step_dir.join(file)).map_err(|_| {
            CheckpointError::MissingPayload {
                path: step_dir.to_path_buf(),
                file: file.clone(),
            }
        })?;
        let computed = fnv1a64(&bytes);
        if computed != *stored {
            return Err(CheckpointError::ChecksumMismatch {
                path: step_dir.to_path_buf(),
                file: file.clone(),
                stored: *stored,
                computed,
            }
            .into());
        }
    }
    Ok(manifest)
}

/// Load one verified checkpoint directory into owned state.
pub fn load_dir(step_dir: &Path) -> Result<LoadedCheckpoint> {
    let manifest = verify(step_dir)?;
    let has = |f: &str| manifest.payloads.iter().any(|(n, _)| n == f);
    let ps = ParamStore::load(&step_dir.join("params.bin"))?;
    let ref_params = load_vec(&step_dir.join("ref_params.bin"))?;
    let prev_params = if has("prev_params.bin") {
        Some(load_vec(&step_dir.join("prev_params.bin"))?)
    } else {
        None
    };
    let engine_params = if has("engine_params.bin") {
        Some(load_vec(&step_dir.join("engine_params.bin"))?)
    } else {
        None
    };
    Ok(LoadedCheckpoint {
        manifest,
        ps,
        ref_params,
        prev_params,
        engine_params,
        dir: step_dir.to_path_buf(),
    })
}

/// Newest checkpoint that verifies clean, scanning `step_*` directories
/// newest-first and falling back past corrupted/torn snapshots (each skip
/// is logged).  `Ok(None)` when the directory holds no checkpoint at all.
/// An unknown manifest version is NOT skipped — it propagates as the
/// typed refusal, because newer-format data means this binary is the
/// wrong reader, and "fall back to older state" would silently rewind
/// the run.
pub fn latest_good(dir: &Path) -> Result<Option<PathBuf>> {
    for (_, path) in step_dirs(dir) {
        match verify(&path) {
            Ok(_) => return Ok(Some(path)),
            Err(e) => {
                let unknown = e
                    .downcast_ref::<CheckpointError>()
                    .map(|c| matches!(c,
                                      CheckpointError::UnknownVersion { .. }))
                    .unwrap_or(false);
                if unknown {
                    return Err(e);
                }
                crate::warnln!("ckpt", "skipping bad checkpoint {path:?}: \
                                {e}; falling back to the previous one");
            }
        }
    }
    Ok(None)
}

/// Load the newest good checkpoint under `dir` (the `--resume` entry
/// point).  Typed [`CheckpointError::NoCheckpoint`] when none exists.
pub fn load_latest(dir: &Path) -> Result<LoadedCheckpoint> {
    match latest_good(dir)? {
        Some(path) => load_dir(&path),
        None => Err(CheckpointError::NoCheckpoint {
            dir: dir.to_path_buf(),
        }
        .into()),
    }
}

// ---- retention -------------------------------------------------------------

/// Retention GC: keep the newest `keep` *good* checkpoints (bad ones
/// inside that window are also retained — they may be all there is until
/// enough good ones accumulate), delete everything older, and sweep
/// `.tmp_*` staging leftovers.  The newest good checkpoint is never
/// deleted: it is the first one the walk counts.  Returns the number of
/// directories removed.
pub fn gc(dir: &Path, keep: usize) -> Result<usize> {
    let keep = keep.max(1);
    let mut removed = 0usize;
    let mut good_seen = 0usize;
    for (_, path) in step_dirs(dir) {
        if good_seen < keep {
            if verify(&path).is_ok() {
                good_seen += 1;
            }
            continue;
        }
        if std::fs::remove_dir_all(&path).is_ok() {
            removed += 1;
        }
    }
    // crash leftovers from interrupted saves
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with(".tmp_step_")
                && std::fs::remove_dir_all(entry.path()).is_ok()
            {
                removed += 1;
            }
        }
    }
    Ok(removed)
}

/// `step_*` checkpoint directories under `dir`, newest (highest step)
/// first.  Staging (`.tmp_*`) and foreign entries are ignored.
fn step_dirs(dir: &Path) -> Vec<(u64, PathBuf)> {
    let mut out: Vec<(u64, PathBuf)> = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy().to_string();
            if let Some(num) = name.strip_prefix("step_") {
                if let Ok(step) = num.parse::<u64>() {
                    if entry.path().is_dir() {
                        out.push((step, entry.path()));
                    }
                }
            }
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

// ---- payload codec ---------------------------------------------------------

/// Atomic checksummed f32-vector payload (reference policy, analysis and
/// engine-source params): `QURLVEC1`, n as u64 LE, raw f32 bytes, FNV-1a
/// 64 over everything preceding.  Same temp + fsync + rename protocol as
/// [`ParamStore::save`].
fn save_vec(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes =
        Vec::with_capacity(16 + data.len() * 4 + 8);
    bytes.extend_from_slice(VEC_MAGIC);
    bytes.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    let sum = fnv1a64(&bytes);
    bytes.extend_from_slice(&sum.to_le_bytes());
    write_atomic(path, &bytes)
}

fn load_vec(path: &Path) -> Result<Vec<f32>> {
    let malformed = |detail: String| CheckpointError::Malformed {
        path: path.to_path_buf(),
        detail,
    };
    let bytes = std::fs::read(path).map_err(|_| {
        CheckpointError::MissingPayload {
            path: path.to_path_buf(),
            file: path
                .file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_default(),
        }
    })?;
    if bytes.len() < 24 || &bytes[..8] != VEC_MAGIC {
        return Err(malformed(
            "truncated or mislabeled vec payload".to_string(),
        )
        .into());
    }
    let mut u = [0u8; 8];
    u.copy_from_slice(&bytes[8..16]);
    let n = u64::from_le_bytes(u) as usize;
    let body_end = 16usize.saturating_add(n.saturating_mul(4));
    if bytes.len() != body_end + 8 {
        return Err(malformed(format!(
            "vec payload claims {n} f32s but holds {} bytes",
            bytes.len()
        ))
        .into());
    }
    u.copy_from_slice(&bytes[body_end..]);
    let stored = u64::from_le_bytes(u);
    let computed =
        fnv1a64_continue(FNV_OFFSET, &bytes[..body_end]);
    if stored != computed {
        return Err(CheckpointError::ChecksumMismatch {
            path: path.to_path_buf(),
            file: path
                .file_name()
                .map(|f| f.to_string_lossy().to_string())
                .unwrap_or_default(),
            stored,
            computed,
        }
        .into());
    }
    let mut out = Vec::with_capacity(n);
    for chunk in bytes[16..body_end].chunks_exact(4) {
        let mut b = [0u8; 4];
        b.copy_from_slice(chunk);
        out.push(f32::from_le_bytes(b));
    }
    Ok(out)
}

/// Temp-file + fsync + atomic-rename write, with a best-effort parent
/// directory fsync for rename durability.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("payload"));
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    let stage_err = |e: std::io::Error| CheckpointError::Malformed {
        path: tmp.clone(),
        detail: format!("staging write failed: {e}"),
    };
    let mut f = std::fs::File::create(&tmp).map_err(stage_err)?;
    f.write_all(bytes).map_err(stage_err)?;
    f.sync_all().map_err(stage_err)?;
    drop(f);
    std::fs::rename(&tmp, path).map_err(|e| {
        CheckpointError::Malformed {
            path: path.to_path_buf(),
            detail: format!("atomic rename failed: {e}"),
        }
    })?;
    if let Some(parent) = path.parent() {
        sync_dir(parent);
    }
    Ok(())
}

/// Best-effort directory fsync (makes renames durable on Linux; a
/// failure here degrades durability, not correctness, so it is ignored).
fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

fn hex128(v: u128) -> Json {
    Json::Str(format!("{v:#x}"))
}

fn hex64(v: u64) -> Json {
    Json::Str(format!("{v:#x}"))
}

fn parse_hex(s: &str) -> Option<u128> {
    u128::from_str_radix(s.strip_prefix("0x")?, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("qurl_ckpt_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn store(step: u64) -> ParamStore {
        ParamStore {
            params: (0..24).map(|i| i as f32 * 0.5 - 3.0).collect(),
            m: vec![0.25; 24],
            v: vec![0.5; 24],
            step,
            a_size: 8,
        }
    }

    fn state<'a>(step: u64, ps: &'a ParamStore, refp: &'a [f32],
                 cfg: &Json) -> CheckpointState<'a> {
        CheckpointState {
            step,
            config: cfg.clone(),
            rng: (0x1234_5678_9abc_def0_1111_2222_3333_4444,
                  0x5555_6666_7777_8888_9999_aaaa_bbbb_cccd),
            rollout_seed: -77,
            engine_age: 1,
            sampler: (0, 0, 0),
            schedule: None,
            service: None,
            ps,
            ref_params: refp,
            prev_params: None,
            engine_params: None,
        }
    }

    fn cfg_json() -> Json {
        Json::obj(vec![
            ("seed", Json::num(7.0)),
            ("steps", Json::num(10.0)),
            ("ckpt_every", Json::num(2.0)),
            ("resume", Json::Bool(false)),
        ])
    }

    /// Manifest JSON round trip is exact, including the u128 RNG state
    /// (hex strings — an f64 number would shred the low bits).
    #[test]
    fn manifest_json_roundtrip_preserves_u128() {
        let ps = store(3);
        let refp = vec![1.0f32; 24];
        let st = state(4, &ps, &refp, &cfg_json());
        let man = CheckpointManifest {
            format_version: FORMAT_VERSION,
            step: st.step,
            rng_state: st.rng.0,
            rng_inc: st.rng.1,
            rollout_seed: st.rollout_seed,
            engine_age: st.engine_age,
            sampler_kept: 1,
            sampler_seen: 2,
            sampler_waves: 3,
            schedule: None,
            service: None,
            config: st.config.clone(),
            config_fingerprint: config_fingerprint(&st.config),
            payloads: vec![("params.bin".into(), 0xdead_beef_cafe_f00d)],
        };
        let text = man.to_json().to_string();
        let back = CheckpointManifest::from_json(
            &Json::parse(&text).unwrap(), Path::new("t")).unwrap();
        assert_eq!(man, back);
    }

    /// Save → load round trip restores params, moments, RNG position and
    /// the manifest metadata bit-for-bit.
    #[test]
    fn save_load_roundtrip() {
        let dir = tdir("roundtrip");
        let ps = store(9);
        let refp: Vec<f32> = (0..24).map(|i| i as f32 * -0.125).collect();
        let prev: Vec<f32> = vec![2.5; 24];
        let mut st = state(6, &ps, &refp, &cfg_json());
        st.prev_params = Some(&prev);
        let path = save(&dir, &st, 0).unwrap();
        assert_eq!(path, dir.join("step_000006"));
        let back = load_latest(&dir).unwrap();
        assert_eq!(back.manifest.step, 6);
        assert_eq!(back.manifest.rng_state, st.rng.0);
        assert_eq!(back.manifest.rng_inc, st.rng.1);
        assert_eq!(back.manifest.rollout_seed, -77);
        assert_eq!(back.ps.params, ps.params);
        assert_eq!(back.ps.m, ps.m);
        assert_eq!(back.ps.step, 9);
        assert_eq!(back.ref_params, refp);
        assert_eq!(back.prev_params.as_deref(), Some(&prev[..]));
        assert!(back.engine_params.is_none());
        // no staging leftovers
        assert!(!dir.join(".tmp_step_000006").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Corrupted payload → loader falls back to the previous good
    /// snapshot; with no good snapshot at all the error is typed.
    #[test]
    fn corruption_falls_back_to_previous_good() {
        let dir = tdir("fallback");
        let ps = store(1);
        let refp = vec![0.5f32; 24];
        save(&dir, &state(2, &ps, &refp, &cfg_json()), 0).unwrap();
        save(&dir, &state(4, &ps, &refp, &cfg_json()), 0).unwrap();
        // flip a byte mid-payload in the newest snapshot
        let victim = dir.join("step_000004").join("params.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&victim, &bytes).unwrap();
        let back = load_latest(&dir).unwrap();
        assert_eq!(back.manifest.step, 2, "did not fall back past the \
                                           corrupted snapshot");
        // corrupt the survivor too: typed NoCheckpoint
        let victim = dir.join("step_000002").join("ref_params.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&victim, &bytes).unwrap();
        let err = load_latest(&dir).unwrap_err();
        assert!(matches!(err.downcast_ref::<CheckpointError>(),
                         Some(CheckpointError::NoCheckpoint { .. })),
                "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Unknown manifest version: typed refusal, NOT a silent fallback to
    /// an older (readable) snapshot.
    #[test]
    fn unknown_version_is_typed_refusal() {
        let dir = tdir("version");
        let ps = store(1);
        let refp = vec![0.5f32; 24];
        save(&dir, &state(2, &ps, &refp, &cfg_json()), 0).unwrap();
        save(&dir, &state(4, &ps, &refp, &cfg_json()), 0).unwrap();
        let mpath = dir.join("step_000004").join("manifest.json");
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath,
                       text.replace("\"format_version\":1",
                                    "\"format_version\":99")).unwrap();
        let err = load_latest(&dir).unwrap_err();
        match err.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::UnknownVersion { found, .. }) => {
                assert_eq!(*found, 99);
            }
            other => panic!("wrong error: {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Config drift refusal names the differing field; the checkpoint
    /// control knobs are exempt.
    #[test]
    fn config_mismatch_names_the_field() {
        let saved = cfg_json();
        let mut current = saved.clone();
        if let Json::Obj(m) = &mut current {
            m.insert("steps".to_string(), Json::num(20.0));
            // control knobs may differ freely
            m.insert("ckpt_every".to_string(), Json::num(5.0));
            m.insert("resume".to_string(), Json::Bool(true));
        }
        let err = check_config(&saved, &current).unwrap_err();
        match err.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::ConfigMismatch { field, saved,
                                                   current }) => {
                assert_eq!(field, "steps");
                assert_eq!((saved.as_str(), current.as_str()),
                           ("10", "20"));
            }
            other => panic!("wrong error: {other:?}"),
        }
        // identical (modulo control knobs) passes
        let mut same = cfg_json();
        if let Json::Obj(m) = &mut same {
            m.insert("resume".to_string(), Json::Bool(true));
        }
        assert!(check_config(&cfg_json(), &same).is_ok());
        assert_eq!(config_fingerprint(&cfg_json()),
                   config_fingerprint(&same),
                   "control knobs must not move the fingerprint");
    }

    /// Retention: newest `keep` good checkpoints survive, older ones go,
    /// the newest good one survives even when newer snapshots are bad,
    /// and staging leftovers are swept.
    #[test]
    fn gc_keeps_newest_good() {
        let dir = tdir("gc");
        let ps = store(1);
        let refp = vec![0.5f32; 24];
        for step in [2u64, 4, 6, 8] {
            save(&dir, &state(step, &ps, &refp, &cfg_json()), 0).unwrap();
        }
        std::fs::create_dir_all(dir.join(".tmp_step_000010")).unwrap();
        gc(&dir, 2).unwrap();
        assert!(!dir.join("step_000002").exists());
        assert!(!dir.join("step_000004").exists());
        assert!(dir.join("step_000006").exists());
        assert!(dir.join("step_000008").exists());
        assert!(!dir.join(".tmp_step_000010").exists(),
                "staging leftover not swept");
        // newest is corrupt: keep=1 must still retain the older good one
        std::fs::remove_file(dir.join("step_000008").join("params.bin"))
            .unwrap();
        gc(&dir, 1).unwrap();
        assert!(dir.join("step_000006").exists(),
                "gc deleted the only good checkpoint");
        std::fs::remove_dir_all(&dir).ok();
    }
}
