//! DAPO specifics (Yu et al., 2025): decoupled clip ranges
//! (eps_high=0.28 > eps_low=0.2), token-mean aggregation and *dynamic
//! sampling* — groups whose rewards are all identical carry zero GRPO
//! advantage and are filtered out, with rollout repeated until the batch is
//! full of informative groups.

/// Returns indices of groups that carry signal (not all-same reward).
pub fn informative_groups(rewards: &[f32], group_size: usize) -> Vec<usize> {
    assert!(group_size > 0 && rewards.len() % group_size == 0);
    rewards
        .chunks_exact(group_size)
        .enumerate()
        .filter(|(_, chunk)| {
            let first = chunk[0];
            chunk.iter().any(|&r| (r - first).abs() > 1e-6)
        })
        .map(|(g, _)| g)
        .collect()
}

/// Dynamic-sampling accumulator: feeds on resolved groups (either a whole
/// post-hoc wave of rewards, or one group at a time as the
/// [`RolloutService`](crate::coordinator::RolloutService) resolves them),
/// keeps only informative ones, reports when `target_groups` have been
/// collected.
///
/// Bookkeeping is by *count*, not by stored group indices — wave-local
/// indices from different waves collide and are meaningless as identifiers
/// (the old `kept: Vec<usize>` stored exactly those, so `efficiency()` was
/// only accidentally right and callers could not trust the ids).
pub struct DynamicSampler {
    pub group_size: usize,
    pub target_groups: usize,
    /// informative groups kept so far, across all waves
    kept_groups: usize,
    /// total groups seen (the DAPO "sampling efficiency" denominator)
    pub seen_groups: usize,
    /// safety valve: stop resampling after this many waves even if short
    pub max_waves: usize,
    pub waves: usize,
}

impl DynamicSampler {
    pub fn new(group_size: usize, target_groups: usize) -> Self {
        DynamicSampler {
            group_size,
            target_groups,
            kept_groups: 0,
            seen_groups: 0,
            max_waves: 8,
            waves: 0,
        }
    }

    /// Post-hoc filtering (fused rollout path): offer one wave of
    /// sequence-major `rewards`; returns the wave-local indices of the
    /// groups kept this wave (valid only against this wave's layout).
    pub fn offer(&mut self, rewards: &[f32]) -> Vec<usize> {
        self.waves += 1;
        self.seen_groups += rewards.len() / self.group_size;
        let keep = informative_groups(rewards, self.group_size);
        let room = self.target_groups.saturating_sub(self.kept_groups);
        let kept: Vec<usize> = keep.into_iter().take(room).collect();
        self.kept_groups += kept.len();
        kept
    }

    /// Online policy (service rollout path): count a service wave.  The
    /// wave budget (`max_waves`) is what bounds DAPO resampling, so each
    /// batch of submitted groups must be announced.
    pub fn begin_wave(&mut self) {
        self.waves += 1;
    }

    /// Online policy: record one resolved group; returns whether the
    /// caller should keep it (informative and still under target).
    /// Pruned/incomplete groups are recorded as uninformative — they count
    /// against efficiency exactly like a post-hoc filtered group.
    pub fn record_group(&mut self, informative: bool) -> bool {
        self.seen_groups += 1;
        if informative && self.kept_groups < self.target_groups {
            self.kept_groups += 1;
            true
        } else {
            false
        }
    }

    /// Informative groups kept so far (across waves).
    pub fn kept(&self) -> usize {
        self.kept_groups
    }

    pub fn done(&self) -> bool {
        self.kept_groups >= self.target_groups || self.waves >= self.max_waves
    }

    /// Fraction of sampled groups that were informative.
    pub fn efficiency(&self) -> f64 {
        if self.seen_groups == 0 {
            0.0
        } else {
            self.kept_groups as f64 / self.seen_groups as f64
        }
    }

    /// Checkpoint capture: `(kept_groups, seen_groups, waves)` — the full
    /// mutable state (the remaining fields are configuration).  The
    /// trainer constructs its sampler fresh inside each step's collect
    /// loop, so at a step-boundary checkpoint this is always
    /// `(0, 0, 0)`; the API exists so any future mid-step or cross-step
    /// sampler survives resume, per the checkpoint manifest contract in
    /// [`crate::rl::checkpoint`].
    pub fn snapshot(&self) -> (usize, usize, usize) {
        (self.kept_groups, self.seen_groups, self.waves)
    }

    /// Restore a [`Self::snapshot`] onto a sampler built with the same
    /// configuration; counting then continues exactly where it left off.
    pub fn restore(&mut self, snap: (usize, usize, usize)) {
        let (kept, seen, waves) = snap;
        self.kept_groups = kept;
        self.seen_groups = seen;
        self.waves = waves;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_uniform_groups() {
        // group 0: all zero (filtered), group 1: mixed (kept),
        // group 2: all one (filtered)
        let rewards = [0., 0., 0., 0., 1., 0., 1., 1., 1., 1., 1., 1.];
        let keep = informative_groups(&rewards, 4);
        assert_eq!(keep, vec![1]);
    }

    #[test]
    fn sampler_accumulates_until_target() {
        let mut ds = DynamicSampler::new(2, 3);
        assert!(!ds.done());
        let k1 = ds.offer(&[0., 0., 1., 0.]); // one informative group
        assert_eq!(k1, vec![1]);
        let k2 = ds.offer(&[1., 0., 0., 1.]); // two informative groups
        assert_eq!(k2, vec![0, 1]);
        assert!(ds.done());
        assert!((ds.efficiency() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sampler_truncates_at_target() {
        let mut ds = DynamicSampler::new(2, 1);
        let k = ds.offer(&[1., 0., 0., 1.]);
        assert_eq!(k.len(), 1);
        assert!(ds.done());
    }

    #[test]
    fn sampler_gives_up_after_max_waves() {
        let mut ds = DynamicSampler::new(2, 5);
        ds.max_waves = 2;
        ds.offer(&[0., 0.]);
        assert!(!ds.done());
        ds.offer(&[1., 1.]);
        assert!(ds.done()); // wave budget exhausted
        assert_eq!(ds.kept(), 0);
    }

    /// Regression for the wave-local-index bug: the same group index kept
    /// in several waves must count as distinct groups, so efficiency over
    /// multi-wave runs is kept/seen — not distorted by index collisions.
    #[test]
    fn efficiency_across_multiple_waves() {
        let mut ds = DynamicSampler::new(2, 4);
        // three waves of 2 groups each; the kept group is index 0 in every
        // wave (the colliding-id case the old Vec<usize> stored blindly)
        for _ in 0..3 {
            let k = ds.offer(&[1., 0., 1., 1.]);
            assert_eq!(k, vec![0]);
        }
        assert_eq!(ds.kept(), 3);
        assert_eq!(ds.seen_groups, 6);
        assert!((ds.efficiency() - 0.5).abs() < 1e-9);
        assert!(!ds.done());
    }

    /// Checkpoint contract: a restored sampler makes the same keep/done
    /// decisions the original would have, from the same position.
    #[test]
    fn snapshot_restore_continues_counting() {
        let mut a = DynamicSampler::new(2, 3);
        a.offer(&[0., 0., 1., 0.]);
        let snap = a.snapshot();
        assert_eq!(snap, (1, 2, 1));
        let mut b = DynamicSampler::new(2, 3);
        b.restore(snap);
        assert_eq!(a.offer(&[1., 0., 0., 1.]), b.offer(&[1., 0., 0., 1.]));
        assert_eq!((a.kept(), a.seen_groups, a.waves, a.done()),
                   (b.kept(), b.seen_groups, b.waves, b.done()));
        assert!((a.efficiency() - b.efficiency()).abs() < 1e-12);
    }

    /// The online (service-path) policy matches post-hoc filtering counts:
    /// groups recorded one at a time accumulate the same kept/seen/
    /// efficiency, and the keep decision honors the target cap.
    #[test]
    fn online_record_matches_posthoc_counts() {
        let mut ds = DynamicSampler::new(4, 2);
        ds.begin_wave();
        assert!(ds.record_group(true));
        assert!(!ds.record_group(false));
        ds.begin_wave();
        assert!(ds.record_group(true));
        assert!(ds.done(), "target reached");
        // over target: informative groups are no longer kept
        assert!(!ds.record_group(true));
        assert_eq!(ds.kept(), 2);
        assert_eq!(ds.seen_groups, 4);
        assert_eq!(ds.waves, 2);
        assert!((ds.efficiency() - 0.5).abs() < 1e-9);
    }
}
