//! DAPO specifics (Yu et al., 2025): decoupled clip ranges
//! (eps_high=0.28 > eps_low=0.2), token-mean aggregation and *dynamic
//! sampling* — groups whose rewards are all identical carry zero GRPO
//! advantage and are filtered out, with rollout repeated until the batch is
//! full of informative groups.

/// Returns indices of groups that carry signal (not all-same reward).
pub fn informative_groups(rewards: &[f32], group_size: usize) -> Vec<usize> {
    assert!(group_size > 0 && rewards.len() % group_size == 0);
    rewards
        .chunks_exact(group_size)
        .enumerate()
        .filter(|(_, chunk)| {
            let first = chunk[0];
            chunk.iter().any(|&r| (r - first).abs() > 1e-6)
        })
        .map(|(g, _)| g)
        .collect()
}

/// Dynamic-sampling accumulator: feeds on rollout waves, keeps only
/// informative groups, reports when `target_groups` have been collected.
pub struct DynamicSampler {
    pub group_size: usize,
    pub target_groups: usize,
    /// collected (sequence-major) data from informative groups
    pub kept: Vec<usize>,
    /// total groups seen / kept (the DAPO "sampling efficiency" metric)
    pub seen_groups: usize,
    /// safety valve: stop resampling after this many waves even if short
    pub max_waves: usize,
    pub waves: usize,
}

impl DynamicSampler {
    pub fn new(group_size: usize, target_groups: usize) -> Self {
        DynamicSampler {
            group_size,
            target_groups,
            kept: Vec::new(),
            seen_groups: 0,
            max_waves: 8,
            waves: 0,
        }
    }

    /// Offer one wave of `rewards`; returns the group indices (within this
    /// wave) that were kept.
    pub fn offer(&mut self, rewards: &[f32]) -> Vec<usize> {
        self.waves += 1;
        self.seen_groups += rewards.len() / self.group_size;
        let keep = informative_groups(rewards, self.group_size);
        let room = self.target_groups.saturating_sub(self.kept.len());
        let kept: Vec<usize> = keep.into_iter().take(room).collect();
        self.kept.extend(kept.iter().copied());
        kept
    }

    pub fn done(&self) -> bool {
        self.kept.len() >= self.target_groups || self.waves >= self.max_waves
    }

    /// Fraction of sampled groups that were informative.
    pub fn efficiency(&self) -> f64 {
        if self.seen_groups == 0 {
            0.0
        } else {
            self.kept.len() as f64 / self.seen_groups as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_uniform_groups() {
        // group 0: all zero (filtered), group 1: mixed (kept),
        // group 2: all one (filtered)
        let rewards = [0., 0., 0., 0., 1., 0., 1., 1., 1., 1., 1., 1.];
        let keep = informative_groups(&rewards, 4);
        assert_eq!(keep, vec![1]);
    }

    #[test]
    fn sampler_accumulates_until_target() {
        let mut ds = DynamicSampler::new(2, 3);
        assert!(!ds.done());
        let k1 = ds.offer(&[0., 0., 1., 0.]); // one informative group
        assert_eq!(k1, vec![1]);
        let k2 = ds.offer(&[1., 0., 0., 1.]); // two informative groups
        assert_eq!(k2, vec![0, 1]);
        assert!(ds.done());
        assert!((ds.efficiency() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sampler_truncates_at_target() {
        let mut ds = DynamicSampler::new(2, 1);
        let k = ds.offer(&[1., 0., 0., 1.]);
        assert_eq!(k.len(), 1);
        assert!(ds.done());
    }

    #[test]
    fn sampler_gives_up_after_max_waves() {
        let mut ds = DynamicSampler::new(2, 5);
        ds.max_waves = 2;
        ds.offer(&[0., 0.]);
        assert!(!ds.done());
        ds.offer(&[1., 1.]);
        assert!(ds.done()); // wave budget exhausted
        assert_eq!(ds.kept.len(), 0);
    }
}
