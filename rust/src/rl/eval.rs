//! Evaluation protocols matching the paper: greedy Avg@1 and sampled Avg@K
//! (temperature 1.0 / 0.6, top-p 0.7 — Table 2/3 settings).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::{EngineWeights, Runtime};
use crate::tasks::{encode_batch, verify, Family, Problem, Suite, Tokenizer};

/// Greedy (Avg@1) accuracy over the suite's test set.
pub fn greedy_accuracy(rt: &Runtime, engine: &EngineWeights, tk: &Tokenizer,
                       suite: &Suite, seed: u64, n_per_family: usize)
                       -> Result<f64> {
    let per = per_family_accuracy(rt, engine, tk, suite, seed, n_per_family,
                                  1, 0.0, 1.0)?;
    let total: f64 = per.values().map(|&(acc, _)| acc).sum();
    Ok(total / per.len().max(1) as f64)
}

/// Avg@K accuracy per family: mean over K sampled generations per problem.
/// Returns family -> (accuracy, n_problems).  K=1 with temp=0 is greedy.
pub fn per_family_accuracy(rt: &Runtime, engine: &EngineWeights,
                           tk: &Tokenizer, suite: &Suite, seed: u64,
                           n_per_family: usize, k: usize, temp: f32,
                           top_p: f32)
                           -> Result<BTreeMap<&'static str, (f64, usize)>> {
    let man = rt.manifest();
    let (b, s) = (man.rollout_batch, man.max_seq);
    let test = suite.test_set(seed, n_per_family);
    // expand each problem K times, keep (family, problem index) per row
    let mut jobs: Vec<(Family, usize)> = Vec::with_capacity(test.len() * k);
    for (i, (fam, _)) in test.iter().enumerate() {
        for _ in 0..k {
            jobs.push((*fam, i));
        }
    }
    let mut correct: Vec<f64> = vec![0.0; test.len()];
    let mut seed_i = seed as i32 ^ 0x6576;
    for wave in jobs.chunks(b) {
        let refs: Vec<&Problem> =
            wave.iter().map(|(_, i)| &test[*i].1).collect();
        let (tokens, lens) = encode_batch(tk, &refs, b, s, man.max_prompt);
        seed_i = seed_i.wrapping_add(1);
        let gen = rt.generate(engine, &tokens, &lens, seed_i, temp, top_p)?;
        for (r, (_, prob_i)) in wave.iter().enumerate() {
            let row = &gen.tokens[r * s..(r + 1) * s];
            let text = tk.decode_generation(row, lens[r] as usize);
            correct[*prob_i] += verify(&test[*prob_i].1, &text) as f64;
        }
    }
    let mut out: BTreeMap<&'static str, (f64, usize)> = BTreeMap::new();
    for (i, (fam, _)) in test.iter().enumerate() {
        let e = out.entry(fam.name()).or_insert((0.0, 0));
        e.0 += correct[i] / k as f64;
        e.1 += 1;
    }
    for (_, v) in out.iter_mut() {
        v.0 /= v.1 as f64;
    }
    Ok(out)
}

/// The paper's Avg@K over one suite: average of per-family Avg@K.
pub fn avg_at_k(rt: &Runtime, engine: &EngineWeights, tk: &Tokenizer,
                suite: &Suite, seed: u64, n_per_family: usize, k: usize,
                temp: f32, top_p: f32) -> Result<f64> {
    let per = per_family_accuracy(rt, engine, tk, suite, seed, n_per_family,
                                  k, temp, top_p)?;
    let total: f64 = per.values().map(|&(acc, _)| acc).sum();
    Ok(total / per.len().max(1) as f64)
}
