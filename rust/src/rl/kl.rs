//! KL divergence estimators over sampled tokens (Schulman 2020 — the
//! paper's k3 choice for GRPO regularization, plus k1/k2 for analysis).
//!
//! Given per-token logprobs of two policies on *sampled* tokens, estimate
//! D_KL(p || q) where tokens were sampled from p:
//!   k1 = log(p/q),   k2 = 0.5 (log p/q)^2,   k3 = (q/p) - 1 - log(q/p).

/// Masked-mean k1 estimate: E_p[log p - log q].
/// This is what the paper plots in Fig. 3(a) as D_KL(behav || prox).
pub fn k1(lp_p: &[f32], lp_q: &[f32], mask: &[f32]) -> f64 {
    masked_mean(lp_p, lp_q, mask, |d| d)
}

pub fn k2(lp_p: &[f32], lp_q: &[f32], mask: &[f32]) -> f64 {
    masked_mean(lp_p, lp_q, mask, |d| 0.5 * d * d)
}

/// k3: unbiased and non-negative; the GRPO regularizer.
pub fn k3(lp_p: &[f32], lp_q: &[f32], mask: &[f32]) -> f64 {
    masked_mean(lp_p, lp_q, mask, |d| {
        // d = log p - log q; q/p = exp(-d)
        (-d).exp() - 1.0 + d
    })
}

fn masked_mean(lp_p: &[f32], lp_q: &[f32], mask: &[f32],
               f: impl Fn(f64) -> f64) -> f64 {
    assert_eq!(lp_p.len(), lp_q.len());
    assert_eq!(lp_p.len(), mask.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..lp_p.len() {
        if mask[i] > 0.5 {
            let d = (lp_p[i] - lp_q[i]) as f64;
            num += f(d.clamp(-30.0, 30.0));
            den += 1.0;
        }
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Max proximal-to-behavior probability ratio over masked tokens — the
/// paper's Fig. 3(b) series (reaches ~1e5 before collapse).
pub fn max_ratio(lp_prox: &[f32], lp_behav: &[f32], mask: &[f32]) -> f64 {
    let mut mx = 0.0f64;
    for i in 0..lp_prox.len() {
        if mask[i] > 0.5 {
            let r = ((lp_prox[i] - lp_behav[i]) as f64).clamp(-30.0, 30.0).exp();
            mx = mx.max(r);
        }
    }
    mx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_policies_zero() {
        let lp = vec![-1.0f32, -2.0, -0.5];
        let m = vec![1.0f32; 3];
        assert!(k1(&lp, &lp, &m).abs() < 1e-9);
        assert!(k2(&lp, &lp, &m).abs() < 1e-9);
        assert!(k3(&lp, &lp, &m).abs() < 1e-9);
        assert!((max_ratio(&lp, &lp, &m) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn k3_nonnegative() {
        let lp_p = vec![-1.0f32, -3.0, -0.2, -5.0];
        let lp_q = vec![-1.5f32, -2.0, -0.9, -4.0];
        let m = vec![1.0f32; 4];
        assert!(k3(&lp_p, &lp_q, &m) >= 0.0);
        assert!(k2(&lp_p, &lp_q, &m) >= 0.0);
    }

    #[test]
    fn mask_excludes_tokens() {
        let lp_p = vec![0.0f32, -10.0];
        let lp_q = vec![0.0f32, 0.0];
        let m = vec![1.0f32, 0.0];
        assert!(k1(&lp_p, &lp_q, &m).abs() < 1e-9);
    }

    #[test]
    fn known_value_k1() {
        // p assigns lp=-1, q lp=-2 on the single sampled token: k1 = 1
        assert!((k1(&[-1.0], &[-2.0], &[1.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn max_ratio_picks_max() {
        let lp_prox = vec![0.0f32, 0.0];
        let lp_behav = vec![-2.0f32, -4.0];
        let m = vec![1.0f32; 2];
        let r = max_ratio(&lp_prox, &lp_behav, &m);
        assert!((r - (4.0f64).exp()).abs() < 1e-6);
    }
}
