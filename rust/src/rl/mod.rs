//! The RL layer: objectives (paper §4 — naive / decoupled / TIS / ACR),
//! advantage estimation (GRPO / RLOO / GAE), DAPO dynamic sampling, KL
//! estimators, evaluation protocols and the training loop.

pub mod advantage;
pub mod dapo;
pub mod eval;
pub mod schedule;
pub mod kl;
pub mod objective;
pub mod trainer;

pub use objective::{Objective, ObjectiveKind};
pub use trainer::{pretrain_sft, Algo, RolloutExec, RolloutPath, Sample,
                  Trainer, TrainerConfig};
