//! The RL layer: objectives (paper §4 — naive / decoupled / TIS / ACR),
//! advantage estimation (GRPO / RLOO / GAE), DAPO dynamic sampling, KL
//! estimators, evaluation protocols and the training loop.
//!
//! # Trainer → checkpoint flow
//!
//! [`Trainer::run`] is the checkpoint/resume seam ([`checkpoint`] holds
//! the format and protocol): every `--ckpt-every` steps it snapshots, at a
//! step boundary, the [`ParamStore`](crate::runtime::ParamStore) (weights
//! + Adam moments), the reference policy, the trainer's
//! [`Pcg64`](crate::util::rng::Pcg64) position, the rollout seed cursor,
//! the requant cadence (`engine_age` + the params the engine was last
//! quantized from), the Fig. 9 analysis snapshot, and — on the scheduler
//! path — the [`ServiceSnapshot`](crate::coordinator::ServiceSnapshot).
//! `--resume` restores all of that before the step loop, rebuilds the
//! engine from the *saved* quantization source, and re-stamps the rebuilt
//! service with the restored weight epoch, making the continued run
//! bit-identical to one that never stopped (integration-tested on the
//! mock engine, including crash-mid-step recovery).

pub mod advantage;
pub mod checkpoint;
pub mod dapo;
pub mod eval;
pub mod schedule;
pub mod kl;
pub mod objective;
pub mod trainer;

pub use checkpoint::{CheckpointError, CheckpointManifest};
pub use objective::{Objective, ObjectiveKind};
pub use trainer::{pretrain_sft, Algo, RolloutExec, RolloutPath, Sample,
                  Trainer, TrainerConfig};
