//! RL objective configuration — the paper's axis of comparison (§4).
//!
//! The train_step artifact implements all variants behind a runtime flag
//! vector; this module is the typed Rust side of that contract.

use crate::runtime::manifest::FlagIndex;

/// Which surrogate objective the train step optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Eq. 1: standard PPO/GRPO clip against the full-precision old actor.
    /// With a quantized rollout engine this *ignores* the behavior mismatch
    /// (the paper's "RL" rows in Tables 1-3).
    OnPolicy,
    /// Eq. 3: importance sampling + clipping against the *quantized* old
    /// actor — the unstable naive combination (collapses in Fig. 2).
    NaiveQuant,
    /// Eq. 4: decoupled PPO (behavior = quantized, proximal = fp) without
    /// truncation — unbounded prox/behav gradient factor.
    Decoupled,
    /// Eq. 5: FlashRL's Truncated Importance Sampling (factor min(rho, C)).
    Tis,
    /// Eq. 9: QuRL's Adaptive Clipping Range — TIS + upper clip bound
    /// (1+eps)/r for truncated tokens.
    Acr,
}

impl ObjectiveKind {
    pub fn mode_flag(&self) -> f32 {
        match self {
            ObjectiveKind::OnPolicy => 0.0,
            ObjectiveKind::NaiveQuant => 1.0,
            ObjectiveKind::Decoupled => 2.0,
            ObjectiveKind::Tis => 3.0,
            ObjectiveKind::Acr => 4.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::OnPolicy => "onpolicy",
            ObjectiveKind::NaiveQuant => "naive",
            ObjectiveKind::Decoupled => "decoupled",
            ObjectiveKind::Tis => "tis",
            ObjectiveKind::Acr => "acr",
        }
    }

    pub fn parse(s: &str) -> Option<ObjectiveKind> {
        match s {
            "onpolicy" | "rl" => Some(ObjectiveKind::OnPolicy),
            "naive" => Some(ObjectiveKind::NaiveQuant),
            "decoupled" => Some(ObjectiveKind::Decoupled),
            "tis" | "flashrl" => Some(ObjectiveKind::Tis),
            "acr" | "qurl" => Some(ObjectiveKind::Acr),
            _ => None,
        }
    }
}

/// Full hyperparameter set of one train step.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub kind: ObjectiveKind,
    pub eps_low: f32,
    pub eps_high: f32,
    /// TIS truncation cap C (Eq. 5/9)
    pub tis_cap: f32,
    pub kl_coef: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    /// DAPO token-mean aggregation (vs GRPO per-sequence mean)
    pub token_mean: bool,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub weight_decay: f32,
    pub value_clip: f32,
    pub max_grad_norm: f32,
}

impl Default for Objective {
    fn default() -> Self {
        Objective {
            kind: ObjectiveKind::Acr,
            eps_low: 0.2,
            eps_high: 0.2,
            tis_cap: 2.0,
            kl_coef: 0.0,
            vf_coef: 0.0,
            ent_coef: 0.0,
            token_mean: false,
            lr: 1e-6,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            weight_decay: 0.0,
            value_clip: 0.2,
            max_grad_norm: 1.0,
        }
    }
}

impl Objective {
    /// Encode into the artifact's flag vector.
    pub fn to_flags(&self, idx: &FlagIndex) -> Vec<f32> {
        let mut f = vec![0.0f32; idx.n];
        f[idx.obj_mode] = self.kind.mode_flag();
        f[idx.eps_low] = self.eps_low;
        f[idx.eps_high] = self.eps_high;
        f[idx.tis_cap] = self.tis_cap;
        f[idx.kl_coef] = self.kl_coef;
        f[idx.vf_coef] = self.vf_coef;
        f[idx.ent_coef] = self.ent_coef;
        f[idx.token_mean] = if self.token_mean { 1.0 } else { 0.0 };
        f[idx.lr] = self.lr;
        f[idx.beta1] = self.beta1;
        f[idx.beta2] = self.beta2;
        f[idx.adam_eps] = self.adam_eps;
        f[idx.weight_decay] = self.weight_decay;
        f[idx.value_clip] = self.value_clip;
        f[idx.max_grad_norm] = self.max_grad_norm;
        f
    }
}

/// Host-side reference of the per-token surrogate (mirrors model.rl_loss);
/// used by unit tests to validate the artifact and by the objective-algebra
/// property tests (clip-bound ordering, ACR >= TIS surrogates, ...).
pub fn surrogate_token(obj: &Objective, lp_theta: f32, lp_behav: f32,
                       lp_prox: f32, adv: f32) -> f32 {
    let clip20 = |x: f32| x.clamp(-20.0, 20.0);
    let ratio_prox = clip20(lp_theta - lp_prox).exp();
    let ratio_behav = clip20(lp_theta - lp_behav).exp();
    let rho = clip20(lp_prox - lp_behav).exp();
    let tis_w = rho.min(obj.tis_cap);
    let r = tis_w / rho;
    let (ratio, factor, hi) = match obj.kind {
        ObjectiveKind::OnPolicy => (ratio_prox, 1.0, 1.0 + obj.eps_high),
        ObjectiveKind::NaiveQuant => (ratio_behav, 1.0, 1.0 + obj.eps_high),
        ObjectiveKind::Decoupled => (ratio_prox, rho, 1.0 + obj.eps_high),
        ObjectiveKind::Tis => (ratio_prox, tis_w, 1.0 + obj.eps_high),
        ObjectiveKind::Acr => (ratio_prox, tis_w, (1.0 + obj.eps_high) / r),
    };
    let lo = 1.0 - obj.eps_low;
    factor * (ratio * adv).min(ratio.clamp(lo, hi) * adv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(kind: ObjectiveKind) -> Objective {
        Objective { kind, eps_low: 0.2, eps_high: 0.28, tis_cap: 2.0,
                    ..Default::default() }
    }

    #[test]
    fn onpolicy_matches_ppo_clip() {
        let o = obj(ObjectiveKind::OnPolicy);
        // ratio 1.5 > 1.28 with positive advantage -> clipped at 1.28
        let lp_theta = 0.405_f32; // ln(1.5)
        let s = surrogate_token(&o, lp_theta, 0.0, 0.0, 1.0);
        assert!((s - 1.28).abs() < 1e-4, "{s}");
        // negative advantage: unclipped branch is the min
        let s = surrogate_token(&o, lp_theta, 0.0, 0.0, -1.0);
        assert!((s + 1.5).abs() < 1e-3, "{s}");
    }

    #[test]
    fn acr_enlarges_upper_bound_when_truncated() {
        // rho = 4 > C = 2 -> r = 0.5 -> ACR hi = 1.28/0.5 = 2.56
        let lp_prox = 0.0_f32;
        let lp_behav = -(4.0_f32.ln());
        let lp_theta = 2.0_f32.ln(); // ratio_prox = 2.0
        let adv = 1.0;
        let tis = surrogate_token(&obj(ObjectiveKind::Tis), lp_theta, lp_behav,
                                  lp_prox, adv);
        let acr = surrogate_token(&obj(ObjectiveKind::Acr), lp_theta, lp_behav,
                                  lp_prox, adv);
        // TIS clips ratio 2.0 to 1.28 (x factor 2) = 2.56;
        // ACR lets it through: 2.0 x 2 = 4.0
        assert!((tis - 2.56).abs() < 1e-3, "{tis}");
        assert!((acr - 4.0).abs() < 1e-3, "{acr}");
        assert!(acr >= tis);
    }

    #[test]
    fn acr_equals_tis_when_not_truncated() {
        // rho <= C -> r = 1 -> identical objectives
        for lp_theta in [-0.5f32, 0.0, 0.3] {
            let tis = surrogate_token(&obj(ObjectiveKind::Tis), lp_theta,
                                      -0.1, 0.0, 0.7);
            let acr = surrogate_token(&obj(ObjectiveKind::Acr), lp_theta,
                                      -0.1, 0.0, 0.7);
            assert!((tis - acr).abs() < 1e-6);
        }
    }

    #[test]
    fn decoupled_factor_unbounded() {
        // extreme rho shows the Fig. 3b gradient blow-up TIS prevents
        let lp_behav = -10.0_f32;
        let dec = surrogate_token(&obj(ObjectiveKind::Decoupled), 0.0,
                                  lp_behav, 0.0, 1.0);
        let tis = surrogate_token(&obj(ObjectiveKind::Tis), 0.0, lp_behav,
                                  0.0, 1.0);
        assert!(dec > 1000.0 * tis / 2.0, "dec={dec} tis={tis}");
    }

    #[test]
    fn flags_roundtrip_indices() {
        let idx = FlagIndex {
            obj_mode: 0, eps_low: 1, eps_high: 2, tis_cap: 3, kl_coef: 4,
            vf_coef: 5, ent_coef: 6, token_mean: 7, lr: 8, beta1: 9,
            beta2: 10, adam_eps: 11, weight_decay: 12, value_clip: 13,
            max_grad_norm: 14, n: 15,
        };
        let o = Objective { kind: ObjectiveKind::Tis, lr: 3e-6,
                            token_mean: true, ..Default::default() };
        let f = o.to_flags(&idx);
        assert_eq!(f.len(), 15);
        assert_eq!(f[0], 3.0);
        assert_eq!(f[7], 1.0);
        assert!((f[8] - 3e-6).abs() < 1e-12);
    }
}
