//! Multi-stage training schedules — the DeepScaleR recipe (§5.1): three
//! stages at 8k/16k/24k context with growing rollouts per query.  This
//! testbed's analog scales task *difficulty* and group size per stage
//! (context length is fixed by the AOT artifacts; DESIGN.md §2).

/// One stage of a staged RL run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stage {
    /// first step (inclusive) this stage applies to
    pub from_step: usize,
    /// task difficulty fed to the problem sampler
    pub difficulty: usize,
    /// rollouts per prompt (the paper grows 8 -> 16)
    pub group_size: usize,
    /// sampling temperature for rollouts
    pub temp: f32,
}

#[derive(Clone, Debug)]
pub struct Schedule {
    stages: Vec<Stage>,
}

impl Schedule {
    /// Single-stage schedule (the default for PPO/DAPO experiments).
    pub fn constant(difficulty: usize, group_size: usize, temp: f32) -> Self {
        Schedule {
            stages: vec![Stage { from_step: 0, difficulty, group_size, temp }],
        }
    }

    /// The DeepScaleR 3-stage analog over a total horizon: the paper runs
    /// 800 steps @8k/8 rollouts, then 400 @16k/16, then 400 @24k/16 —
    /// proportions 0.5 / 0.25 / 0.25 of the horizon.
    pub fn deepscaler(total_steps: usize, base_difficulty: usize,
                      group_size: usize) -> Self {
        let s1 = total_steps / 2;
        let s2 = s1 + total_steps / 4;
        Schedule {
            stages: vec![
                Stage { from_step: 0, difficulty: base_difficulty,
                        group_size, temp: 1.0 },
                Stage { from_step: s1, difficulty: base_difficulty + 1,
                        group_size: group_size * 2, temp: 1.0 },
                Stage { from_step: s2, difficulty: (base_difficulty + 2).min(3),
                        group_size: group_size * 2, temp: 1.0 },
            ],
        }
    }

    pub fn from_stages(mut stages: Vec<Stage>) -> Self {
        assert!(!stages.is_empty());
        stages.sort_by_key(|s| s.from_step);
        assert_eq!(stages[0].from_step, 0, "first stage must start at 0");
        Schedule { stages }
    }

    /// The stage in effect at `step`.
    pub fn at(&self, step: usize) -> Stage {
        let mut cur = self.stages[0];
        for s in &self.stages {
            if s.from_step <= step {
                cur = *s;
            } else {
                break;
            }
        }
        cur
    }

    /// True when `step` is the first step of a new stage (> 0) — trainers
    /// reset optimizer state on stage boundaries like the paper's restarts.
    pub fn is_boundary(&self, step: usize) -> bool {
        step > 0 && self.stages.iter().any(|s| s.from_step == step)
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_flat() {
        let s = Schedule::constant(2, 8, 1.0);
        assert_eq!(s.at(0), s.at(10_000));
        assert!(!s.is_boundary(500));
    }

    #[test]
    fn deepscaler_three_stages() {
        let s = Schedule::deepscaler(800, 1, 8);
        assert_eq!(s.n_stages(), 3);
        assert_eq!(s.at(0).difficulty, 1);
        assert_eq!(s.at(0).group_size, 8);
        assert_eq!(s.at(399).difficulty, 1);
        assert_eq!(s.at(400).difficulty, 2);
        assert_eq!(s.at(400).group_size, 16);
        assert_eq!(s.at(799).difficulty, 3);
        assert!(s.is_boundary(400));
        assert!(s.is_boundary(600));
        assert!(!s.is_boundary(401));
    }

    #[test]
    fn difficulty_caps_at_three() {
        let s = Schedule::deepscaler(100, 3, 8);
        assert_eq!(s.at(99).difficulty, 3);
    }

    #[test]
    fn stages_sorted_and_selected() {
        let s = Schedule::from_stages(vec![
            Stage { from_step: 50, difficulty: 2, group_size: 4, temp: 0.8 },
            Stage { from_step: 0, difficulty: 0, group_size: 2, temp: 1.0 },
        ]);
        assert_eq!(s.at(49).difficulty, 0);
        assert_eq!(s.at(50).temp, 0.8);
    }

    #[test]
    #[should_panic]
    fn must_start_at_zero() {
        let _ = Schedule::from_stages(vec![Stage {
            from_step: 5, difficulty: 0, group_size: 2, temp: 1.0,
        }]);
    }
}
