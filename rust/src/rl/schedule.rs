//! Multi-stage training schedules — the DeepScaleR recipe (§5.1): three
//! stages at 8k/16k/24k context with growing rollouts per query.  This
//! testbed's analog scales task *difficulty* and group size per stage
//! (context length is fixed by the AOT artifacts; DESIGN.md §2).
//!
//! A [`Schedule`] is a pure function of the step counter — it carries no
//! cursor — so checkpoint/resume ([`crate::rl::checkpoint`]) needs only
//! the step number plus the stage table itself, which
//! [`Schedule::to_json`]/[`Schedule::from_json`] round-trip into the
//! manifest (a resumed run must refuse a silently edited stage table the
//! same way it refuses a changed `TrainerConfig`).

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One stage of a staged RL run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stage {
    /// first step (inclusive) this stage applies to
    pub from_step: usize,
    /// task difficulty fed to the problem sampler
    pub difficulty: usize,
    /// rollouts per prompt (the paper grows 8 -> 16)
    pub group_size: usize,
    /// sampling temperature for rollouts
    pub temp: f32,
}

#[derive(Clone, Debug)]
pub struct Schedule {
    stages: Vec<Stage>,
}

impl Schedule {
    /// Single-stage schedule (the default for PPO/DAPO experiments).
    pub fn constant(difficulty: usize, group_size: usize, temp: f32) -> Self {
        Schedule {
            stages: vec![Stage { from_step: 0, difficulty, group_size, temp }],
        }
    }

    /// The DeepScaleR 3-stage analog over a total horizon: the paper runs
    /// 800 steps @8k/8 rollouts, then 400 @16k/16, then 400 @24k/16 —
    /// proportions 0.5 / 0.25 / 0.25 of the horizon.
    pub fn deepscaler(total_steps: usize, base_difficulty: usize,
                      group_size: usize) -> Self {
        let s1 = total_steps / 2;
        let s2 = s1 + total_steps / 4;
        Schedule {
            stages: vec![
                Stage { from_step: 0, difficulty: base_difficulty,
                        group_size, temp: 1.0 },
                Stage { from_step: s1, difficulty: base_difficulty + 1,
                        group_size: group_size * 2, temp: 1.0 },
                Stage { from_step: s2, difficulty: (base_difficulty + 2).min(3),
                        group_size: group_size * 2, temp: 1.0 },
            ],
        }
    }

    pub fn from_stages(mut stages: Vec<Stage>) -> Self {
        assert!(!stages.is_empty());
        stages.sort_by_key(|s| s.from_step);
        assert_eq!(stages[0].from_step, 0, "first stage must start at 0");
        Schedule { stages }
    }

    /// The stage in effect at `step`.
    pub fn at(&self, step: usize) -> Stage {
        let mut cur = self.stages[0];
        for s in &self.stages {
            if s.from_step <= step {
                cur = *s;
            } else {
                break;
            }
        }
        cur
    }

    /// True when `step` is the first step of a new stage (> 0) — trainers
    /// reset optimizer state on stage boundaries like the paper's restarts.
    pub fn is_boundary(&self, step: usize) -> bool {
        step > 0 && self.stages.iter().any(|s| s.from_step == step)
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Serialize the stage table (checkpoint-manifest payload).  `temp` is
    /// stored via `f32 -> f64` widening, which is exact, so the round trip
    /// is bit-preserving.
    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("from_step", Json::num(s.from_step as f64)),
                    ("difficulty", Json::num(s.difficulty as f64)),
                    ("group_size", Json::num(s.group_size as f64)),
                    ("temp", Json::num(s.temp as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("stages", Json::Arr(stages))])
    }

    /// Parse a [`Self::to_json`] stage table; typed errors on shape
    /// violations (missing array, bad field, empty table, nonzero first
    /// stage) rather than panics — this runs on the resume path.
    pub fn from_json(j: &Json) -> Result<Schedule> {
        let arr = j
            .get("stages")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("schedule: missing \"stages\" array"))?;
        if arr.is_empty() {
            return Err(anyhow!("schedule: empty stage table"));
        }
        let mut stages = Vec::with_capacity(arr.len());
        for (i, s) in arr.iter().enumerate() {
            let field = |k: &str| {
                s.get(k).and_then(|v| v.as_usize()).ok_or_else(|| {
                    anyhow!("schedule stage {i}: bad field {k:?}")
                })
            };
            let temp = s
                .get("temp")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("schedule stage {i}: bad field \"temp\""))?
                as f32;
            stages.push(Stage {
                from_step: field("from_step")?,
                difficulty: field("difficulty")?,
                group_size: field("group_size")?,
                temp,
            });
        }
        stages.sort_by_key(|s| s.from_step);
        if stages[0].from_step != 0 {
            return Err(anyhow!("schedule: first stage must start at step 0"));
        }
        Ok(Schedule { stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_flat() {
        let s = Schedule::constant(2, 8, 1.0);
        assert_eq!(s.at(0), s.at(10_000));
        assert!(!s.is_boundary(500));
    }

    #[test]
    fn deepscaler_three_stages() {
        let s = Schedule::deepscaler(800, 1, 8);
        assert_eq!(s.n_stages(), 3);
        assert_eq!(s.at(0).difficulty, 1);
        assert_eq!(s.at(0).group_size, 8);
        assert_eq!(s.at(399).difficulty, 1);
        assert_eq!(s.at(400).difficulty, 2);
        assert_eq!(s.at(400).group_size, 16);
        assert_eq!(s.at(799).difficulty, 3);
        assert!(s.is_boundary(400));
        assert!(s.is_boundary(600));
        assert!(!s.is_boundary(401));
    }

    #[test]
    fn difficulty_caps_at_three() {
        let s = Schedule::deepscaler(100, 3, 8);
        assert_eq!(s.at(99).difficulty, 3);
    }

    #[test]
    fn stages_sorted_and_selected() {
        let s = Schedule::from_stages(vec![
            Stage { from_step: 50, difficulty: 2, group_size: 4, temp: 0.8 },
            Stage { from_step: 0, difficulty: 0, group_size: 2, temp: 1.0 },
        ]);
        assert_eq!(s.at(49).difficulty, 0);
        assert_eq!(s.at(50).temp, 0.8);
    }

    /// Checkpoint contract: the stage table JSON round-trips exactly
    /// (including f32 temps), and malformed tables are typed errors.
    #[test]
    fn json_roundtrip_preserves_stages() {
        let s = Schedule::deepscaler(800, 1, 8);
        let text = s.to_json().to_string();
        let back = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_stages(), 3);
        for step in [0, 399, 400, 600, 799, 10_000] {
            assert_eq!(s.at(step), back.at(step), "stage drift at {step}");
        }
        let odd = Schedule::from_stages(vec![
            Stage { from_step: 0, difficulty: 1, group_size: 4, temp: 0.7 },
            Stage { from_step: 9, difficulty: 2, group_size: 8, temp: 1.3 },
        ]);
        let back =
            Schedule::from_json(&Json::parse(&odd.to_json().to_string())
                .unwrap()).unwrap();
        assert_eq!(back.at(9).temp.to_bits(), 1.3f32.to_bits(),
                   "temp must round-trip bit-exactly");
        for bad in ["{}", r#"{"stages": []}"#,
                    r#"{"stages": [{"from_step": 5, "difficulty": 1,
                                    "group_size": 2, "temp": 1.0}]}"#] {
            assert!(Schedule::from_json(&Json::parse(bad).unwrap()).is_err(),
                    "accepted malformed schedule: {bad}");
        }
    }

    #[test]
    #[should_panic]
    fn must_start_at_zero() {
        let _ = Schedule::from_stages(vec![Stage {
            from_step: 5, difficulty: 0, group_size: 2, temp: 1.0,
        }]);
    }
}
