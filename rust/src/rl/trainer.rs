//! The QuRL training loop (paper Fig. 1): quantize the old actor, roll out
//! on the quantized engine, score behavior/proximal/reference logprobs,
//! estimate advantages (GRPO/PPO/DAPO), and update the full-precision actor
//! with the selected objective (on-policy / naive / decoupled / TIS / ACR).
//!
//! Python never runs here: rollout, scoring, quantization and optimization
//! are all AOT artifacts executed through the PJRT runtime.

use anyhow::Result;

use crate::metrics::{Recorder, Row};
use crate::quant::analysis;
use crate::runtime::{EngineWeights, ParamStore, QuantMode, Runtime, TrainBatch};
use crate::tasks::{encode_batch, Problem, Suite, Tokenizer};
use crate::util::rng::Pcg64;
use crate::util::stats;

use super::advantage;
use super::dapo::DynamicSampler;
use super::eval;
use super::kl;
use super::objective::Objective;

/// RL algorithm family (the paper evaluates all three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// GRPO: group-normalized advantages, optional KL-to-reference.
    Grpo,
    /// PPO: GAE advantages from the value head, clipped value loss.
    Ppo,
    /// DAPO: GRPO advantages + dynamic sampling + decoupled clip +
    /// token-mean aggregation.
    Dapo,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "grpo" => Some(Algo::Grpo),
            "ppo" => Some(Algo::Ppo),
            "dapo" => Some(Algo::Dapo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Grpo => "grpo",
            Algo::Ppo => "ppo",
            Algo::Dapo => "dapo",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub algo: Algo,
    pub objective: Objective,
    /// rollout engine precision — the QuRL axis
    pub rollout_mode: QuantMode,
    pub suite: String,
    /// UAQ invariant scale s (1.0 disables; paper default 1.5)
    pub uaq_scale: f32,
    pub steps: usize,
    /// distinct prompts per RL step (each expanded group_size times)
    pub prompts_per_step: usize,
    pub group_size: usize,
    pub temp: f32,
    pub top_p: f32,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_problems_per_family: usize,
    /// std-dev of Gaussian noise injected into behavior logprobs — the
    /// controlled stand-in for FlashRL's training/inference engine mismatch
    pub engine_noise: f32,
    /// PPO-style epochs over each rollout batch (>1 makes clipping bind)
    pub inner_epochs: usize,
    /// GAE parameters (PPO)
    pub gamma: f32,
    pub gae_lambda: f32,
    pub whiten_adv: bool,
    /// dynamic sampling (DAPO) on/off
    pub dynamic_sampling: bool,
    /// re-quantize engine weights every k steps (1 = every step, paper setup)
    pub requantize_every: usize,
    /// compute Fig. 4/9 weight-change analysis every k steps (0 = never)
    pub analyze_every: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            algo: Algo::Grpo,
            objective: Objective::default(),
            rollout_mode: QuantMode::Int8,
            suite: "deepscaler".into(),
            uaq_scale: 1.0,
            steps: 100,
            prompts_per_step: 8,
            group_size: 8,
            temp: 1.0,
            top_p: 1.0,
            seed: 0,
            eval_every: 0,
            eval_problems_per_family: 32,
            engine_noise: 0.0,
            inner_epochs: 2,
            gamma: 1.0,
            gae_lambda: 0.95,
            whiten_adv: false,
            dynamic_sampling: false,
            requantize_every: 1,
            analyze_every: 0,
        }
    }
}

/// One rolled-out sequence with its verification outcome.
#[derive(Clone, Debug)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub lp_behav: Vec<f32>,
    pub mask: Vec<f32>,
    pub prompt_len: usize,
    pub reward: f32,
    /// index of the problem (group id) this sample answers
    pub group: usize,
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: TrainerConfig,
    pub ps: ParamStore,
    /// frozen reference policy for the KL term (the SFT base model)
    pub ref_params: Vec<f32>,
    pub tk: Tokenizer,
    pub suite: Suite,
    pub rec: Recorder,
    rng: Pcg64,
    rollout_seed: i32,
    engine: Option<EngineWeights>,
    engine_age: usize,
    /// previous-step section-B snapshot for the Fig. 9 analysis
    prev_params: Option<Vec<f32>>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainerConfig, base: ParamStore,
               rec: Recorder) -> Result<Self> {
        let suite = Suite::by_name(&cfg.suite)
            .ok_or_else(|| anyhow::anyhow!("unknown suite {:?}", cfg.suite))?;
        let mut ps = base;
        // UAQ: one-shot invariant rescaling before RL begins (§4.3)
        if (cfg.uaq_scale - 1.0).abs() > 1e-6 {
            ps.params = rt.uaq_scale(&ps.params, cfg.uaq_scale)?;
        }
        ps.reset_optimizer();
        let ref_params = ps.params.clone();
        let rng = Pcg64::new(cfg.seed ^ 0x5152_4c00);
        Ok(Trainer {
            rt,
            rng,
            rollout_seed: (cfg.seed as i32) ^ 0x2f2f,
            tk: Tokenizer::new(),
            suite,
            rec,
            ps,
            ref_params,
            cfg,
            engine: None,
            engine_age: usize::MAX,
            prev_params: None,
        })
    }

    /// Quantized (or fp) rollout-engine weights, refreshed per the
    /// requantize schedule.  This is the Q(theta_old) step of Fig. 1.
    fn refresh_engine(&mut self) -> Result<()> {
        if self.engine_age < self.cfg.requantize_every {
            self.engine_age += 1;
            return Ok(());
        }
        self.engine =
            Some(self.rt.engine_weights(self.cfg.rollout_mode, &self.ps.params)?);
        self.engine_age = 1;
        Ok(())
    }

    /// Roll out `problems` (already group-expanded) in rollout_batch waves.
    pub fn rollout(&mut self, problems: &[(usize, &Problem)]) -> Result<Vec<Sample>> {
        let man = self.rt.manifest();
        let (b, s) = (man.rollout_batch, man.max_seq);
        let mut out = Vec::with_capacity(problems.len());
        let engine = self.engine.as_ref().expect("engine not initialized");
        for wave in problems.chunks(b) {
            let refs: Vec<&Problem> = wave.iter().map(|(_, p)| *p).collect();
            let (tokens, lens) = encode_batch(&self.tk, &refs, b, s, man.max_prompt);
            self.rollout_seed = self.rollout_seed.wrapping_add(1);
            let gen = self.rt.generate(engine, &tokens, &lens,
                                       self.rollout_seed, self.cfg.temp,
                                       self.cfg.top_p)?;
            for (r, (group, prob)) in wave.iter().enumerate() {
                let row = &gen.tokens[r * s..(r + 1) * s];
                let mut lp = gen.logprob[r * s..(r + 1) * s].to_vec();
                let mask = gen.mask[r * s..(r + 1) * s].to_vec();
                // engine-mismatch simulation (FlashRL's HF-vs-vLLM gap)
                if self.cfg.engine_noise > 0.0 {
                    for (l, &m) in lp.iter_mut().zip(&mask) {
                        if m > 0.5 {
                            *l += (self.rng.normal() as f32) * self.cfg.engine_noise;
                        }
                    }
                }
                let plen = lens[r] as usize;
                let gen_text = self.tk.decode_generation(row, plen);
                let reward = crate::tasks::verify(prob, &gen_text);
                out.push(Sample {
                    tokens: row.to_vec(),
                    lp_behav: lp,
                    mask,
                    prompt_len: plen,
                    reward,
                    group: *group,
                });
            }
        }
        Ok(out)
    }

    /// Collect one RL step's samples (with DAPO dynamic sampling when on).
    fn collect(&mut self, step: usize) -> Result<Vec<Sample>> {
        let g = self.cfg.group_size;
        let n_prompts = self.cfg.prompts_per_step;
        let mut sampler = self.suite.train_sampler(self.cfg.seed
            .wrapping_add(step as u64 * 7919));
        if !self.cfg.dynamic_sampling {
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            return self.rollout(&expanded);
        }
        // DAPO: resample until enough informative groups
        let mut ds = DynamicSampler::new(g, n_prompts);
        let mut kept: Vec<Sample> = Vec::new();
        while !ds.done() {
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            let samples = self.rollout(&expanded)?;
            let rewards: Vec<f32> = samples.iter().map(|x| x.reward).collect();
            let keep_groups = ds.offer(&rewards);
            let base = kept.len() / g;
            for (new_gid, gid) in keep_groups.iter().enumerate() {
                for r in 0..g {
                    let mut smp = samples[gid * g + r].clone();
                    smp.group = base + new_gid;
                    kept.push(smp);
                }
            }
        }
        if kept.is_empty() {
            // degenerate (all groups uniform): fall back to the last wave
            crate::warnln!("trainer", "dynamic sampling found no signal; \
                            falling back to plain sampling");
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            kept = self.rollout(&expanded)?;
        }
        self.rec.log(Row::new(step as u64)
            .set("dapo_efficiency", ds.efficiency())
            .tag("phase", "sampling"));
        Ok(kept)
    }

    /// Assemble [B, T] grids from samples (padding with inert rows).
    fn grids(&self, samples: &[Sample]) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
        let man = self.rt.manifest();
        let (b, t) = (man.train_batch, man.max_seq);
        assert!(samples.len() <= b);
        let mut tokens = vec![crate::tasks::PAD; b * t];
        let mut mask = vec![0.0f32; b * t];
        let mut lp_behav = vec![0.0f32; b * t];
        for (r, smp) in samples.iter().enumerate() {
            tokens[r * t..(r + 1) * t].copy_from_slice(&smp.tokens);
            mask[r * t..(r + 1) * t].copy_from_slice(&smp.mask);
            lp_behav[r * t..(r + 1) * t].copy_from_slice(&smp.lp_behav);
        }
        for r in samples.len()..b {
            tokens[r * t] = crate::tasks::BOS;
        }
        (tokens, mask, lp_behav)
    }

    /// Run one full RL step; returns the mean training reward.
    pub fn step(&mut self, step: usize) -> Result<f64> {
        let man = self.rt.manifest().clone();
        let (bt, t) = (man.train_batch, man.max_seq);
        self.refresh_engine()?;
        let samples = self.collect(step)?;
        let mean_reward =
            stats::mean_f32(&samples.iter().map(|s| s.reward).collect::<Vec<_>>());

        // Fig. 4/9 analysis: weight update vs quantization noise
        if self.cfg.analyze_every > 0 && step % self.cfg.analyze_every == 0 {
            let b_now = self.ps.section_b().to_vec();
            if let Some(prev) = &self.prev_params {
                let upd = analysis::normalized_weight_update(prev, &self.ps.params);
                let prev_b = &prev[man.a_size..];
                let code_change =
                    analysis::int8_code_change_fraction(&man, prev_b, &b_now);
                self.rec.log(Row::new(step as u64)
                    .set("norm_weight_update", upd)
                    .set("int8_code_change_frac", code_change)
                    .tag("phase", "analysis"));
            }
            let qerr = analysis::normalized_quant_error(
                &man, &b_now, self.cfg.rollout_mode);
            self.rec.log(Row::new(step as u64)
                .set("norm_quant_error", qerr)
                .tag("phase", "analysis"));
            self.prev_params = Some(self.ps.params.clone());
        }

        // process in train_batch chunks
        let mut metric_acc: Vec<f64> = vec![0.0; man.metric_names.len()];
        let mut metric_n = 0usize;
        let mut kl_bp_acc = 0.0f64;
        let mut rho_max_all = 0.0f64;
        for chunk in samples.chunks(bt) {
            let (tokens, mask, lp_behav) = self.grids(chunk);
            // proximal policy = full-precision theta_old (pre-update)
            let prox = self.rt.score_bf16(&self.ps.params, &tokens)?;
            let lp_ref = if self.cfg.objective.kl_coef > 0.0 {
                self.rt.score_bf16(&self.ref_params, &tokens)?.logprob
            } else {
                vec![0.0f32; bt * t]
            };
            kl_bp_acc += kl::k1(&lp_behav, &prox.logprob, &mask);
            rho_max_all =
                rho_max_all.max(kl::max_ratio(&prox.logprob, &lp_behav, &mask));

            // advantages
            let rewards: Vec<f32> = chunk.iter().map(|s| s.reward).collect();
            let (mut adv, returns) = match self.cfg.algo {
                Algo::Grpo | Algo::Dapo => {
                    let g = self.cfg.group_size.min(rewards.len().max(1));
                    let padded_g = if g > 0 && rewards.len() % g == 0 { g } else { 1 };
                    let mut a = advantage::grpo(&rewards, padded_g);
                    // pad to the full train grid (inert rows get zeros)
                    let mut rw = rewards.clone();
                    a.resize(bt, 0.0);
                    rw.resize(bt, 0.0);
                    advantage::broadcast_sequence_adv(&a, &rw, &mask, bt, t)
                }
                Algo::Ppo => {
                    let mut adv = vec![0.0f32; bt * t];
                    let mut ret = vec![0.0f32; bt * t];
                    for (r, smp) in chunk.iter().enumerate() {
                        // values over the generated span
                        let span: Vec<usize> = (0..t)
                            .filter(|&c| smp.mask[c] > 0.5)
                            .collect();
                        let vals: Vec<f32> =
                            span.iter().map(|&c| prox.value[r * t + c]).collect();
                        let (a, rt_) = advantage::gae(&vals, smp.reward,
                                                      self.cfg.gamma,
                                                      self.cfg.gae_lambda);
                        for (k, &c) in span.iter().enumerate() {
                            adv[r * t + c] = a[k];
                            ret[r * t + c] = rt_[k];
                        }
                    }
                    (adv, ret)
                }
            };
            // pad adv grid to full [bt, t] (broadcast helper handled b<=bt)
            adv.resize(bt * t, 0.0);
            let mut returns = returns;
            returns.resize(bt * t, 0.0);
            if self.cfg.whiten_adv {
                advantage::whiten(&mut adv, &mask);
            }

            let batch = TrainBatch {
                tokens,
                mask,
                adv,
                lp_behav,
                lp_prox: prox.logprob.clone(),
                lp_ref,
                returns,
                old_values: prox.value.clone(),
            };
            let flags = self.cfg.objective.to_flags(&man.flags);
            for _ in 0..self.cfg.inner_epochs.max(1) {
                let mets = self.rt.train_step(&mut self.ps, &batch, &flags)?;
                for (i, &m) in mets.iter().enumerate() {
                    if i < metric_acc.len() {
                        metric_acc[i] += m as f64;
                    }
                }
                metric_n += 1;
            }
        }

        let chunks = samples.chunks(bt).len().max(1);
        let mut row = Row::new(step as u64)
            .set("reward", mean_reward)
            .set("kl_behav_prox", kl_bp_acc / chunks as f64)
            .set("rho_max", rho_max_all)
            .set("n_samples", samples.len() as f64)
            .tag("phase", "train");
        if metric_n > 0 {
            for (i, name) in man.metric_names.iter().enumerate() {
                row = row.set(name, metric_acc[i] / metric_n as f64);
            }
        }
        self.rec.log(row);

        // periodic evaluation
        if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
            let engine = self.engine.clone().expect("engine");
            let acc = eval::greedy_accuracy(
                self.rt, &engine, &self.tk, &self.suite,
                self.cfg.seed, self.cfg.eval_problems_per_family)?;
            self.rec.log(Row::new(step as u64)
                .set("eval_acc", acc)
                .tag("phase", "eval"));
            crate::info!("trainer", "step {step}: reward {mean_reward:.3} \
                          eval {acc:.3}");
        }
        Ok(mean_reward)
    }

    /// Run the configured number of steps; returns final training reward EMA.
    pub fn run(&mut self) -> Result<f64> {
        let mut last = 0.0;
        for step in 0..self.cfg.steps {
            last = self.step(step)?;
        }
        Ok(self.rec.tail_mean("reward", 8).unwrap_or(last))
    }
}

/// Supervised pretraining: builds the "base model" (the paper's Qwen/
/// DeepSeek starting checkpoints) by cross-entropy on (prompt, answer)
/// pairs.  Returns the final CE loss.
pub fn pretrain_sft(rt: &Runtime, ps: &mut ParamStore, suite: &Suite,
                    steps: usize, lr: f32, seed: u64,
                    rec: &mut Recorder) -> Result<f64> {
    let man = rt.manifest();
    let (b, s) = (man.train_batch, man.max_seq);
    let tk = Tokenizer::new();
    let mut sampler = suite.train_sampler(seed ^ 0x5f74);
    let mut flags = vec![0.0f32; man.flags.n];
    flags[man.flags.lr] = lr;
    flags[man.flags.beta1] = 0.9;
    flags[man.flags.beta2] = 0.999;
    flags[man.flags.adam_eps] = 1e-8;
    flags[man.flags.max_grad_norm] = 1.0;
    let mut last = f64::NAN;
    for step in 0..steps {
        let problems = sampler.batch(b);
        let (tokens, mask) = crate::tasks::encode_sft_batch(&tk, &problems, b, s);
        let mets = rt.sft_step(ps, &tokens, &mask, &flags)?;
        last = mets[0] as f64;
        if step % 20 == 0 || step + 1 == steps {
            rec.log(Row::new(step as u64)
                .set("sft_loss", last)
                .set("sft_token_prob", mets[1] as f64)
                .tag("phase", "sft"));
        }
    }
    Ok(last)
}
