//! The QuRL training loop (paper Fig. 1): quantize the old actor, roll out
//! on the quantized engine, score behavior/proximal/reference logprobs,
//! estimate advantages (GRPO/PPO/DAPO), and update the full-precision actor
//! with the selected objective (on-policy / naive / decoupled / TIS / ACR).
//!
//! Python never runs here: rollout, scoring, quantization and optimization
//! are all AOT artifacts executed through the PJRT runtime.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{EngineFactory, GroupSpec, KvConfig, KvLayout,
                         PlacementLog, PrunePolicy, RolloutService,
                         SchedulerStats, StealPolicy, StepEngine,
                         StripePolicy};
use crate::coordinator::request::RolloutResult;
use crate::coordinator::service::{GroupMember, GroupResult};
use crate::metrics::{Recorder, Row};
use crate::quant::analysis;
use crate::quant::DeltaReport;
use crate::runtime::{EngineWeights, ParamStore, QuantMode, Runtime, TrainBatch};
use crate::tasks::{encode_batch, Problem, Suite, Tokenizer};
use crate::util::rng::Pcg64;
use crate::util::stats;

use super::advantage;
use super::dapo::DynamicSampler;
use super::eval;
use super::kl;
use super::objective::Objective;

/// Typed error for driving the trainer's serving or eval paths before any
/// rollout weights exist — [`Trainer::prepare`] (or the first `step`) must
/// run `refresh_engine` first.  Previously an `.expect` panic; as a plain
/// error it propagates to the caller like any other trainer failure
/// instead of aborting the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineNotReady;

impl std::fmt::Display for EngineNotReady {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rollout engine weights not initialized (call \
                   Trainer::prepare or Trainer::step first)")
    }
}

impl std::error::Error for EngineNotReady {}

/// RL algorithm family (the paper evaluates all three).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// GRPO: group-normalized advantages, optional KL-to-reference.
    Grpo,
    /// PPO: GAE advantages from the value head, clipped value loss.
    Ppo,
    /// DAPO: GRPO advantages + dynamic sampling + decoupled clip +
    /// token-mean aggregation.
    Dapo,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "grpo" => Some(Algo::Grpo),
            "ppo" => Some(Algo::Ppo),
            "dapo" => Some(Algo::Dapo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Grpo => "grpo",
            Algo::Ppo => "ppo",
            Algo::Dapo => "dapo",
        }
    }
}

/// Which serving path generates the trainer's rollouts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutPath {
    /// The fused `generate_*` artifact: fixed lockstep waves of
    /// `rollout_batch` prompts; every wave pays the full decode scan, so
    /// short sequences wait for the longest one in their wave.
    Fused,
    /// The [`RolloutService`] over continuous-batching schedulers: each
    /// prompt is submitted as a [`GroupSpec`] and the service owns group
    /// expansion, per-member seeds, group-shared prefix prefill (fork_kv),
    /// striping across `rollout_engines` engine replicas, and — under DAPO
    /// dynamic sampling — in-flight pruning of reward-decided groups.
    /// Early-finished or cancelled sequences free their KV slot
    /// immediately and queued prompts backfill it.  Greedy decode without
    /// pruning is bit-identical to the fused path (integration-tested);
    /// serving metrics land in the step's `sched_*` Recorder fields.
    Scheduler,
}

impl RolloutPath {
    pub fn parse(s: &str) -> Option<RolloutPath> {
        match s {
            "fused" => Some(RolloutPath::Fused),
            "scheduler" | "sched" => Some(RolloutPath::Scheduler),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RolloutPath::Fused => "fused",
            RolloutPath::Scheduler => "scheduler",
        }
    }
}

/// How the rollout service executes its engine replicas (scheduler path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutExec {
    /// One thread ticks all schedulers round-robin (reference semantics;
    /// `--rollout-engines N` buys queueing capacity, not decode
    /// parallelism).
    Inline,
    /// One worker thread per engine replica, each owning its own engine
    /// stack (own `Runtime`/PJRT client for [`StepEngine`]); replicas
    /// decode in parallel while the control loop scores rewards, prunes
    /// groups and pushes weight swaps.  Outputs are bit-identical to
    /// inline (parity-tested); only wall-clock changes.
    Threaded,
}

impl RolloutExec {
    pub fn parse(s: &str) -> Option<RolloutExec> {
        match s {
            "inline" | "sync" => Some(RolloutExec::Inline),
            "threaded" | "threads" | "async" => Some(RolloutExec::Threaded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RolloutExec::Inline => "inline",
            RolloutExec::Threaded => "threaded",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub algo: Algo,
    pub objective: Objective,
    /// rollout engine precision — the QuRL axis
    pub rollout_mode: QuantMode,
    /// rollout serving path — fused waves or the continuous-batching
    /// scheduler
    pub rollout_path: RolloutPath,
    pub suite: String,
    /// UAQ invariant scale s (1.0 disables; paper default 1.5)
    pub uaq_scale: f32,
    pub steps: usize,
    /// distinct prompts per RL step (each expanded group_size times)
    pub prompts_per_step: usize,
    pub group_size: usize,
    pub temp: f32,
    pub top_p: f32,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_problems_per_family: usize,
    /// std-dev of Gaussian noise injected into behavior logprobs — the
    /// controlled stand-in for FlashRL's training/inference engine mismatch
    pub engine_noise: f32,
    /// PPO-style epochs over each rollout batch (>1 makes clipping bind)
    pub inner_epochs: usize,
    /// GAE parameters (PPO)
    pub gamma: f32,
    pub gae_lambda: f32,
    pub whiten_adv: bool,
    /// dynamic sampling (DAPO) on/off
    pub dynamic_sampling: bool,
    /// in-flight rollout pruning ("Prune as You Generate"): under DAPO
    /// dynamic sampling on the scheduler path, cancel the remainder of a
    /// group once enough members finished with identical rewards
    pub prune_rollouts: bool,
    /// members that must finish (all with identical reward) before a group
    /// is predicted uninformative and pruned; 0 = auto
    /// (`max(2, group_size / 2)` — a majority, so sparse-reward workloads
    /// don't mispredict on the first two zero-reward finishers)
    pub prune_min_finished: usize,
    /// engine replicas behind the rollout service (scheduler path); groups
    /// are placed across them per `rollout_stripe`
    pub rollout_engines: usize,
    /// execution backend for the rollout service: `inline` (one thread
    /// ticks all schedulers) or `threaded` (one worker thread per replica,
    /// parallel decode)
    pub rollout_exec: RolloutExec,
    /// group-placement policy across engine replicas: blind round-robin,
    /// least-loaded (estimated outstanding decode tokens,
    /// prompt-length + max_new aware) or `replay` (re-execute the
    /// recorded placement log at `placement_log`)
    pub rollout_stripe: StripePolicy,
    /// work stealing across engine replicas: `off` (placement final at
    /// submission) or `idle` (an idle replica pulls whole queued groups
    /// off the most-loaded one; every move is recorded in the placement
    /// log, so the run stays reproducible via `--stripe replay`)
    pub rollout_steal: StealPolicy,
    /// placement-log JSON path: with `rollout_stripe == Replay` it is
    /// *loaded* and drives placement; otherwise, when non-empty, the
    /// recorded log is *dumped* there after every rollout call
    /// (cumulative — the last write holds the whole run).  Empty = off.
    pub placement_log: String,
    /// scheduler admission floor: wait until this many requests can
    /// prefill together (1 = admit eagerly)
    pub min_prefill_batch: usize,
    /// KV bookkeeping layout on the scheduler path: `Dense` reserves a
    /// full `max_seq` sequence per admitted slot (the oracle), `Paged`
    /// tracks fixed-size pages with prefix aliasing + copy-on-write and
    /// admits against actual page demand — outputs are bit-identical
    /// either way
    pub kv_layout: KvLayout,
    /// cache positions per KV page (paged layout granularity; see
    /// coordinator/kv.rs for the waste/sharing trade-off)
    pub kv_page_size: usize,
    /// chunked prefill: prompts longer than this prefill in chunks
    /// interleaved with decode ticks (0 = whole-prompt prefill)
    pub prefill_chunk: usize,
    /// re-quantize engine weights every k steps (1 = every step, paper setup)
    pub requantize_every: usize,
    /// compute Fig. 4/9 weight-change analysis every k steps (0 = never)
    pub analyze_every: usize,
    /// delta requantization (on = default): refresh engine weights through
    /// [`Runtime::engine_weights_delta`], which reuses the previous
    /// epoch's payload `Arc` for every tensor whose quantized form came
    /// out bit-identical — downstream, `StepEngine::swap_weights` keeps
    /// the cached device conversion for pointer-equal payloads, so a
    /// refresh re-stages only what actually changed
    /// (`sched_swap_bytes_h2d`).  Off = the full-requant oracle: rebuild
    /// and re-stage everything each refresh (outputs bit-identical either
    /// way; property-tested)
    pub requant_delta: bool,
    /// write a crash-safe checkpoint every k steps (0 = off); see
    /// [`crate::rl::checkpoint`] for the snapshot format and the
    /// deterministic-resume guarantee
    pub ckpt_every: usize,
    /// directory checkpoints are written to / resumed from (empty = off)
    pub ckpt_dir: String,
    /// retention: keep the newest k good checkpoints (0 = keep all); the
    /// newest good one is never deleted
    pub ckpt_keep: usize,
    /// resume from the newest good checkpoint under `ckpt_dir` before
    /// training; refused if the (non-checkpoint) config changed
    pub resume: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            algo: Algo::Grpo,
            objective: Objective::default(),
            rollout_mode: QuantMode::Int8,
            rollout_path: RolloutPath::Fused,
            suite: "deepscaler".into(),
            uaq_scale: 1.0,
            steps: 100,
            prompts_per_step: 8,
            group_size: 8,
            temp: 1.0,
            top_p: 1.0,
            seed: 0,
            eval_every: 0,
            eval_problems_per_family: 32,
            engine_noise: 0.0,
            inner_epochs: 2,
            gamma: 1.0,
            gae_lambda: 0.95,
            whiten_adv: false,
            dynamic_sampling: false,
            prune_rollouts: true,
            prune_min_finished: 0,
            rollout_engines: 1,
            rollout_exec: RolloutExec::Inline,
            rollout_stripe: StripePolicy::RoundRobin,
            rollout_steal: StealPolicy::Off,
            placement_log: String::new(),
            min_prefill_batch: 1,
            kv_layout: KvLayout::Dense,
            kv_page_size: 16,
            prefill_chunk: 0,
            requantize_every: 1,
            analyze_every: 0,
            requant_delta: true,
            ckpt_every: 0,
            ckpt_dir: String::new(),
            ckpt_keep: 3,
            resume: false,
        }
    }
}

/// One prompt group prepared for the rollout service: the trainer-side
/// bookkeeping (problem + encoded prompt) matching a submitted
/// [`GroupSpec`], indexed by the spec's `group_id`.
struct PromptGroup<'p> {
    /// group index the resulting samples carry (`Sample::group`)
    group: usize,
    prob: &'p Problem,
    prompt: Vec<i32>,
    size: usize,
}

/// One rolled-out sequence with its verification outcome.
#[derive(Clone, Debug)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub lp_behav: Vec<f32>,
    pub mask: Vec<f32>,
    pub prompt_len: usize,
    pub reward: f32,
    /// index of the problem (group id) this sample answers
    pub group: usize,
}

pub struct Trainer {
    pub rt: Arc<Runtime>,
    pub cfg: TrainerConfig,
    pub ps: ParamStore,
    /// frozen reference policy for the KL term (the SFT base model)
    pub ref_params: Vec<f32>,
    pub tk: Tokenizer,
    pub suite: Suite,
    pub rec: Recorder,
    rng: Pcg64,
    rollout_seed: i32,
    engine: Option<EngineWeights>,
    engine_age: usize,
    /// persistent scheduler-path rollout service (`rollout_engines`
    /// StepEngine replicas — inline clones of `rt`, or threaded workers
    /// each owning a private Runtime), reused across rollout calls and
    /// steps.  Requantization HOT-SWAPS weights into the live service
    /// (`push_weights`, bumping the WeightEpoch) — the service is built
    /// once and never torn down on the requantize path.  Stale KV rows
    /// are safe: prefill (or fork_kv) overwrites a slot's rows before
    /// reuse (tested).
    service: Option<RolloutService<StepEngine>>,
    /// how many times the service was (re)built — the requantize path
    /// must keep this at 1 (hot swap, not teardown); asserted in tests
    service_builds: usize,
    /// scheduler-path serving stats accumulated over the current step's
    /// rollout calls (DAPO may run several), drained into a Recorder row
    sched_stats: Option<SchedulerStats>,
    /// per-replica accumulation of the same stats (the `sched_e{i}_*`
    /// Recorder fields)
    sched_engine_stats: Vec<SchedulerStats>,
    /// previous-step section-B snapshot for the Fig. 9 analysis
    prev_params: Option<Vec<f32>>,
    /// params the engine was last quantized from — checkpointed so a
    /// resume mid requant interval rebuilds the *same* engine instead of
    /// requantizing newer params ([`crate::rl::checkpoint`])
    engine_src: Option<Vec<f32>>,
}

impl Trainer {
    pub fn new(rt: &Arc<Runtime>, cfg: TrainerConfig, base: ParamStore,
               rec: Recorder) -> Result<Self> {
        let suite = Suite::by_name(&cfg.suite)
            .ok_or_else(|| anyhow::anyhow!("unknown suite {:?}", cfg.suite))?;
        let mut ps = base;
        // UAQ: one-shot invariant rescaling before RL begins (§4.3)
        if (cfg.uaq_scale - 1.0).abs() > 1e-6 {
            ps.params = rt.uaq_scale(&ps.params, cfg.uaq_scale)?;
        }
        ps.reset_optimizer();
        let ref_params = ps.params.clone();
        let rng = Pcg64::new(cfg.seed ^ 0x5152_4c00);
        Ok(Trainer {
            rt: rt.clone(),
            rng,
            rollout_seed: (cfg.seed as i32) ^ 0x2f2f,
            tk: Tokenizer::new(),
            suite,
            rec,
            ps,
            ref_params,
            cfg,
            engine: None,
            engine_age: usize::MAX,
            service: None,
            service_builds: 0,
            sched_stats: None,
            sched_engine_stats: Vec::new(),
            prev_params: None,
            engine_src: None,
        })
    }

    /// How many times the rollout service was built from scratch.  Stays
    /// at 1 across arbitrarily many requantizations — the hot-swap
    /// acceptance check (`service = None` teardown would bump it).
    pub fn service_builds(&self) -> usize {
        self.service_builds
    }

    /// Build (or refresh) the rollout engine without running a step — lets
    /// callers drive [`Trainer::rollout`] directly (parity tests, benches).
    pub fn prepare(&mut self) -> Result<()> {
        self.refresh_engine()
    }

    /// Quantized (or fp) rollout-engine weights, refreshed per the
    /// requantize schedule.  This is the Q(theta_old) step of Fig. 1.
    ///
    /// Requantization no longer tears the rollout service down: fresh
    /// weights are HOT-SWAPPED into the live engines (`push_weights` →
    /// WeightEpoch bump; the swap lands between decode ticks on threaded
    /// workers), so engine rebuild cost is gone and `requantize_every`
    /// works at sub-step granularity — the swap is safe mid-step, even
    /// with requests in flight.
    fn refresh_engine(&mut self) -> Result<()> {
        if self.engine_age < self.cfg.requantize_every {
            self.engine_age += 1;
            return Ok(());
        }
        // delta path (default): quantize via the same artifacts, then reuse
        // the previous epoch's Arc for every bit-identical payload — the
        // pointer equality swap_weights keys its zero-restage hot swap on.
        // Off = the full-requant oracle (every tensor counts as changed).
        let (w, report) = if self.cfg.requant_delta {
            self.rt.engine_weights_delta(self.cfg.rollout_mode,
                                         &self.ps.params,
                                         self.engine.as_ref())?
        } else {
            let n = self.rt.manifest().params.len();
            (self.rt.engine_weights(self.cfg.rollout_mode, &self.ps.params)?,
             DeltaReport::all_changed(n))
        };
        if self.cfg.rollout_path == RolloutPath::Scheduler {
            self.sched_stats
                .get_or_insert_with(SchedulerStats::default)
                .merge(&SchedulerStats {
                    requant_tensors_changed: report.tensors_changed,
                    requant_tensors_skipped: report.tensors_skipped,
                    ..Default::default()
                });
        }
        self.engine = Some(w.clone());
        self.engine_src = Some(self.ps.params.clone());
        self.engine_age = 1;
        if let Some(svc) = &mut self.service {
            svc.push_weights(w);
        }
        Ok(())
    }

    /// Build the rollout service on demand (once per training run):
    /// `rollout_engines` StepEngine replicas of the current quantized
    /// weights behind one submission interface, executed inline or on
    /// worker threads per `rollout_exec`.
    fn ensure_service(&mut self) -> Result<()> {
        if self.service.is_some() {
            return Ok(());
        }
        let weights = self.engine.clone().ok_or(EngineNotReady)?;
        let n = self.cfg.rollout_engines.max(1);
        let m = self.rt.manifest();
        let (max_seq, eos_id) = (m.max_seq, m.eos_id);
        let mut svc = match self.cfg.rollout_exec {
            RolloutExec::Inline => {
                let engines: Vec<StepEngine> = (0..n)
                    // lint: allow(send, inline backend — engines are built and ticked on this thread only, PJRT state never crosses)
                    .map(|_| StepEngine::new(&self.rt, weights.clone()))
                    .collect();
                RolloutService::new(engines, max_seq, eos_id)
            }
            RolloutExec::Threaded => {
                // each worker opens its own Runtime (PJRT state is not
                // Send); the one-time per-worker artifact compile is
                // amortized over the whole run, since requantization now
                // swaps weights instead of rebuilding workers
                let dir = self.rt.artifact_dir().to_path_buf();
                let factories: Vec<EngineFactory<StepEngine>> = (0..n)
                    .map(|_| StepEngine::factory(dir.clone(),
                                                 weights.clone()))
                    .collect();
                RolloutService::threaded(factories, max_seq, eos_id)?
            }
        };
        svc.stripe = self.cfg.rollout_stripe;
        svc.steal = self.cfg.rollout_steal;
        if self.cfg.rollout_stripe == StripePolicy::Replay {
            anyhow::ensure!(!self.cfg.placement_log.is_empty(),
                            "--stripe replay needs --placement-log <path> \
                             to load the recorded log from");
            let log = PlacementLog::load(
                std::path::Path::new(&self.cfg.placement_log))?;
            svc.set_replay(log);
        }
        svc.set_min_prefill_batch(self.cfg.min_prefill_batch);
        svc.set_kv(KvConfig {
            layout: self.cfg.kv_layout,
            page_size: self.cfg.kv_page_size.max(1),
            budget_pages: None, // derived per engine from slots × max_seq
        });
        svc.set_prefill_chunk(self.cfg.prefill_chunk);
        self.service = Some(svc);
        self.service_builds += 1;
        Ok(())
    }

    /// Roll out `problems` (already group-expanded) through the configured
    /// serving path.  Both paths produce identical [`Sample`] layout, so
    /// everything downstream — scoring, advantages, objectives — is
    /// path-agnostic.
    pub fn rollout(&mut self, problems: &[(usize, &Problem)]) -> Result<Vec<Sample>> {
        match self.cfg.rollout_path {
            RolloutPath::Fused => self.rollout_fused(problems),
            RolloutPath::Scheduler => self.rollout_scheduler(problems),
        }
    }

    /// Final [`Sample`] assembly (fused path): decode + verify for the
    /// reward, then the shared noise/layout step.
    fn finish_sample(&mut self, tokens: Vec<i32>, lp: Vec<f32>,
                     mask: Vec<f32>, prompt_len: usize, prob: &Problem,
                     group: usize) -> Sample {
        let gen_text = self.tk.decode_generation(&tokens, prompt_len);
        let reward = crate::tasks::verify(prob, &gen_text);
        self.finish_sample_scored(tokens, lp, mask, prompt_len, reward, group)
    }

    /// Shared tail of sample assembly: engine-noise injection on behavior
    /// logprobs (FlashRL's HF-vs-vLLM gap, simulated) around an
    /// already-computed reward.  The service path lands here directly with
    /// the reward its prune policy acted on — verified exactly once.
    fn finish_sample_scored(&mut self, tokens: Vec<i32>, mut lp: Vec<f32>,
                            mask: Vec<f32>, prompt_len: usize, reward: f32,
                            group: usize) -> Sample {
        if self.cfg.engine_noise > 0.0 {
            for (l, &m) in lp.iter_mut().zip(&mask) {
                if m > 0.5 {
                    *l += (self.rng.normal() as f32) * self.cfg.engine_noise;
                }
            }
        }
        Sample { tokens, lp_behav: lp, mask, prompt_len, reward, group }
    }

    /// Fused path: fixed lockstep waves through the `generate_*` artifact.
    fn rollout_fused(&mut self, problems: &[(usize, &Problem)]) -> Result<Vec<Sample>> {
        let m = self.rt.manifest();
        let (b, s, max_prompt) = (m.rollout_batch, m.max_seq, m.max_prompt);
        let mut out = Vec::with_capacity(problems.len());
        for wave in problems.chunks(b) {
            let refs: Vec<&Problem> = wave.iter().map(|(_, p)| *p).collect();
            let (tokens, lens) = encode_batch(&self.tk, &refs, b, s, max_prompt);
            self.rollout_seed = self.rollout_seed.wrapping_add(1);
            let gen = {
                let engine = self.engine.as_ref().ok_or(EngineNotReady)?;
                self.rt.generate(engine, &tokens, &lens, self.rollout_seed,
                                 self.cfg.temp, self.cfg.top_p)?
            };
            for (r, (group, prob)) in wave.iter().enumerate() {
                let row = gen.tokens[r * s..(r + 1) * s].to_vec();
                let lp = gen.logprob[r * s..(r + 1) * s].to_vec();
                let mask = gen.mask[r * s..(r + 1) * s].to_vec();
                let plen = lens[r] as usize;
                out.push(self.finish_sample(row, lp, mask, plen, prob, *group));
            }
        }
        Ok(out)
    }

    /// Scheduler path: reconstruct the group structure from the expanded
    /// problem list (contiguous runs of one group index), hand the groups
    /// to the [`RolloutService`] with pruning off, and flatten the
    /// [`GroupResult`]s back into [`Sample`]s in submission order — so the
    /// flat API stays interchangeable with the fused path.
    fn rollout_scheduler(&mut self, problems: &[(usize, &Problem)])
                         -> Result<Vec<Sample>> {
        let mut groups: Vec<PromptGroup> = Vec::new();
        for &(group, prob) in problems {
            match groups.last_mut() {
                // merge only true group members: same group id AND the same
                // problem — two different problems sharing a group id must
                // not collapse into one prompt (each still rolls out)
                Some(pg) if pg.group == group
                    && std::ptr::eq(pg.prob, prob) => pg.size += 1,
                _ => groups.push(PromptGroup {
                    group,
                    prob,
                    prompt: self.tk.encode_prompt(&prob.prompt),
                    size: 1,
                }),
            }
        }
        let results = self.run_groups(&groups, false)?;
        let mut out = Vec::with_capacity(problems.len());
        for (gr, pg) in results.into_iter().zip(&groups) {
            anyhow::ensure!(gr.complete(),
                            "service cancelled members with pruning off");
            for m in gr.members {
                out.push(self.result_to_sample(m, &pg.prompt, pg.group));
            }
        }
        anyhow::ensure!(out.len() == problems.len(),
                        "service returned {} samples for {} requests",
                        out.len(), problems.len());
        Ok(out)
    }

    /// Submit prepared groups to the service, score completions with the
    /// task verifier as they finish (the signal the prune policy acts on),
    /// and drain serving stats into `sched_stats`.  Results come back in
    /// submission order with `group_id` = index into `groups`.
    fn run_groups(&mut self, groups: &[PromptGroup], prune: bool)
                  -> Result<Vec<GroupResult>> {
        self.ensure_service()?;
        let m = self.rt.manifest();
        let (max_prompt, max_new) = (m.max_prompt, m.max_new);
        // one seed domain per rollout call (mirrors the fused path's
        // per-wave seed bump), split into per-member streams by the service
        self.rollout_seed = self.rollout_seed.wrapping_add(1);
        let base = (self.rollout_seed as u32 as u64) << 32;
        let min_finished = if self.cfg.prune_min_finished > 0 {
            self.cfg.prune_min_finished
        } else {
            // auto: a majority of the group must agree before pruning, so
            // sparse rewards (first two members zero) don't throw away
            // groups a later member would have made informative
            (self.cfg.group_size / 2).max(2)
        };
        // lint: allow(panic, ensure_service above either built the service or returned an error — None here is unreachable by construction)
        let svc = self.service.as_mut().unwrap();
        svc.prune = if prune {
            PrunePolicy::online(min_finished)
        } else {
            PrunePolicy::off()
        };
        let mut offset = 0u64;
        for (gid, pg) in groups.iter().enumerate() {
            assert!(pg.prompt.len() <= max_prompt,
                    "prompt overflows max_prompt: {}", pg.prob.prompt);
            svc.submit_group(GroupSpec {
                group_id: gid,
                prompt: pg.prompt.clone(),
                group_size: pg.size,
                max_new,
                temperature: self.cfg.temp,
                top_p: self.cfg.top_p,
                seed: base | offset,
            });
            offset += pg.size as u64;
        }
        let tk = &self.tk;
        let results = svc.run(|gid, res: &RolloutResult| {
            let text = tk.decode(&res.generated);
            crate::tasks::verify(groups[gid].prob, &text)
        })?;
        let stats = svc.take_stats()?;
        let per_engine = svc.last_engine_stats().to_vec();
        if !self.cfg.placement_log.is_empty()
            && self.cfg.rollout_stripe != StripePolicy::Replay
        {
            svc.placement_log()
                .save(std::path::Path::new(&self.cfg.placement_log))?;
        }
        self.sched_stats
            .get_or_insert_with(SchedulerStats::default)
            .merge(&stats);
        if self.sched_engine_stats.len() < per_engine.len() {
            self.sched_engine_stats
                .resize(per_engine.len(), SchedulerStats::default());
        }
        for (acc, st) in self.sched_engine_stats.iter_mut().zip(&per_engine) {
            acc.merge(st);
        }
        anyhow::ensure!(results.len() == groups.len(),
                        "service resolved {} of {} groups",
                        results.len(), groups.len());
        Ok(results)
    }

    /// Convert one service rollout back into the fused-path [`Sample`]
    /// grid layout (prompt + generated span in a max_seq row), reusing the
    /// reward the service's closure already verified.
    fn result_to_sample(&mut self, member: GroupMember, prompt: &[i32],
                        group: usize) -> Sample {
        // lint: allow(panic, service contract — run()'s closure scores every completed member before it is returned (ensured by GroupResult::complete upstream))
        let reward = member.reward.expect("completed member unscored");
        let res = member.result;
        let s = self.rt.manifest().max_seq;
        let plen = prompt.len();
        let mut tokens = vec![crate::tasks::PAD; s];
        tokens[..plen].copy_from_slice(prompt);
        let mut lp = vec![0.0f32; s];
        let mut mask = vec![0.0f32; s];
        for (i, (&tok, &l)) in
            res.generated.iter().zip(&res.logprobs).enumerate()
        {
            tokens[plen + i] = tok;
            lp[plen + i] = l;
            mask[plen + i] = 1.0;
        }
        self.finish_sample_scored(tokens, lp, mask, plen, reward, group)
    }

    /// Collect one RL step's samples (with DAPO dynamic sampling when on).
    fn collect(&mut self, step: usize) -> Result<Vec<Sample>> {
        let g = self.cfg.group_size;
        let n_prompts = self.cfg.prompts_per_step;
        let mut sampler = self.suite.train_sampler(self.cfg.seed
            .wrapping_add(step as u64 * 7919));
        if !self.cfg.dynamic_sampling {
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            return self.rollout(&expanded);
        }
        // DAPO: resample until enough informative groups
        let mut ds = DynamicSampler::new(g, n_prompts);
        let mut kept: Vec<Sample> = Vec::new();
        while !ds.done() {
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            if self.cfg.rollout_path == RolloutPath::Scheduler {
                // online policy: the service scores members as they finish
                // and (with prune_rollouts) cancels reward-decided groups
                // mid-flight, so uninformative groups never burn their full
                // decode budget before being filtered
                ds.begin_wave();
                let groups: Vec<PromptGroup> = probs
                    .iter()
                    .enumerate()
                    .map(|(i, p)| PromptGroup {
                        group: i,
                        prob: p,
                        prompt: self.tk.encode_prompt(&p.prompt),
                        size: g,
                    })
                    .collect();
                let results =
                    self.run_groups(&groups, self.cfg.prune_rollouts)?;
                for gr in results {
                    let keep = ds.record_group(
                        gr.complete() && gr.informative());
                    if !keep {
                        continue;
                    }
                    let new_gid = kept.len() / g;
                    let pg = &groups[gr.group_id];
                    for m in gr.members {
                        kept.push(self.result_to_sample(m, &pg.prompt,
                                                        new_gid));
                    }
                }
                continue;
            }
            // fused path: post-hoc wave filtering
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            let samples = self.rollout(&expanded)?;
            let rewards: Vec<f32> = samples.iter().map(|x| x.reward).collect();
            let keep_groups = ds.offer(&rewards);
            let base = kept.len() / g;
            for (new_gid, gid) in keep_groups.iter().enumerate() {
                for r in 0..g {
                    let mut smp = samples[gid * g + r].clone();
                    smp.group = base + new_gid;
                    kept.push(smp);
                }
            }
        }
        if kept.is_empty() {
            // degenerate (all groups uniform): fall back to the last wave
            crate::warnln!("trainer", "dynamic sampling found no signal; \
                            falling back to plain sampling");
            let probs: Vec<Problem> =
                (0..n_prompts).map(|_| sampler.next().1).collect();
            let expanded: Vec<(usize, &Problem)> = probs
                .iter()
                .enumerate()
                .flat_map(|(i, p)| std::iter::repeat((i, p)).take(g))
                .collect();
            kept = self.rollout(&expanded)?;
        }
        self.rec.log(Row::new(step as u64)
            .set("dapo_efficiency", ds.efficiency())
            .tag("phase", "sampling"));
        Ok(kept)
    }

    /// Assemble [B, T] grids from samples (padding with inert rows).
    fn grids(&self, samples: &[Sample]) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
        let man = self.rt.manifest();
        let (b, t) = (man.train_batch, man.max_seq);
        assert!(samples.len() <= b);
        let mut tokens = vec![crate::tasks::PAD; b * t];
        let mut mask = vec![0.0f32; b * t];
        let mut lp_behav = vec![0.0f32; b * t];
        for (r, smp) in samples.iter().enumerate() {
            tokens[r * t..(r + 1) * t].copy_from_slice(&smp.tokens);
            mask[r * t..(r + 1) * t].copy_from_slice(&smp.mask);
            lp_behav[r * t..(r + 1) * t].copy_from_slice(&smp.lp_behav);
        }
        for r in samples.len()..b {
            tokens[r * t] = crate::tasks::BOS;
        }
        (tokens, mask, lp_behav)
    }

    /// Run one full RL step; returns the mean training reward.
    pub fn step(&mut self, step: usize) -> Result<f64> {
        let man = self.rt.manifest().clone();
        let (bt, t) = (man.train_batch, man.max_seq);
        self.refresh_engine()?;
        let samples = self.collect(step)?;
        let mean_reward =
            stats::mean_f32(&samples.iter().map(|s| s.reward).collect::<Vec<_>>());

        // Fig. 4/9 analysis: weight update vs quantization noise
        if self.cfg.analyze_every > 0 && step % self.cfg.analyze_every == 0 {
            let b_now = self.ps.section_b().to_vec();
            if let Some(prev) = &self.prev_params {
                let upd = analysis::normalized_weight_update(prev, &self.ps.params);
                let prev_b = &prev[man.a_size..];
                let code_change =
                    analysis::int8_code_change_fraction(&man, prev_b, &b_now);
                self.rec.log(Row::new(step as u64)
                    .set("norm_weight_update", upd)
                    .set("int8_code_change_frac", code_change)
                    .tag("phase", "analysis"));
            }
            let qerr = analysis::normalized_quant_error(
                &man, &b_now, self.cfg.rollout_mode);
            self.rec.log(Row::new(step as u64)
                .set("norm_quant_error", qerr)
                .tag("phase", "analysis"));
            self.prev_params = Some(self.ps.params.clone());
        }

        // GRPO/DAPO advantages over the TRUE group structure, computed once
        // for the whole step before chunking.  Deriving group boundaries per
        // chunk from `rewards.len() % group_size` is wrong twice over: a
        // ragged final chunk used to collapse to singleton groups (whose
        // advantages are identically zero — the silent zero-advantage bug),
        // and a group straddling two train_batch chunks would be normalized
        // against the wrong members.  `Sample::group` runs are contiguous
        // across the step's samples, so chunk slices below stay aligned.
        let adv_seq_all: Vec<f32> = match self.cfg.algo {
            Algo::Grpo | Algo::Dapo => {
                let rewards_all: Vec<f32> =
                    samples.iter().map(|s| s.reward).collect();
                let groups: Vec<usize> =
                    samples.iter().map(|s| s.group).collect();
                advantage::grpo_by_group(&rewards_all, &groups)
            }
            Algo::Ppo => Vec::new(),
        };

        // process in train_batch chunks
        let mut metric_acc: Vec<f64> = vec![0.0; man.metric_names.len()];
        let mut metric_n = 0usize;
        let mut kl_bp_acc = 0.0f64;
        let mut rho_max_all = 0.0f64;
        let mut chunk_off = 0usize;
        for chunk in samples.chunks(bt) {
            let (tokens, mask, lp_behav) = self.grids(chunk);
            // proximal policy = full-precision theta_old (pre-update)
            let prox = self.rt.score_bf16(&self.ps.params, &tokens)?;
            let lp_ref = if self.cfg.objective.kl_coef > 0.0 {
                self.rt.score_bf16(&self.ref_params, &tokens)?.logprob
            } else {
                vec![0.0f32; bt * t]
            };
            kl_bp_acc += kl::k1(&lp_behav, &prox.logprob, &mask);
            rho_max_all =
                rho_max_all.max(kl::max_ratio(&prox.logprob, &lp_behav, &mask));

            // advantages
            let rewards: Vec<f32> = chunk.iter().map(|s| s.reward).collect();
            let (mut adv, returns) = match self.cfg.algo {
                Algo::Grpo | Algo::Dapo => {
                    let mut a =
                        adv_seq_all[chunk_off..chunk_off + chunk.len()].to_vec();
                    // pad to the full train grid (inert rows get zeros)
                    let mut rw = rewards.clone();
                    a.resize(bt, 0.0);
                    rw.resize(bt, 0.0);
                    advantage::broadcast_sequence_adv(&a, &rw, &mask, bt, t)
                }
                Algo::Ppo => {
                    let mut adv = vec![0.0f32; bt * t];
                    let mut ret = vec![0.0f32; bt * t];
                    for (r, smp) in chunk.iter().enumerate() {
                        // values over the generated span
                        let span: Vec<usize> = (0..t)
                            .filter(|&c| smp.mask[c] > 0.5)
                            .collect();
                        let vals: Vec<f32> =
                            span.iter().map(|&c| prox.value[r * t + c]).collect();
                        let (a, rt_) = advantage::gae(&vals, smp.reward,
                                                      self.cfg.gamma,
                                                      self.cfg.gae_lambda);
                        for (k, &c) in span.iter().enumerate() {
                            adv[r * t + c] = a[k];
                            ret[r * t + c] = rt_[k];
                        }
                    }
                    (adv, ret)
                }
            };
            // pad adv grid to full [bt, t] (broadcast helper handled b<=bt)
            adv.resize(bt * t, 0.0);
            let mut returns = returns;
            returns.resize(bt * t, 0.0);
            if self.cfg.whiten_adv {
                advantage::whiten(&mut adv, &mask);
            }

            let batch = TrainBatch {
                tokens,
                mask,
                adv,
                lp_behav,
                lp_prox: prox.logprob.clone(),
                lp_ref,
                returns,
                old_values: prox.value.clone(),
            };
            let flags = self.cfg.objective.to_flags(&man.flags);
            for _ in 0..self.cfg.inner_epochs.max(1) {
                let mets = self.rt.train_step(&mut self.ps, &batch, &flags)?;
                for (i, &m) in mets.iter().enumerate() {
                    if i < metric_acc.len() {
                        metric_acc[i] += m as f64;
                    }
                }
                metric_n += 1;
            }
            chunk_off += chunk.len();
        }

        // scheduler-path serving metrics for this step's rollouts: the
        // merged view plus (with >1 replica) a per-engine breakdown, so
        // striping imbalance and per-replica decode volume are visible in
        // every step row.  Field catalog: metrics/recorder.rs.
        if let Some(st) = self.sched_stats.take() {
            let mut row = Row::new(step as u64)
                .set("sched_occupancy", st.mean_occupancy())
                .set("sched_queue_wait_s", st.mean_queue_wait_s())
                // lifecycle counters (added with the stats-catalog lint,
                // which found them merged but never emitted): admission
                // and completion volume per step, and the summed
                // per-replica decode ticks behind load_imbalance
                .set("sched_submitted", st.submitted as f64)
                .set("sched_completed", st.completed as f64)
                .set("sched_decode_steps", st.decode_steps as f64)
                .set("sched_prefill_calls", st.prefill_calls as f64)
                .set("sched_prefill_rows", st.prefill_rows as f64)
                .set("sched_mean_prefill_batch", st.mean_prefill_batch())
                .set("sched_forked", st.forked as f64)
                .set("sched_cancelled", st.cancelled as f64)
                .set("sched_pruned_groups", st.pruned_groups as f64)
                // work-stealing observability: groups migrated off the
                // most-loaded replica this step, and the summed per-engine
                // decode-tick deficit vs. the slowest replica (0 when every
                // replica drains in lockstep).
                .set("sched_steals", st.steals as f64)
                .set("sched_idle_ticks", st.idle_ticks as f64)
                .set("sched_decode_calls", st.decode_calls as f64)
                .set("sched_generated_tokens", st.generated_tokens as f64)
                .set("sched_tokens_per_s", st.tokens_per_s())
                .set("sched_weight_epoch", st.weight_epoch as f64)
                // the copy-tax ledger: bytes newly staged host→device-format
                // and fetched back per step.  On the resident path h2d stays
                // near zero between weight swaps (weights convert once per
                // epoch, KV literals recycle decode→decode); regressions
                // show up here before they show up in wall-clock.
                .set("sched_bytes_h2d", st.bytes_h2d as f64)
                .set("sched_bytes_d2h", st.bytes_d2h as f64)
                .set("sched_h2d_per_decode", st.h2d_per_decode())
                // delta requantization: what each refresh actually moved.
                // swap_bytes_h2d is the re-stage the swaps scheduled
                // (pointer-unequal payloads only — 0 when quantization
                // masked every update); the tensor counters split each
                // refresh into changed vs Arc-reused manifest tensors.
                .set("sched_swap_bytes_h2d", st.swap_bytes_h2d as f64)
                .set("sched_requant_tensors_changed",
                     st.requant_tensors_changed as f64)
                .set("sched_requant_tensors_skipped",
                     st.requant_tensors_skipped as f64)
                .set("sched_prefill_chunks", st.prefill_chunks as f64)
                // the page ledger: allocation/free deltas plus the live
                // and high-water levels — paged-vs-dense memory pressure
                // at a glance, sharing/CoW volume for the prefix-aliasing
                // win.  freed == allocated on every drained step.
                .set("sched_kv_pages_allocated", st.kv_pages_allocated as f64)
                .set("sched_kv_pages_freed", st.kv_pages_freed as f64)
                .set("sched_kv_pages_shared", st.kv_pages_shared as f64)
                .set("sched_kv_pages_cow", st.kv_pages_cow as f64)
                .set("sched_kv_pages_active", st.kv_pages_active as f64)
                .set("sched_kv_pages_high_water",
                     st.kv_pages_high_water as f64)
                .tag("phase", "rollout");
            let per = std::mem::take(&mut self.sched_engine_stats);
            if per.len() > 1 {
                row = row.set("sched_load_imbalance",
                              SchedulerStats::load_imbalance(&per));
                for (i, es) in per.iter().enumerate() {
                    row = row
                        .set(&format!("sched_e{i}_occupancy"),
                             es.mean_occupancy())
                        .set(&format!("sched_e{i}_idle_ticks"),
                             es.idle_ticks as f64)
                        .set(&format!("sched_e{i}_decode_calls"),
                             es.decode_calls as f64)
                        .set(&format!("sched_e{i}_generated_tokens"),
                             es.generated_tokens as f64)
                        .set(&format!("sched_e{i}_pruned_groups"),
                             es.pruned_groups as f64)
                        .set(&format!("sched_e{i}_weight_epoch"),
                             es.weight_epoch as f64)
                        .set(&format!("sched_e{i}_kv_pages_active"),
                             es.kv_pages_active as f64)
                        .set(&format!("sched_e{i}_kv_pages_high_water"),
                             es.kv_pages_high_water as f64);
                }
            }
            self.rec.log(row);
        } else {
            self.sched_engine_stats.clear();
        }

        let chunks = samples.chunks(bt).len().max(1);
        let mut row = Row::new(step as u64)
            .set("reward", mean_reward)
            .set("kl_behav_prox", kl_bp_acc / chunks as f64)
            .set("rho_max", rho_max_all)
            .set("n_samples", samples.len() as f64)
            .tag("phase", "train");
        if metric_n > 0 {
            for (i, name) in man.metric_names.iter().enumerate() {
                row = row.set(name, metric_acc[i] / metric_n as f64);
            }
        }
        self.rec.log(row);

        // periodic evaluation
        if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
            let engine = self.engine.clone().ok_or(EngineNotReady)?;
            let acc = eval::greedy_accuracy(
                &self.rt, &engine, &self.tk, &self.suite,
                self.cfg.seed, self.cfg.eval_problems_per_family)?;
            self.rec.log(Row::new(step as u64)
                .set("eval_acc", acc)
                .tag("phase", "eval"));
            crate::info!("trainer", "step {step}: reward {mean_reward:.3} \
                          eval {acc:.3}");
        }
        Ok(mean_reward)
    }

    /// Run the configured number of steps; returns final training reward EMA.
    ///
    /// With `cfg.resume` set, training first restores the newest good
    /// checkpoint under `cfg.ckpt_dir` and continues from its step; with
    /// `cfg.ckpt_every > 0`, a crash-safe snapshot is written at every k-th
    /// step boundary ([`crate::rl::checkpoint`]).
    pub fn run(&mut self) -> Result<f64> {
        let mut start = 0usize;
        if self.cfg.resume {
            start = self.resume_from_checkpoint()?;
        }
        let mut last = 0.0;
        for step in start..self.cfg.steps {
            last = self.step(step)?;
            self.maybe_checkpoint(step)?;
        }
        Ok(self.rec.tail_mean("reward", 8).unwrap_or(last))
    }

    /// Write a checkpoint if `step` lands on the `ckpt_every` cadence.
    /// Runs *after* `step` completed, so the snapshot's `step` field is the
    /// next step to execute and the per-step stats are fully drained.
    fn maybe_checkpoint(&mut self, step: usize) -> Result<()> {
        if self.cfg.ckpt_every == 0
            || self.cfg.ckpt_dir.is_empty()
            || (step + 1) % self.cfg.ckpt_every != 0
        {
            return Ok(());
        }
        let service = match &self.service {
            Some(svc) => Some(svc.snapshot()?),
            None => None,
        };
        let st = super::checkpoint::CheckpointState {
            step: (step + 1) as u64,
            config: crate::config::to_json(&self.cfg),
            rng: self.rng.snapshot(),
            rollout_seed: self.rollout_seed,
            engine_age: self.engine_age as u64,
            // the trainer's DynamicSampler lives inside collect(), so at a
            // step boundary its counters are zero by construction
            sampler: (0, 0, 0),
            schedule: None,
            service,
            ps: &self.ps,
            ref_params: &self.ref_params,
            prev_params: self.prev_params.as_deref(),
            engine_params: self.engine_src.as_deref(),
        };
        let dir = std::path::PathBuf::from(&self.cfg.ckpt_dir);
        let path = super::checkpoint::save(&dir, &st, self.cfg.ckpt_keep)?;
        crate::info!("trainer", "checkpoint written: {path:?}");
        Ok(())
    }

    /// Restore the newest good checkpoint and return the step to continue
    /// from.  Refuses (typed errors from [`crate::rl::checkpoint`]) on a
    /// changed config, an unknown manifest version, or when every snapshot
    /// is corrupt.  The rollout engine is requantized from the *saved*
    /// engine-source params — not the current ones — so a resume that
    /// lands mid requant interval serves exactly the weights the
    /// uninterrupted run would have; on the scheduler path the service is
    /// rebuilt eagerly and stamped with the restored [`WeightEpoch`] via
    /// `reissue_weights`, so the next `push_weights` bumps the epoch just
    /// like an uninterrupted run's would.
    fn resume_from_checkpoint(&mut self) -> Result<usize> {
        anyhow::ensure!(!self.cfg.ckpt_dir.is_empty(),
                        "resume requested but ckpt_dir is empty \
                         (--resume needs --ckpt-dir)");
        let dir = std::path::PathBuf::from(&self.cfg.ckpt_dir);
        let loaded = super::checkpoint::load_latest(&dir)?;
        super::checkpoint::check_config(&loaded.manifest.config,
                                        &crate::config::to_json(&self.cfg))?;
        self.rng = loaded.rng();
        self.rollout_seed = loaded.manifest.rollout_seed;
        self.engine_age = loaded.manifest.engine_age as usize;
        self.ps = loaded.ps;
        self.ref_params = loaded.ref_params;
        self.prev_params = loaded.prev_params;
        if let Some(src) = &loaded.engine_params {
            // full requant of the saved source params is bit-identical to
            // whatever delta path produced the original engine
            // (property-tested), so the rebuilt engine serves the same
            // quantized weights and the next delta refresh sees the same
            // per-tensor change set
            let w = self.rt.engine_weights(self.cfg.rollout_mode, src)?;
            self.engine = Some(w);
            self.engine_src = Some(src.clone());
        }
        if let Some(snap) = loaded.manifest.service.clone() {
            self.ensure_service()?;
            if let Some(svc) = &mut self.service {
                svc.restore(&snap)?;
                if let Some(w) = &self.engine {
                    svc.reissue_weights(w.clone());
                }
            }
        }
        let step = loaded.manifest.step as usize;
        crate::info!("trainer", "resumed from {:?} at step {step}",
                     loaded.dir);
        Ok(step)
    }
}

/// Supervised pretraining: builds the "base model" (the paper's Qwen/
/// DeepSeek starting checkpoints) by cross-entropy on (prompt, answer)
/// pairs.  Returns the final CE loss.
pub fn pretrain_sft(rt: &Runtime, ps: &mut ParamStore, suite: &Suite,
                    steps: usize, lr: f32, seed: u64,
                    rec: &mut Recorder) -> Result<f64> {
    let man = rt.manifest();
    let (b, s) = (man.train_batch, man.max_seq);
    let tk = Tokenizer::new();
    let mut sampler = suite.train_sampler(seed ^ 0x5f74);
    let mut flags = vec![0.0f32; man.flags.n];
    flags[man.flags.lr] = lr;
    flags[man.flags.beta1] = 0.9;
    flags[man.flags.beta2] = 0.999;
    flags[man.flags.adam_eps] = 1e-8;
    flags[man.flags.max_grad_norm] = 1.0;
    let mut last = f64::NAN;
    for step in 0..steps {
        let problems = sampler.batch(b);
        let (tokens, mask) = crate::tasks::encode_sft_batch(&tk, &problems, b, s);
        let mets = rt.sft_step(ps, &tokens, &mask, &flags)?;
        last = mets[0] as f64;
        if step % 20 == 0 || step + 1 == steps {
            rec.log(Row::new(step as u64)
                .set("sft_loss", last)
                .set("sft_token_prob", mets[1] as f64)
                .tag("phase", "sft"));
        }
    }
    Ok(last)
}
