//! Artifact registry: load HLO text, compile on the PJRT CPU client, cache
//! the executables, and provide a shape-checked call interface.
//!
//! This is the only module that touches the `xla` crate's execution API;
//! everything above it works with [`HostTensor`]s.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::tensor::HostTensor;

pub struct ArtifactStore {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    /// cumulative (calls, seconds) per artifact — the L3 profile source
    exec_stats: RefCell<HashMap<String, (u64, f64)>>,
}

impl ArtifactStore {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(ArtifactStore {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory this store loads artifacts from.  Rollout worker threads
    /// use it to open their own store: PJRT clients and compiled
    /// executables are not `Send`, so each worker owns a full stack instead
    /// of sharing this one across threads.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        crate::debugln!("runtime", "compiled {name} in {:.2}s",
                        t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with the given inputs; returns the output tuple as
    /// host tensors.  Inputs are shape/dtype-checked against the manifest.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if let Some(sig) = self.manifest.artifacts.get(name) {
            anyhow::ensure!(sig.inputs.len() == inputs.len(),
                            "{name}: expected {} inputs, got {}",
                            sig.inputs.len(), inputs.len());
            for (i, (t, s)) in inputs.iter().zip(&sig.inputs).enumerate() {
                anyhow::ensure!(t.shape() == s.shape.as_slice(),
                                "{name} input {i}: shape {:?} != manifest {:?}",
                                t.shape(), s.shape);
                anyhow::ensure!(t.dtype_str() == s.dtype,
                                "{name} input {i}: dtype {} != manifest {}",
                                t.dtype_str(), s.dtype);
            }
        }
        self.ensure_compiled(name)?;
        let t0 = Instant::now();
        let lits = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e:?}"))?;
        let out = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.exec_stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        Ok(out)
    }

    /// (calls, total seconds) per artifact since start — used by the perf
    /// report and the L3 "coordinator is not the bottleneck" check.
    pub fn stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .exec_stats
            .borrow()
            .iter()
            .map(|(k, (n, s))| (k.clone(), *n, *s))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    pub fn reset_stats(&self) {
        self.exec_stats.borrow_mut().clear();
    }
}
