//! Artifact registry: load HLO text, compile on the PJRT CPU client, cache
//! the executables, and provide a shape-checked call interface.
//!
//! This is the only module that touches the `xla` crate's execution API;
//! everything above it works with [`HostTensor`]s or the resident-input
//! types below.
//!
//! # Residency boundary
//!
//! Every artifact call historically paid the same host-side copy tax:
//! convert each input `HostTensor` into a PJRT `Literal` (a full memcpy
//! into device format), execute, then copy every output literal back into
//! host vectors.  For the rollout hot path — where the multi-megabyte
//! engine weights and the full `[L,B,H,S,Dh]` KV caches are inputs to
//! *every* decode tick — that tax dominates, and it is exactly the
//! boundary a GPU backend would call PCIe.
//!
//! Two mechanisms make inputs *resident* instead:
//!
//! * [`InputHandle`] — caches the converted literal of an immutable host
//!   tensor for the handle's lifetime, reusing it call after call; callers
//!   replace the handle when the content changes (`StepEngine` rebuilds
//!   its weight handles on `swap_weights`, so weights convert **once per
//!   weight epoch**, not once per tick).
//! * literal recycling — [`CallOutputs`] hands outputs back as raw
//!   literals on request, so state that flows output→input across calls
//!   (the KV caches) never round-trips through host vectors at all.
//!
//! The vendored `xla` crate executes from literals (`execute::<Literal>`);
//! if a future vendored build exposes device-buffer execution
//! (`PjRtBuffer` arguments), [`InputHandle`] is the single place to swap
//! the cached representation — callers are already coded against the
//! residency API.  Per-artifact `bytes_h2d`/`bytes_d2h` counters measure
//! exactly the copies that remain.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Cumulative per-artifact execution profile (the L3 perf source).
///
/// `bytes_h2d` counts bytes newly materialized into device-format literals
/// at call time — resident inputs whose cached conversion was reused (and
/// recycled output literals fed back as inputs) contribute **zero**.
/// `bytes_d2h` counts bytes copied out of output literals into host
/// vectors; outputs kept as literals ([`CallOutputs::take_literal`])
/// contribute zero.
///
/// `secs` spans input staging, execution, result fetch and untupling.
/// Output literal→host conversion happens at [`CallOutputs::take_host`]
/// time — after the timed window — so versus the pre-residency profile a
/// sliver of time per call moved from these rows into callers' host-side
/// accounting (e.g. perf_hotpath's "host (L3) overhead" row); the BYTES
/// are still attributed here.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArtifactStat {
    pub calls: u64,
    pub secs: f64,
    pub bytes_h2d: u64,
    pub bytes_d2h: u64,
}

/// A resident artifact input: a host tensor plus its cached device-format
/// conversion.
///
/// A handle's content is immutable — there is deliberately no in-place
/// setter, so "stale cached conversion" is unrepresentable: replacing
/// content means building a new handle (which starts unstaged), and that
/// rebuild is exactly what `StepEngine::swap_weights` does once per
/// weight epoch.  A handle can also be built directly
/// [`from_literal`](InputHandle::from_literal) to feed an output literal
/// back as the next call's input with no host round-trip (the KV-cache
/// flow).
pub struct InputHandle {
    host: Option<HostTensor>,
    lit: Option<Literal>,
}

impl InputHandle {
    /// Resident handle over host data; the first call converts (and
    /// caches) the literal, and every later call reuses it for free.
    pub fn new(tensor: HostTensor) -> InputHandle {
        InputHandle { host: Some(tensor), lit: None }
    }

    /// Handle around an already device-format literal (e.g. a previous
    /// call's output): staging it costs zero bytes.  There is no host
    /// view; callers needing one must convert the literal themselves.
    pub fn from_literal(lit: Literal) -> InputHandle {
        InputHandle { host: None, lit: Some(lit) }
    }

    /// Drop the cached conversion (forces a re-stage on the next call —
    /// the per-call baseline the parity tests and benches compare against).
    pub fn invalidate(&mut self) {
        self.lit = None;
    }

    pub fn host(&self) -> Option<&HostTensor> {
        self.host.as_ref()
    }

    /// True when the next call will reuse the cached literal.
    pub fn is_staged(&self) -> bool {
        self.lit.is_some()
    }

    /// Deconstruct into whatever content survives (error recovery: a
    /// failed call leaves either the host payload, the staged literal, or
    /// both in place).
    pub fn into_parts(self) -> (Option<HostTensor>, Option<Literal>) {
        (self.host, self.lit)
    }
}

/// Output tuple of one artifact call, held as raw literals so callers
/// choose per output: copy to host ([`take_host`](CallOutputs::take_host),
/// counted as `bytes_d2h`) or keep device-format
/// ([`take_literal`](CallOutputs::take_literal), zero copy — feed it back
/// through [`InputHandle::from_literal`]).
pub struct CallOutputs<'a> {
    store: &'a ArtifactStore,
    /// borrowed, not owned — no per-call String allocation on the decode
    /// hot path; callers' name strings outlive their `CallOutputs`
    name: &'a str,
    parts: Vec<Option<Literal>>,
    staged_h2d: u64,
    fetched_d2h: u64,
}

impl CallOutputs<'_> {
    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    /// Bytes converted host→literal for this call (fresh inputs plus any
    /// resident handle whose cache missed its epoch).
    pub fn staged_h2d(&self) -> u64 {
        self.staged_h2d
    }

    /// Bytes copied literal→host via [`take_host`](Self::take_host) so far.
    pub fn fetched_d2h(&self) -> u64 {
        self.fetched_d2h
    }

    /// Take output `i` as a raw literal (no host copy).
    pub fn take_literal(&mut self, i: usize) -> Result<Literal> {
        self.parts
            .get_mut(i)
            .and_then(|p| p.take())
            .ok_or_else(|| anyhow!("{}: output {i} missing or already taken",
                                   self.name))
    }

    /// Take output `i` as a host tensor (copies; counted as d2h traffic).
    pub fn take_host(&mut self, i: usize) -> Result<HostTensor> {
        let lit = self.take_literal(i)?;
        let t = HostTensor::from_literal(&lit)?;
        let b = t.byte_len();
        self.fetched_d2h += b;
        self.store.note_d2h(self.name, b);
        Ok(t)
    }
}

pub struct ArtifactStore {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    /// cumulative profile per artifact — the L3 perf source
    exec_stats: RefCell<HashMap<String, ArtifactStat>>,
}

impl ArtifactStore {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(ArtifactStore {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory this store loads artifacts from.  Rollout worker threads
    /// use it to open their own store: PJRT clients and compiled
    /// executables are not `Send`, so each worker owns a full stack instead
    /// of sharing this one across threads.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        crate::debugln!("runtime", "compiled {name} in {:.2}s",
                        t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with the given inputs; returns the output tuple as
    /// host tensors.  Inputs are shape/dtype-checked against the manifest.
    /// Every input converts and every output copies back — the fully
    /// per-call path (training/scoring artifacts, where inputs change
    /// every call anyway).
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let mut outs = self.call_with_resident(name, &mut [], inputs)?;
        (0..outs.len()).map(|i| outs.take_host(i)).collect()
    }

    /// Execute `name` with `resident` inputs first, then `fresh` inputs —
    /// the order must match the artifact's input signature.  Resident
    /// handles reuse their cached literal when one is staged (staging cost
    /// 0); fresh tensors convert per call.  Outputs come
    /// back as [`CallOutputs`], so callers keep device-format literals for
    /// state that flows into the next call.
    ///
    /// On any failure (staging or execution) the staged literals are put
    /// back into their handles before the error propagates, so resident
    /// state — including recycled KV literals — survives a failed call.
    pub fn call_with_resident<'s>(&'s self, name: &'s str,
                                  resident: &mut [&mut InputHandle],
                                  fresh: &[HostTensor])
                                  -> Result<CallOutputs<'s>> {
        let n_res = resident.len();
        if let Some(sig) = self.manifest.artifacts.get(name) {
            anyhow::ensure!(sig.inputs.len() == n_res + fresh.len(),
                            "{name}: expected {} inputs, got {}",
                            sig.inputs.len(), n_res + fresh.len());
            for (i, h) in resident.iter().enumerate() {
                // literal-only handles (recycled outputs) carry no host
                // view to check; their shape is the artifact's own output
                // shape by construction
                if let Some(t) = h.host() {
                    anyhow::ensure!(t.shape() == sig.inputs[i].shape.as_slice(),
                                    "{name} input {i}: shape {:?} != manifest \
                                     {:?}", t.shape(), sig.inputs[i].shape);
                    anyhow::ensure!(t.dtype_str() == sig.inputs[i].dtype,
                                    "{name} input {i}: dtype {} != manifest {}",
                                    t.dtype_str(), sig.inputs[i].dtype);
                }
            }
            for (j, t) in fresh.iter().enumerate() {
                let i = n_res + j;
                anyhow::ensure!(t.shape() == sig.inputs[i].shape.as_slice(),
                                "{name} input {i}: shape {:?} != manifest {:?}",
                                t.shape(), sig.inputs[i].shape);
                anyhow::ensure!(t.dtype_str() == sig.inputs[i].dtype,
                                "{name} input {i}: dtype {} != manifest {}",
                                t.dtype_str(), sig.inputs[i].dtype);
            }
        }
        self.ensure_compiled(name)?;
        let t0 = Instant::now();
        // stage: take cached literals, convert the rest (counting bytes)
        let mut lits: Vec<Literal> = Vec::with_capacity(n_res + fresh.len());
        let mut staged: u64 = 0;
        // resident indices converted by THIS call (not yet booked anywhere)
        let mut converted_now: Vec<usize> = Vec::new();
        let mut stage_err: Option<anyhow::Error> = None;
        for (i, h) in resident.iter_mut().enumerate() {
            // take the cached literal when present (`is_staged`);
            // otherwise fall through to the host-conversion path
            if let Some(l) = h.lit.take() {
                lits.push(l);
                continue;
            }
            let converted = match h.host.as_ref() {
                Some(t) => t.to_literal().map(|l| (l, t.byte_len())),
                None => Err(anyhow!("{name}: resident input has neither a \
                                     valid cached literal nor host data")),
            };
            match converted {
                Ok((l, b)) => {
                    staged += b;
                    converted_now.push(i);
                    lits.push(l);
                }
                Err(e) => {
                    stage_err = Some(e);
                    break;
                }
            }
        }
        if stage_err.is_none() {
            for t in fresh {
                match t.to_literal() {
                    Ok(l) => {
                        staged += t.byte_len();
                        lits.push(l);
                    }
                    Err(e) => {
                        stage_err = Some(e);
                        break;
                    }
                }
            }
        }
        let exec_result = match stage_err {
            Some(e) => Err(e),
            None => {
                let cache = self.cache.borrow();
                match cache.get(name) {
                    Some(exe) => exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| anyhow::anyhow!(
                            "executing {name}: {e:?}")),
                    None => Err(anyhow!(
                        "{name}: executable missing after \
                         ensure_compiled")),
                }
            }
        };
        // hand the staged literals back to their handles in all cases — a
        // cached conversion (or a recycled KV literal) must survive both a
        // failed stage and a failed execution
        for (h, lit) in resident.iter_mut().zip(lits.drain(..)) {
            h.lit = Some(lit);
        }
        if exec_result.is_err() {
            // this call's conversions were never booked (stats are recorded
            // only on success) — drop them so a retry re-stages and
            // re-counts instead of riding unaccounted cached bytes, keeping
            // "bytes_h2d counts every new conversion" exact across failures
            for &i in &converted_now {
                resident[i].invalidate();
            }
        }
        let result = exec_result?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} result: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.exec_stats.borrow_mut();
            let e = stats.entry(name.to_string()).or_default();
            e.calls += 1;
            e.secs += dt;
            e.bytes_h2d += staged;
        }
        Ok(CallOutputs {
            store: self,
            name,
            parts: parts.into_iter().map(Some).collect(),
            staged_h2d: staged,
            fetched_d2h: 0,
        })
    }

    /// Record device-format→host bytes copied outside a [`CallOutputs`]
    /// extraction (e.g. `StepEngine` materializing a resident KV literal
    /// for a row merge or fork).  Public so engine-side copies land in the
    /// same per-artifact ledger as call-time traffic — `stats()` then
    /// reconciles with the scheduler-level `bytes_d2h` counters instead of
    /// disagreeing by the size of every KV materialization.
    pub fn note_d2h(&self, name: &str, bytes: u64) {
        self.exec_stats
            .borrow_mut()
            .entry(name.to_string())
            .or_default()
            .bytes_d2h += bytes;
    }

    /// Per-artifact profile since start (sorted by total seconds) — used
    /// by the perf report and the L3 "coordinator is not the bottleneck"
    /// check; the byte columns are the copy-tax ledger.
    pub fn stats(&self) -> Vec<(String, ArtifactStat)> {
        let mut v: Vec<(String, ArtifactStat)> = self
            .exec_stats
            .borrow()
            .iter()
            .map(|(k, s)| (k.clone(), *s))
            .collect();
        v.sort_by(|a, b| b.1.secs.total_cmp(&a.1.secs));
        v
    }

    pub fn reset_stats(&self) {
        self.exec_stats.borrow_mut().clear();
    }
}
