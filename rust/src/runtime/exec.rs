//! Typed call wrappers over the artifact store — the API the coordinator,
//! trainer and benches program against.

use std::sync::Arc;

use anyhow::Result;

use crate::quant::delta::{self, DeltaReport};

use super::artifact::ArtifactStore;
use super::tensor::HostTensor;

/// Rollout precision mode (the paper's axis of comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantMode {
    Bf16,
    Int8,
    Fp8,
}

impl QuantMode {
    pub fn tag(&self) -> &'static str {
        match self {
            QuantMode::Bf16 => "bf16",
            QuantMode::Int8 => "int8",
            QuantMode::Fp8 => "fp8",
        }
    }

    pub fn parse(s: &str) -> Option<QuantMode> {
        match s {
            "bf16" | "fp32" | "full" => Some(QuantMode::Bf16),
            "int8" => Some(QuantMode::Int8),
            "fp8" => Some(QuantMode::Fp8),
            _ => None,
        }
    }
}

/// Rollout-engine weights in the precision the engine runs at.
///
/// Payloads are `Arc`'d: cloning weights (one requantization fans out to
/// every engine replica) and pushing them as artifact inputs
/// ([`Self::host_tensors`]) are refcount bumps, never megabyte copies.
#[derive(Clone, Debug)]
pub enum EngineWeights {
    Bf16 { flat: Arc<Vec<f32>> },
    Int8 { a: Arc<Vec<f32>>, qw: Arc<Vec<i8>>, qs: Arc<Vec<f32>> },
    Fp8 { a: Arc<Vec<f32>>, b_fq: Arc<Vec<f32>> },
}

impl EngineWeights {
    pub fn mode(&self) -> QuantMode {
        match self {
            EngineWeights::Bf16 { .. } => QuantMode::Bf16,
            EngineWeights::Int8 { .. } => QuantMode::Int8,
            EngineWeights::Fp8 { .. } => QuantMode::Fp8,
        }
    }

    /// The weight tensors in artifact input order, sharing this value's
    /// storage (zero copy).  The single definition of the weight input
    /// layout — the fused `generate_*`/`logprob_*` calls and
    /// `StepEngine`'s resident weight handles both build from it.
    pub fn host_tensors(&self) -> Vec<HostTensor> {
        match self {
            EngineWeights::Bf16 { flat } => {
                vec![HostTensor::f32_shared(&[flat.len()], flat.clone())]
            }
            EngineWeights::Int8 { a, qw, qs } => {
                vec![HostTensor::f32_shared(&[a.len()], a.clone()),
                     HostTensor::i8_shared(&[qw.len()], qw.clone()),
                     HostTensor::f32_shared(&[qs.len()], qs.clone())]
            }
            EngineWeights::Fp8 { a, b_fq } => {
                vec![HostTensor::f32_shared(&[a.len()], a.clone()),
                     HostTensor::f32_shared(&[b_fq.len()], b_fq.clone())]
            }
        }
    }

    /// Total payload size in bytes (what one full host→device-format
    /// conversion of these weights costs — the per-tick tax the resident
    /// path eliminates).
    pub fn byte_len(&self) -> u64 {
        self.host_tensors().iter().map(|t| t.byte_len()).sum()
    }

    fn push_inputs(&self, inputs: &mut Vec<HostTensor>) {
        inputs.extend(self.host_tensors());
    }
}

/// Result of one batched rollout wave.
#[derive(Clone, Debug)]
pub struct GenerateOut {
    /// [B, S] tokens (prompt + generation, PAD elsewhere)
    pub tokens: Vec<i32>,
    /// [B, S] behavior logprobs on generated positions
    pub logprob: Vec<f32>,
    /// [B, S] 1.0 on generated positions (EOS inclusive)
    pub mask: Vec<f32>,
}

/// Result of teacher-forced scoring.
#[derive(Clone, Debug)]
pub struct ScoreOut {
    pub logprob: Vec<f32>,
    pub value: Vec<f32>,
    pub entropy: Vec<f32>,
}

/// One RL/SFT minibatch for train_step.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub adv: Vec<f32>,
    pub lp_behav: Vec<f32>,
    pub lp_prox: Vec<f32>,
    pub lp_ref: Vec<f32>,
    pub returns: Vec<f32>,
    pub old_values: Vec<f32>,
}

pub struct Runtime {
    pub store: ArtifactStore,
}

/// Next output of an artifact call, with the artifact named in the error.
/// An artifact returning fewer outputs than its signature promises is a
/// build problem (stale `make artifacts`), and it surfaces as a typed
/// error on the serving path instead of a panicking `unwrap`.
fn next_out(it: &mut std::vec::IntoIter<HostTensor>, name: &str)
            -> Result<HostTensor> {
    it.next().ok_or_else(|| anyhow::anyhow!(
        "{name}: artifact returned fewer outputs than its signature"))
}

impl Runtime {
    pub fn open(dir: &std::path::Path) -> Result<Runtime> {
        Ok(Runtime { store: ArtifactStore::open(dir)? })
    }

    pub fn manifest(&self) -> &super::manifest::Manifest {
        &self.store.manifest
    }

    /// Artifact directory this runtime executes from.  The threaded rollout
    /// service hands it to engine-worker factories so each worker thread
    /// opens its own `Runtime` (own PJRT client + compile cache) — the
    /// "owned artifact handles per worker" layering that keeps all
    /// non-`Send` XLA state confined to the thread that created it.
    pub fn artifact_dir(&self) -> &std::path::Path {
        self.store.dir()
    }

    /// Deterministic initial parameters from a seed.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.store.call("init_params", &[HostTensor::scalar_i32(seed)])?;
        let mut it = out.into_iter();
        Ok(next_out(&mut it, "init_params")?.into_f32())
    }

    /// Quantize section-B weights to int8 (per-output-channel scales).
    pub fn quantize_int8(&self, flat_b: &[f32]) -> Result<(Vec<i8>, Vec<f32>)> {
        let out = self.store.call(
            "quantize_int8",
            &[HostTensor::f32(&[flat_b.len()], flat_b.to_vec())],
        )?;
        let mut it = out.into_iter();
        Ok((next_out(&mut it, "quantize_int8")?.into_i8(),
            next_out(&mut it, "quantize_int8")?.into_f32()))
    }

    /// Fake-quantize section-B weights onto the e4m3 grid.
    pub fn quantize_fp8(&self, flat_b: &[f32]) -> Result<Vec<f32>> {
        let out = self.store.call(
            "quantize_fp8",
            &[HostTensor::f32(&[flat_b.len()], flat_b.to_vec())],
        )?;
        let mut it = out.into_iter();
        Ok(next_out(&mut it, "quantize_fp8")?.into_f32())
    }

    /// Build rollout-engine weights from full-precision params.
    pub fn engine_weights(&self, mode: QuantMode, params: &[f32]) -> Result<EngineWeights> {
        let a_size = self.manifest().a_size;
        match mode {
            QuantMode::Bf16 => {
                Ok(EngineWeights::Bf16 { flat: Arc::new(params.to_vec()) })
            }
            QuantMode::Int8 => {
                let (qw, qs) = self.quantize_int8(&params[a_size..])?;
                Ok(EngineWeights::Int8 {
                    a: Arc::new(params[..a_size].to_vec()),
                    qw: Arc::new(qw),
                    qs: Arc::new(qs),
                })
            }
            QuantMode::Fp8 => {
                let b_fq = self.quantize_fp8(&params[a_size..])?;
                Ok(EngineWeights::Fp8 {
                    a: Arc::new(params[..a_size].to_vec()),
                    b_fq: Arc::new(b_fq),
                })
            }
        }
    }

    /// Delta form of [`Self::engine_weights`]: rebuild only what changed.
    ///
    /// Quantizes through the SAME artifacts as the full path — so a delta
    /// refresh is bit-identical to a full one by construction (the host
    /// mirrors in [`quant::delta`](crate::quant::delta) are close but not
    /// bit-exact vs the fp8 artifact) — then compares the fresh payloads
    /// bitwise against `prev` and returns the previous `Arc` for every
    /// payload that did not change.  Downstream, `Arc` pointer equality
    /// is the change signal: `StepEngine::swap_weights` keeps the
    /// resident `InputHandle` (and its cached device literal) for every
    /// pointer-equal payload, so unchanged weights restage zero bytes.
    ///
    /// The [`DeltaReport`] counts changes per *manifest tensor*
    /// (section-A vectors by raw f32 bits, section-B matrices by
    /// quantized payload) for the `sched_requant_tensors_changed/skipped`
    /// metrics; `prev = None` or a rollout-mode flip falls back to a full
    /// build with every tensor counted changed.
    pub fn engine_weights_delta(&self, mode: QuantMode, params: &[f32],
                                prev: Option<&EngineWeights>)
                                -> Result<(EngineWeights, DeltaReport)> {
        let man = self.manifest();
        let n_tensors = man.params.len();
        let a_size = man.a_size;
        let Some(prev) = prev.filter(|p| p.mode() == mode) else {
            return Ok((self.engine_weights(mode, params)?,
                       DeltaReport::all_changed(n_tensors)));
        };
        // `prev.mode() == mode` above, so each arm rebuilds its own
        // variant — no cross-mode arm exists.
        let reuse_a = |old: &Arc<Vec<f32>>| {
            if delta::f32_bits_eq(old, &params[..a_size]) {
                old.clone()
            } else {
                Arc::new(params[..a_size].to_vec())
            }
        };
        let reuse_f32 = |old: &Arc<Vec<f32>>, new: Vec<f32>| {
            if delta::f32_bits_eq(old, &new) {
                old.clone()
            } else {
                Arc::new(new)
            }
        };
        match prev {
            EngineWeights::Bf16 { flat } => {
                let report = delta::flat_delta(man, flat, params);
                let flat = if delta::f32_bits_eq(flat, params) {
                    flat.clone()
                } else {
                    Arc::new(params.to_vec())
                };
                Ok((EngineWeights::Bf16 { flat }, report))
            }
            EngineWeights::Int8 { a, qw, qs } => {
                let (nqw, nqs) = self.quantize_int8(&params[a_size..])?;
                let mut report =
                    delta::section_a_delta(man, a, &params[..a_size]);
                report.merge(delta::int8_delta(man, qw, qs, &nqw, &nqs));
                let qw = if nqw[..] == qw[..] {
                    qw.clone()
                } else {
                    Arc::new(nqw)
                };
                Ok((EngineWeights::Int8 {
                    a: reuse_a(a),
                    qw,
                    qs: reuse_f32(qs, nqs),
                }, report))
            }
            EngineWeights::Fp8 { a, b_fq } => {
                let nfq = self.quantize_fp8(&params[a_size..])?;
                let mut report =
                    delta::section_a_delta(man, a, &params[..a_size]);
                report.merge(delta::fp8_delta(man, b_fq, &nfq));
                Ok((EngineWeights::Fp8 {
                    a: reuse_a(a),
                    b_fq: reuse_f32(b_fq, nfq),
                }, report))
            }
        }
    }

    /// UAQ invariant scaling (Eq. 11): returns the rescaled parameters.
    pub fn uaq_scale(&self, params: &[f32], s: f32) -> Result<Vec<f32>> {
        let out = self.store.call(
            "uaq_scale",
            &[
                HostTensor::f32(&[params.len()], params.to_vec()),
                HostTensor::scalar_f32(s),
            ],
        )?;
        let mut it = out.into_iter();
        Ok(next_out(&mut it, "uaq_scale")?.into_f32())
    }

    /// Batched rollout (prefill + scan decode + sampling in one artifact).
    ///
    /// `tokens` is [B, S] with left-aligned prompts; `lens` their lengths.
    pub fn generate(&self, w: &EngineWeights, tokens: &[i32], lens: &[i32],
                    seed: i32, temp: f32, top_p: f32) -> Result<GenerateOut> {
        let m = self.manifest();
        let (b, s) = (m.rollout_batch, m.max_seq);
        anyhow::ensure!(tokens.len() == b * s, "tokens must be [{b}, {s}]");
        anyhow::ensure!(lens.len() == b);
        let mut inputs = Vec::with_capacity(8);
        w.push_inputs(&mut inputs);
        inputs.push(HostTensor::i32(&[b, s], tokens.to_vec()));
        inputs.push(HostTensor::i32(&[b], lens.to_vec()));
        inputs.push(HostTensor::scalar_i32(seed));
        inputs.push(HostTensor::scalar_f32(temp));
        inputs.push(HostTensor::scalar_f32(top_p));
        let name = format!("generate_{}", w.mode().tag());
        let out = self.store.call(&name, &inputs)?;
        let mut it = out.into_iter();
        Ok(GenerateOut {
            tokens: next_out(&mut it, &name)?.into_i32(),
            logprob: next_out(&mut it, &name)?.into_f32(),
            mask: next_out(&mut it, &name)?.into_f32(),
        })
    }

    /// Teacher-forced scoring under the full-precision actor:
    /// per-token logprob, value and entropy ([B, T] each).
    pub fn score_bf16(&self, params: &[f32], tokens: &[i32]) -> Result<ScoreOut> {
        let m = self.manifest();
        let (b, t) = (m.train_batch, m.max_seq);
        anyhow::ensure!(tokens.len() == b * t, "tokens must be [{b}, {t}]");
        let out = self.store.call(
            "logprob_bf16",
            &[
                HostTensor::f32(&[params.len()], params.to_vec()),
                HostTensor::i32(&[b, t], tokens.to_vec()),
            ],
        )?;
        let mut it = out.into_iter();
        Ok(ScoreOut {
            logprob: next_out(&mut it, "logprob_bf16")?.into_f32(),
            value: next_out(&mut it, "logprob_bf16")?.into_f32(),
            entropy: next_out(&mut it, "logprob_bf16")?.into_f32(),
        })
    }

    /// Teacher-forced behavior logprobs under quantized engine weights
    /// (used for Fig. 4b analysis and the engine-consistency tests).
    pub fn score_engine(&self, w: &EngineWeights, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = self.manifest();
        let (b, t) = (m.train_batch, m.max_seq);
        anyhow::ensure!(tokens.len() == b * t);
        let mut inputs = Vec::with_capacity(4);
        w.push_inputs(&mut inputs);
        inputs.push(HostTensor::i32(&[b, t], tokens.to_vec()));
        let name = format!("logprob_{}", w.mode().tag());
        let out = self.store.call(&name, &inputs)?;
        let mut it = out.into_iter();
        Ok(next_out(&mut it, &name)?.into_f32())
    }

    /// One RL optimization step; updates `store` in place, returns metrics.
    pub fn train_step(&self, ps: &mut super::params::ParamStore,
                      batch: &TrainBatch, flags: &[f32]) -> Result<Vec<f32>> {
        let m = self.manifest();
        let (b, t) = (m.train_batch, m.max_seq);
        anyhow::ensure!(batch.tokens.len() == b * t);
        anyhow::ensure!(flags.len() == m.flags.n);
        ps.step += 1;
        let grid = |v: &Vec<f32>| HostTensor::f32(&[b, t], v.clone());
        let n = ps.params.len();
        let inputs = vec![
            HostTensor::f32(&[n], ps.params.clone()),
            HostTensor::f32(&[n], ps.m.clone()),
            HostTensor::f32(&[n], ps.v.clone()),
            HostTensor::scalar_f32(ps.step as f32),
            HostTensor::i32(&[b, t], batch.tokens.clone()),
            grid(&batch.mask),
            grid(&batch.adv),
            grid(&batch.lp_behav),
            grid(&batch.lp_prox),
            grid(&batch.lp_ref),
            grid(&batch.returns),
            grid(&batch.old_values),
            HostTensor::f32(&[flags.len()], flags.to_vec()),
        ];
        let out = self.store.call("train_step", &inputs)?;
        let mut it = out.into_iter();
        ps.params = next_out(&mut it, "train_step")?.into_f32();
        ps.m = next_out(&mut it, "train_step")?.into_f32();
        ps.v = next_out(&mut it, "train_step")?.into_f32();
        Ok(next_out(&mut it, "train_step")?.into_f32())
    }

    /// One supervised (cross-entropy) step — builds the RL base model.
    pub fn sft_step(&self, ps: &mut super::params::ParamStore,
                    tokens: &[i32], mask: &[f32], flags: &[f32]) -> Result<Vec<f32>> {
        let m = self.manifest();
        let (b, t) = (m.train_batch, m.max_seq);
        anyhow::ensure!(tokens.len() == b * t);
        ps.step += 1;
        let n = ps.params.len();
        let inputs = vec![
            HostTensor::f32(&[n], ps.params.clone()),
            HostTensor::f32(&[n], ps.m.clone()),
            HostTensor::f32(&[n], ps.v.clone()),
            HostTensor::scalar_f32(ps.step as f32),
            HostTensor::i32(&[b, t], tokens.to_vec()),
            HostTensor::f32(&[b, t], mask.to_vec()),
            HostTensor::f32(&[flags.len()], flags.to_vec()),
        ];
        let out = self.store.call("sft_step", &inputs)?;
        let mut it = out.into_iter();
        ps.params = next_out(&mut it, "sft_step")?.into_f32();
        ps.m = next_out(&mut it, "sft_step")?.into_f32();
        ps.v = next_out(&mut it, "sft_step")?.into_f32();
        Ok(next_out(&mut it, "sft_step")?.into_f32())
    }
}
