//! Parse `artifacts/manifest.json` — the contract between the Python
//! compile path and the Rust runtime.  All shapes, parameter layouts, flag
//! indices and metric names come from here; the coordinator never
//! hard-codes model dimensions.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ScaleEntry {
    pub name: String,
    pub offset: usize,
    pub channels: usize,
}

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Flag indices into the train_step `flags` vector (mirrors
/// python/compile/config.py::TrainFlags).
#[derive(Clone, Debug)]
pub struct FlagIndex {
    pub obj_mode: usize,
    pub eps_low: usize,
    pub eps_high: usize,
    pub tis_cap: usize,
    pub kl_coef: usize,
    pub vf_coef: usize,
    pub ent_coef: usize,
    pub token_mean: usize,
    pub lr: usize,
    pub beta1: usize,
    pub beta2: usize,
    pub adam_eps: usize,
    pub weight_decay: usize,
    pub value_clip: usize,
    pub max_grad_norm: usize,
    pub n: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    // model dims
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub max_prompt: usize,
    pub max_new: usize,
    pub rollout_batch: usize,
    pub train_batch: usize,
    // flat layouts
    pub a_size: usize,
    pub b_size: usize,
    pub n_params: usize,
    pub n_qscales: usize,
    pub params: Vec<ParamEntry>,
    pub qscales: Vec<ScaleEntry>,
    // misc
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub flags: FlagIndex,
    pub metric_names: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .with_context(|| format!("manifest: missing numeric field {key:?}"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let cfg = j.req("config");
        let params = cfg
            .req("params")
            .as_arr()
            .context("config.params not an array")?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.req("name").as_str().unwrap_or_default().to_string(),
                    shape: p
                        .req("shape")
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                    offset: usize_of(p, "offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let qscales = cfg
            .req("qscales")
            .as_arr()
            .context("config.qscales not an array")?
            .iter()
            .map(|p| {
                Ok(ScaleEntry {
                    name: p.req("name").as_str().unwrap_or_default().to_string(),
                    offset: usize_of(p, "offset")?,
                    channels: usize_of(p, "channels")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let fl = j.req("flags");
        let flags = FlagIndex {
            obj_mode: usize_of(fl, "OBJ_MODE")?,
            eps_low: usize_of(fl, "EPS_LOW")?,
            eps_high: usize_of(fl, "EPS_HIGH")?,
            tis_cap: usize_of(fl, "TIS_CAP")?,
            kl_coef: usize_of(fl, "KL_COEF")?,
            vf_coef: usize_of(fl, "VF_COEF")?,
            ent_coef: usize_of(fl, "ENT_COEF")?,
            token_mean: usize_of(fl, "TOKEN_MEAN")?,
            lr: usize_of(fl, "LR")?,
            beta1: usize_of(fl, "BETA1")?,
            beta2: usize_of(fl, "BETA2")?,
            adam_eps: usize_of(fl, "ADAM_EPS")?,
            weight_decay: usize_of(fl, "WEIGHT_DECAY")?,
            value_clip: usize_of(fl, "VALUE_CLIP")?,
            max_grad_norm: usize_of(fl, "MAX_GRAD_NORM")?,
            n: usize_of(fl, "N")?,
        };

        let sp = j.req("special_tokens");
        let metric_names = j
            .req("metric_names")
            .as_arr()
            .context("metric_names")?
            .iter()
            .filter_map(|x| x.as_str().map(|s| s.to_string()))
            .collect();

        let mut artifacts = BTreeMap::new();
        if let Some(obj) = j.req("artifacts").as_obj() {
            for (name, sig) in obj {
                let parse_sigs = |key: &str| -> Result<Vec<TensorSig>> {
                    sig.req(key)
                        .as_arr()
                        .context("artifact sig")?
                        .iter()
                        .map(|t| {
                            Ok(TensorSig {
                                shape: t
                                    .req("shape")
                                    .as_arr()
                                    .context("shape")?
                                    .iter()
                                    .filter_map(|x| x.as_usize())
                                    .collect(),
                                dtype: t
                                    .req("dtype")
                                    .as_str()
                                    .unwrap_or_default()
                                    .to_string(),
                            })
                        })
                        .collect()
                };
                artifacts.insert(
                    name.clone(),
                    ArtifactSig {
                        inputs: parse_sigs("inputs")?,
                        outputs: parse_sigs("outputs")?,
                    },
                );
            }
        }

        Ok(Manifest {
            vocab_size: usize_of(cfg, "vocab_size")?,
            d_model: usize_of(cfg, "d_model")?,
            n_heads: usize_of(cfg, "n_heads")?,
            n_layers: usize_of(cfg, "n_layers")?,
            d_ff: usize_of(cfg, "d_ff")?,
            head_dim: usize_of(cfg, "head_dim")?,
            max_seq: usize_of(cfg, "max_seq")?,
            max_prompt: usize_of(cfg, "max_prompt")?,
            max_new: usize_of(j, "max_new")?,
            rollout_batch: usize_of(cfg, "rollout_batch")?,
            train_batch: usize_of(cfg, "train_batch")?,
            a_size: usize_of(cfg, "a_size")?,
            b_size: usize_of(cfg, "b_size")?,
            n_params: usize_of(cfg, "n_params")?,
            n_qscales: usize_of(cfg, "n_qscales")?,
            params,
            qscales,
            pad_id: usize_of(sp, "pad")? as i32,
            bos_id: usize_of(sp, "bos")? as i32,
            eos_id: usize_of(sp, "eos")? as i32,
            flags,
            metric_names,
            artifacts,
        })
    }

    pub fn param(&self, name: &str) -> Option<&ParamEntry> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Consistency checks between layout arithmetic and declared sizes.
    pub fn validate(&self) -> Result<()> {
        let total: usize = self.params.iter().map(|p| p.numel()).sum();
        anyhow::ensure!(total == self.n_params,
                        "param layout sums to {total}, manifest says {}",
                        self.n_params);
        anyhow::ensure!(self.a_size + self.b_size == self.n_params,
                        "a_size + b_size != n_params");
        let qtotal: usize = self.qscales.iter().map(|s| s.channels).sum();
        anyhow::ensure!(qtotal == self.n_qscales, "qscale layout mismatch");
        anyhow::ensure!(self.max_prompt + self.max_new <= self.max_seq,
                        "prompt + max_new exceeds context");
        // offsets must be strictly increasing and contiguous
        let mut off = 0;
        for p in &self.params {
            anyhow::ensure!(p.offset == off, "param {} offset gap", p.name);
            off += p.numel();
        }
        Ok(())
    }
}
