//! L3 runtime: load AOT artifacts (HLO text) and execute them via the PJRT
//! CPU client.  Python never runs on this path — `make artifacts` is the
//! only place jax executes.
//!
//! # Residency boundary (who pays for data movement, and when)
//!
//! Every artifact call crosses a host↔device-format boundary; this module
//! defines four tiers of traffic across it:
//!
//! * **per-call** — fresh [`HostTensor`] inputs convert to PJRT literals
//!   at call time and outputs copy back out
//!   ([`ArtifactStore::call`](artifact::ArtifactStore::call)).  Right for
//!   training/scoring inputs that change every call anyway (token grids,
//!   parameters mid-optimization).
//! * **per-epoch** — [`InputHandle`](artifact::InputHandle)s cache the
//!   converted literal of an immutable payload for the handle's lifetime
//!   ([`ArtifactStore::call_with_resident`](artifact::ArtifactStore::call_with_resident));
//!   callers replace handles when content changes.  This is how
//!   rollout-engine weights convert at most once per
//!   `WeightEpoch`/requantization instead of once per decode tick.
//! * **per-delta** — the change-proportional refinement of per-epoch:
//!   [`Runtime::engine_weights_delta`](exec::Runtime::engine_weights_delta)
//!   clones the previous epoch's `Arc` for every payload that requantized
//!   bit-identically, and `StepEngine::swap_weights` keeps the existing
//!   handle (cached conversion and all) for every pointer-equal payload.
//!   With small RL steps (the paper's premise) quantization masks most
//!   updates, so a typical refresh re-converts only the payloads that
//!   actually moved — the replaced remainder is the `swap_bytes_h2d`
//!   metric, and a zero-change refresh stages zero weight bytes.
//! * **never** — output literals taken raw from
//!   [`CallOutputs`](artifact::CallOutputs) and fed back through
//!   `InputHandle::from_literal` stay in device format across calls.  The
//!   step engine's KV caches ride this tier between decode ticks.
//!
//! [`HostTensor`] payloads are `Arc`-backed, so the *host* side of the
//! boundary is copy-free too: weights move from the quantizer through
//! [`EngineWeights`] into call inputs without cloning vectors.  What
//! traffic remains is measured per artifact
//! ([`ArtifactStat`](artifact::ArtifactStat)'s `bytes_h2d`/`bytes_d2h`),
//! because on a GPU backend this same boundary is PCIe — keeping it near
//! zero on the decode hot loop is what makes quantized rollout pay off
//! (QuRL's premise; see ROADMAP).

pub mod artifact;
pub mod exec;
pub mod manifest;
pub mod params;
pub mod tensor;

pub use artifact::{ArtifactStat, ArtifactStore, CallOutputs, InputHandle};
pub use exec::{EngineWeights, GenerateOut, QuantMode, Runtime, ScoreOut, TrainBatch};
pub use manifest::Manifest;
pub use params::ParamStore;
pub use tensor::HostTensor;
