//! L3 runtime: load AOT artifacts (HLO text) and execute them via the PJRT
//! CPU client.  Python never runs on this path — `make artifacts` is the
//! only place jax executes.

pub mod artifact;
pub mod exec;
pub mod manifest;
pub mod params;
pub mod tensor;

pub use artifact::ArtifactStore;
pub use exec::{EngineWeights, GenerateOut, QuantMode, Runtime, ScoreOut, TrainBatch};
pub use manifest::Manifest;
pub use params::ParamStore;
pub use tensor::HostTensor;
