//! Flat parameter store + optimizer state + binary checkpoints.
//!
//! Parameters live as one contiguous `Vec<f32>` in the manifest's layout
//! (section A: embeddings/norms/heads, then section B: quantized matrices).
//! Checkpoints are a tiny self-describing binary format so examples and
//! benches can share a pretrained base model.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::hash::{fnv1a64_continue, FNV_OFFSET};

use super::manifest::Manifest;

/// Legacy header: no checksum, written non-atomically.  Still accepted on
/// load so pre-existing artifacts (`base_model.bin`) keep working.
const MAGIC_V1: &[u8; 8] = b"QURLCKP1";
/// Current header: same layout as V1 plus a trailing FNV-1a 64 digest over
/// every preceding byte (magic + header + payload).  Written atomically
/// (temp + fsync + rename), so a reader never observes a torn V2 file at
/// the final path; the checksum catches truncation/corruption that happens
/// after the rename (bit rot, partial copies).
const MAGIC_V2: &[u8; 8] = b"QURLCKP2";

/// Hard ceiling on the parameter count a checkpoint header may claim —
/// a corrupted length field must become a typed error, not a
/// multi-terabyte allocation attempt.
const MAX_PARAMS: usize = 1 << 32;

/// Actor parameters + Adam state + step counter.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub a_size: usize,
}

impl ParamStore {
    pub fn new(manifest: &Manifest, params: Vec<f32>) -> Self {
        assert_eq!(params.len(), manifest.n_params);
        let n = params.len();
        ParamStore {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            a_size: manifest.a_size,
        }
    }

    /// Section A (never-quantized parameters).
    pub fn section_a(&self) -> &[f32] {
        &self.params[..self.a_size]
    }

    /// Section B (quantized matrices).
    pub fn section_b(&self) -> &[f32] {
        &self.params[self.a_size..]
    }

    /// Named view using the manifest layout.
    pub fn view<'a>(&'a self, manifest: &Manifest, name: &str) -> Option<&'a [f32]> {
        let p = manifest.param(name)?;
        Some(&self.params[p.offset..p.offset + p.numel()])
    }

    /// Reset the optimizer (paper: fresh Adam state per RL stage).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    pub fn l2_norm(&self) -> f64 {
        self.params.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    // ---- checkpoint I/O ----------------------------------------------------

    /// Write a V2 checkpoint crash-safely: stage the full payload in a
    /// sibling `.tmp` file, fsync it, then atomically rename over `path`
    /// (and best-effort fsync the parent directory so the rename itself is
    /// durable).  A crash at any point leaves either the previous file or
    /// a stray `.tmp` — never a torn checkpoint at the final path.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let tmp = tmp_path(path);
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        let mut header = Vec::with_capacity(32);
        header.extend_from_slice(MAGIC_V2);
        header.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        header.extend_from_slice(&self.step.to_le_bytes());
        header.extend_from_slice(&(self.a_size as u64).to_le_bytes());
        let mut sum = fnv1a64_continue(FNV_OFFSET, &header);
        f.write_all(&header)?;
        for v in [&self.params, &self.m, &self.v] {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            sum = fnv1a64_continue(sum, bytes);
            f.write_all(bytes)?;
        }
        f.write_all(&sum.to_le_bytes())?;
        f.sync_all()
            .with_context(|| format!("fsync of staged checkpoint {tmp:?}"))?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} into {path:?}"))?;
        if let Some(parent) = path.parent() {
            if let Ok(dir) = std::fs::File::open(parent) {
                let _ = dir.sync_all(); // durability of the rename itself
            }
        }
        Ok(())
    }

    /// Load a checkpoint, accepting the current V2 format (checksummed)
    /// and the legacy V1 format (pre-checksum artifacts such as
    /// `base_model.bin`).  Truncated or corrupted files are typed errors
    /// naming the path — never garbage weights.
    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)
            .with_context(|| format!("truncated checkpoint header in {path:?}"))?;
        if &magic == MAGIC_V2 {
            Self::load_body(&mut f, path, true)
        } else if &magic == MAGIC_V1 {
            Self::load_body(&mut f, path, false)
        } else {
            bail!("{path:?} is not a qurl checkpoint (unknown magic \
                   {magic:02x?}; known versions: QURLCKP1, QURLCKP2)");
        }
    }

    /// Shared V1/V2 body reader; `checksummed` selects whether a trailing
    /// FNV-1a digest is expected and verified.
    fn load_body(f: &mut std::fs::File, path: &Path, checksummed: bool)
                 -> Result<ParamStore> {
        let magic = if checksummed { MAGIC_V2 } else { MAGIC_V1 };
        let mut sum = fnv1a64_continue(FNV_OFFSET, magic);
        let mut header = [0u8; 24];
        f.read_exact(&mut header)
            .with_context(|| format!("truncated checkpoint header in {path:?}"))?;
        sum = fnv1a64_continue(sum, &header);
        let word = |i: usize| -> u64 {
            let mut u = [0u8; 8];
            u.copy_from_slice(&header[i * 8..i * 8 + 8]);
            u64::from_le_bytes(u)
        };
        let n = word(0) as usize;
        let step = word(1);
        let a_size = word(2) as usize;
        if n > MAX_PARAMS || a_size > n {
            bail!("implausible checkpoint header in {path:?}: \
                   n_params={n} a_size={a_size} (corrupt length field?)");
        }
        let mut read_vec = |section: &str| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes).with_context(|| {
                format!("truncated checkpoint {path:?}: {section} section \
                         short of {} bytes", n * 4)
            })?;
            sum = fnv1a64_continue(sum, &bytes);
            let mut out = vec![0.0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
            }
            Ok(out)
        };
        let params = read_vec("params")?;
        let m = read_vec("adam-m")?;
        let v = read_vec("adam-v")?;
        drop(read_vec);
        if checksummed {
            let mut tail = [0u8; 8];
            f.read_exact(&mut tail).with_context(|| {
                format!("truncated checkpoint {path:?}: checksum missing")
            })?;
            let expect = u64::from_le_bytes(tail);
            if sum != expect {
                bail!("checksum mismatch in {path:?}: computed \
                       {sum:#018x}, stored {expect:#018x} (torn or \
                       corrupted checkpoint)");
            }
        }
        Ok(ParamStore { params, m, v, step, a_size })
    }
}

/// Sibling staging path for atomic writes: `<file>.tmp` in the same
/// directory, so the final `rename` never crosses a filesystem boundary.
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("ckpt"));
    name.push(".tmp");
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt");
        let path = dir.join("t.bin");
        let mut ps = ParamStore {
            params: (0..100).map(|i| i as f32 * 0.5).collect(),
            m: vec![0.25; 100],
            v: vec![0.125; 100],
            step: 7,
            a_size: 40,
        };
        ps.params[3] = -1.5;
        ps.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.params, ps.params);
        assert_eq!(back.m, ps.m);
        assert_eq!(back.v, ps.v);
        assert_eq!(back.step, 7);
        assert_eq!(back.a_size, 40);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn small_store() -> ParamStore {
        ParamStore {
            params: (0..32).map(|i| (i as f32 - 7.0) * 0.25).collect(),
            m: vec![0.5; 32],
            v: vec![0.0625; 32],
            step: 3,
            a_size: 8,
        }
    }

    /// Legacy V1 files (pre-checksum `base_model.bin` artifacts) must
    /// still load byte-for-byte.
    #[test]
    fn legacy_v1_format_still_loads() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bin");
        let ps = small_store();
        // hand-write the V1 layout: magic, n, step, a_size, 3 raw sections
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"QURLCKP1");
        bytes.extend_from_slice(&(ps.params.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&ps.step.to_le_bytes());
        bytes.extend_from_slice(&(ps.a_size as u64).to_le_bytes());
        for sec in [&ps.params, &ps.m, &ps.v] {
            for x in sec.iter() {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(&path, &bytes).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.params, ps.params);
        assert_eq!((back.step, back.a_size), (3, 8));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Truncated payload = typed error whose message names the path —
    /// never a short-read panic or a garbage-weights resume.
    #[test]
    fn truncated_file_is_typed_error_naming_path() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.bin");
        small_store().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in [4usize, 20, 40, full.len() - 4] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = ParamStore::load(&path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("torn.bin"),
                    "cut={cut}: error must name the path: {msg}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A flipped payload byte fails the V2 checksum with a typed error.
    #[test]
    fn corrupted_payload_fails_checksum() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flip.bin");
        small_store().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = ParamStore::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum mismatch") && msg.contains("flip.bin"),
                "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The atomic protocol leaves no `.tmp` straggler after a successful
    /// save, and saving over an existing checkpoint replaces it whole.
    #[test]
    fn save_is_atomic_and_replaces_in_place() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.bin");
        let mut ps = small_store();
        ps.save(&path).unwrap();
        ps.params[0] = 123.5;
        ps.step = 9;
        ps.save(&path).unwrap();
        assert!(!tmp_path(&path).exists(), "staging file left behind");
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.params[0], 123.5);
        assert_eq!(back.step, 9);
        std::fs::remove_dir_all(&dir).ok();
    }
}
