//! Flat parameter store + optimizer state + binary checkpoints.
//!
//! Parameters live as one contiguous `Vec<f32>` in the manifest's layout
//! (section A: embeddings/norms/heads, then section B: quantized matrices).
//! Checkpoints are a tiny self-describing binary format so examples and
//! benches can share a pretrained base model.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

const MAGIC: &[u8; 8] = b"QURLCKP1";

/// Actor parameters + Adam state + step counter.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub a_size: usize,
}

impl ParamStore {
    pub fn new(manifest: &Manifest, params: Vec<f32>) -> Self {
        assert_eq!(params.len(), manifest.n_params);
        let n = params.len();
        ParamStore {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            a_size: manifest.a_size,
        }
    }

    /// Section A (never-quantized parameters).
    pub fn section_a(&self) -> &[f32] {
        &self.params[..self.a_size]
    }

    /// Section B (quantized matrices).
    pub fn section_b(&self) -> &[f32] {
        &self.params[self.a_size..]
    }

    /// Named view using the manifest layout.
    pub fn view<'a>(&'a self, manifest: &Manifest, name: &str) -> Option<&'a [f32]> {
        let p = manifest.param(name)?;
        Some(&self.params[p.offset..p.offset + p.numel()])
    }

    /// Reset the optimizer (paper: fresh Adam state per RL stage).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    pub fn l2_norm(&self) -> f64 {
        self.params.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    // ---- checkpoint I/O ----------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.a_size as u64).to_le_bytes())?;
        for v in [&self.params, &self.m, &self.v] {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a qurl checkpoint");
        }
        let mut u = [0u8; 8];
        f.read_exact(&mut u)?;
        let n = u64::from_le_bytes(u) as usize;
        f.read_exact(&mut u)?;
        let step = u64::from_le_bytes(u);
        f.read_exact(&mut u)?;
        let a_size = u64::from_le_bytes(u) as usize;
        let mut read_vec = |n: usize| -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let mut out = vec![0.0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
            }
            Ok(out)
        };
        let params = read_vec(n)?;
        let m = read_vec(n)?;
        let v = read_vec(n)?;
        Ok(ParamStore { params, m, v, step, a_size })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt");
        let path = dir.join("t.bin");
        let mut ps = ParamStore {
            params: (0..100).map(|i| i as f32 * 0.5).collect(),
            m: vec![0.25; 100],
            v: vec![0.125; 100],
            step: 7,
            a_size: 40,
        };
        ps.params[3] = -1.5;
        ps.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.params, ps.params);
        assert_eq!(back.m, ps.m);
        assert_eq!(back.v, ps.v);
        assert_eq!(back.step, 7);
        assert_eq!(back.a_size, 40);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("qurl_test_ckpt2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
