//! Host-side tensors and conversions to/from PJRT `Literal`s.
//!
//! The coordinator keeps model state (parameters, Adam moments, token
//! batches, metrics) as plain Rust vectors and converts at artifact-call
//! boundaries.  All conversions are shape-checked against the manifest.
//!
//! Payloads are backed by `Arc`'d storage (copy-on-write): cloning a
//! `HostTensor` — or building one from an already-shared buffer via the
//! `*_shared` constructors — never copies the data.  That is what lets
//! [`EngineWeights`](super::EngineWeights) push multi-megabyte weight
//! tensors as artifact inputs on every rollout tick without cloning the
//! underlying vectors (the PR-4 residency work; see `runtime/artifact.rs`
//! for where conversions themselves are cached).

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

/// Dense host tensor; dtype is encoded in the variant.  `Clone` is an
/// `Arc` bump, not a data copy.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Arc<Vec<f32>> },
    I32 { shape: Vec<usize>, data: Arc<Vec<i32>> },
    I8 { shape: Vec<usize>, data: Arc<Vec<i8>> },
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        Self::f32_shared(shape, Arc::new(data))
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data: Arc::new(data) }
    }

    pub fn i8(shape: &[usize], data: Vec<i8>) -> Self {
        Self::i8_shared(shape, Arc::new(data))
    }

    /// Zero-copy constructor over an already-shared buffer (weight tensors
    /// live in [`EngineWeights`](super::EngineWeights) as `Arc`s and are
    /// pushed as inputs once per engine call).
    pub fn f32_shared(shape: &[usize], data: Arc<Vec<f32>>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    /// Zero-copy constructor over an already-shared i8 buffer.
    pub fn i8_shared(shape: &[usize], data: Arc<Vec<i8>>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::I8 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::f32(&[], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::i32(&[], vec![x])
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::f32(shape, vec![0.0; numel(shape)])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::I8 { shape, .. } => shape,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "float32",
            HostTensor::I32 { .. } => "int32",
            HostTensor::I8 { .. } => "int8",
        }
    }

    /// True when both tensors share one payload allocation (same dtype,
    /// same `Arc`).  This is the delta-requantization change signal:
    /// [`Runtime::engine_weights_delta`](super::Runtime::engine_weights_delta)
    /// clones the previous epoch's `Arc` for every payload that requantized
    /// bit-identically, so pointer equality here tells
    /// `StepEngine::swap_weights` which resident handles (and cached device
    /// conversions) it may keep.  Pointer-unequal payloads may still be
    /// bytewise equal — callers must treat that as "changed" (a false
    /// positive costs one re-stage, never stale bytes).
    pub fn same_payload(&self, other: &HostTensor) -> bool {
        match (self, other) {
            (HostTensor::F32 { data: a, .. },
             HostTensor::F32 { data: b, .. }) => Arc::ptr_eq(a, b),
            (HostTensor::I32 { data: a, .. },
             HostTensor::I32 { data: b, .. }) => Arc::ptr_eq(a, b),
            (HostTensor::I8 { data: a, .. },
             HostTensor::I8 { data: b, .. }) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Payload size in bytes (the unit of the `bytes_h2d`/`bytes_d2h`
    /// transfer accounting in `ArtifactStore`).
    pub fn byte_len(&self) -> u64 {
        let elem = match self {
            HostTensor::F32 { .. } | HostTensor::I32 { .. } => 4,
            HostTensor::I8 { .. } => 1,
        };
        (numel(self.shape()) * elem) as u64
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data.as_slice(),
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected f32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data.as_slice(),
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected i32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn as_i8(&self) -> &[i8] {
        match self {
            HostTensor::I8 { data, .. } => data.as_slice(),
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected i8 tensor, got {}", other.dtype_str()),
        }
    }

    /// Take the payload out.  Zero-copy when this tensor is the sole owner
    /// (the common case: artifact outputs and freshly built inputs); falls
    /// back to a clone when the buffer is shared.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => {
                Arc::try_unwrap(data).unwrap_or_else(|a| (*a).clone())
            }
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected f32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn into_i32(self) -> Vec<i32> {
        match self {
            HostTensor::I32 { data, .. } => {
                Arc::try_unwrap(data).unwrap_or_else(|a| (*a).clone())
            }
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected i32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn into_i8(self) -> Vec<i8> {
        match self {
            HostTensor::I8 { data, .. } => {
                Arc::try_unwrap(data).unwrap_or_else(|a| (*a).clone())
            }
            // lint: allow(panic, dtype contract — callers pick the accessor the artifact signature pins; a mismatch is a caller bug, not runtime input)
            other => panic!("expected i8 tensor, got {}", other.dtype_str()),
        }
    }

    /// Convert to a PJRT literal (copies the payload into device format —
    /// this is the host-side "upload" cost that `ArtifactStore`'s resident
    /// input handles cache across calls).
    pub fn to_literal(&self) -> Result<Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, shape, bytes)?
            }
            HostTensor::I32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::S32, shape, bytes)?
            }
            HostTensor::I8 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len())
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::S8, shape, bytes)?
            }
        };
        Ok(lit)
    }

    /// Convert back from a PJRT literal (copies out of device format).
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => {
                Ok(HostTensor::f32(&dims, lit.to_vec::<f32>()?))
            }
            ElementType::S32 => {
                Ok(HostTensor::i32(&dims, lit.to_vec::<i32>()?))
            }
            ElementType::S8 => {
                Ok(HostTensor::i8(&dims, lit.to_vec::<i8>()?))
            }
            ty => bail!("unsupported literal element type {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[4], vec![-1, 0, 7, 42]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32(), t.as_i32());
    }

    #[test]
    fn literal_roundtrip_i8() {
        let t = HostTensor::i8(&[2, 2], vec![-128, -1, 0, 127]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i8(), t.as_i8());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(3.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert!(back.shape().is_empty());
        assert_eq!(back.as_f32(), &[3.5]);
    }

    #[test]
    fn shared_storage_is_zero_copy() {
        let buf = Arc::new(vec![1.0f32, 2.0, 3.0]);
        let t = HostTensor::f32_shared(&[3], buf.clone());
        // clone bumps the refcount instead of copying the payload
        let t2 = t.clone();
        assert!(std::ptr::eq(t.as_f32().as_ptr(), t2.as_f32().as_ptr()));
        assert_eq!(t.byte_len(), 12);
        drop((t, t2));
        // sole owner again: into_f32 moves the buffer out without copying
        let t3 = HostTensor::f32_shared(&[3], buf);
        let ptr = t3.as_f32().as_ptr();
        let v = t3.into_f32();
        assert!(std::ptr::eq(ptr, v.as_ptr()));
    }

    #[test]
    fn same_payload_is_pointer_equality_not_value_equality() {
        let buf = Arc::new(vec![1.0f32, 2.0]);
        let a = HostTensor::f32_shared(&[2], buf.clone());
        let b = HostTensor::f32_shared(&[2], buf);
        // same Arc → same payload, and clone preserves it
        assert!(a.same_payload(&b));
        assert!(a.same_payload(&a.clone()));
        // bytewise-equal but distinct allocation → NOT same payload
        let c = HostTensor::f32(&[2], vec![1.0, 2.0]);
        assert!(!a.same_payload(&c));
        // dtype mismatch is never the same payload
        let d = HostTensor::i8(&[2], vec![1, 2]);
        assert!(!a.same_payload(&d));
    }

    #[test]
    fn byte_len_by_dtype() {
        assert_eq!(HostTensor::i32(&[2, 2], vec![0; 4]).byte_len(), 16);
        assert_eq!(HostTensor::i8(&[5], vec![0; 5]).byte_len(), 5);
        assert_eq!(HostTensor::scalar_f32(1.0).byte_len(), 4);
    }
}
