//! Host-side tensors and conversions to/from PJRT `Literal`s.
//!
//! The coordinator keeps model state (parameters, Adam moments, token
//! batches, metrics) as plain Rust vectors and converts at artifact-call
//! boundaries.  All conversions are shape-checked against the manifest.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

/// Dense host tensor; dtype is encoded in the variant.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    I8 { shape: Vec<usize>, data: Vec<i8> },
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn i8(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor::I8 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![x] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::I8 { shape, .. } => shape,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "float32",
            HostTensor::I32 { .. } => "int32",
            HostTensor::I8 { .. } => "int8",
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            other => panic!("expected f32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32 { data, .. } => data,
            other => panic!("expected i32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn as_i8(&self) -> &[i8] {
        match self {
            HostTensor::I8 { data, .. } => data,
            other => panic!("expected i8 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => data,
            other => panic!("expected f32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn into_i32(self) -> Vec<i32> {
        match self {
            HostTensor::I32 { data, .. } => data,
            other => panic!("expected i32 tensor, got {}", other.dtype_str()),
        }
    }

    pub fn into_i8(self) -> Vec<i8> {
        match self {
            HostTensor::I8 { data, .. } => data,
            other => panic!("expected i8 tensor, got {}", other.dtype_str()),
        }
    }

    /// Convert to a PJRT literal (copies).
    pub fn to_literal(&self) -> Result<Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, shape, bytes)?
            }
            HostTensor::I32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::S32, shape, bytes)?
            }
            HostTensor::I8 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                               data.len())
                };
                Literal::create_from_shape_and_untyped_data(
                    ElementType::S8, shape, bytes)?
            }
        };
        Ok(lit)
    }

    /// Convert back from a PJRT literal.
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            ElementType::S8 => {
                Ok(HostTensor::I8 { shape: dims, data: lit.to_vec::<i8>()? })
            }
            ty => bail!("unsupported literal element type {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[4], vec![-1, 0, 7, 42]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32(), t.as_i32());
    }

    #[test]
    fn literal_roundtrip_i8() {
        let t = HostTensor::i8(&[2, 2], vec![-128, -1, 0, 127]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i8(), t.as_i8());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(3.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert!(back.shape().is_empty());
        assert_eq!(back.as_f32(), &[3.5]);
    }
}
