//! Synthetic verifiable-reward task families — the testbed's analog of the
//! paper's math benchmarks (DESIGN.md §2 maps each family to a benchmark).
//!
//! Every family samples `(prompt, answer)` pairs from a seeded RNG with a
//! difficulty knob; the reward is exact string match of the generated span
//! (RLVR-style binary verification, like the paper's GSM8K/AIME/DeepScaleR
//! setups).  Train and test splits use disjoint RNG streams.

use crate::util::rng::Pcg64;

/// A single RLVR problem.
#[derive(Clone, Debug)]
pub struct Problem {
    pub prompt: String,
    pub answer: String,
}

/// Task family identifiers, ordered as reported in the Table 3 analog.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// chained +/- arithmetic — GSM8K analog (multi-step word-free math)
    ArithChain,
    /// modular arithmetic — AIME analog (competition-style number theory)
    Modular,
    /// multi-digit multiplication — MATH analog
    MultiDigit,
    /// min/max over a list — AMC analog (discrete comparison)
    Compare,
    /// greatest common divisor — Minerva analog
    Gcd,
    /// next term of a progression — OlympiadBench analog
    Sequence,
}

pub const ALL_FAMILIES: [Family; 6] = [
    Family::ArithChain,
    Family::Modular,
    Family::MultiDigit,
    Family::Compare,
    Family::Gcd,
    Family::Sequence,
];

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::ArithChain => "arith",
            Family::Modular => "modular",
            Family::MultiDigit => "multidigit",
            Family::Compare => "compare",
            Family::Gcd => "gcd",
            Family::Sequence => "sequence",
        }
    }

    /// Paper benchmark this family stands in for (Table 3 columns).
    pub fn paper_analog(&self) -> &'static str {
        match self {
            Family::ArithChain => "MATH",
            Family::Modular => "AIME24",
            Family::MultiDigit => "AMC",
            Family::Compare => "Minerva",
            Family::Gcd => "Olympiad",
            Family::Sequence => "GSM8K",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        ALL_FAMILIES.iter().copied().find(|f| f.name() == s)
    }

    /// Sample one problem.  `difficulty` in [0, 3]: 0 is trivial (SFT
    /// warm-up regime), higher stretches operand ranges / term counts so RL
    /// has headroom, mirroring the paper's staged context-length schedule.
    pub fn sample(&self, rng: &mut Pcg64, difficulty: usize) -> Problem {
        let d = difficulty.min(3) as i64;
        match self {
            Family::ArithChain => {
                let terms = 2 + d.min(2) + rng.range_i64(0, 1);
                let hi = 9 + d * 21; // 9, 30, 51, 72
                let mut acc = rng.range_i64(0, hi);
                let mut s = format!("{acc}");
                for _ in 1..terms {
                    let v = rng.range_i64(0, hi);
                    if rng.f64() < 0.5 {
                        acc += v;
                        s.push('+');
                    } else {
                        acc -= v;
                        s.push('-');
                    }
                    s.push_str(&v.to_string());
                }
                s.push_str("=?");
                Problem { prompt: s, answer: acc.to_string() }
            }
            Family::Modular => {
                let hi = 9 + d * 13;
                let a = rng.range_i64(1, hi);
                let b = rng.range_i64(1, hi);
                let c = rng.range_i64(0, hi);
                let m = rng.range_i64(2, 7 + d * 3);
                let ans = (a * b + c).rem_euclid(m);
                Problem {
                    prompt: format!("({a}*{b}+{c})%{m}=?"),
                    answer: ans.to_string(),
                }
            }
            Family::MultiDigit => {
                let hi = 9 + d * 10; // up to 39x39
                let a = rng.range_i64(2, hi);
                let b = rng.range_i64(2, hi);
                Problem {
                    prompt: format!("{a}*{b}=?"),
                    answer: (a * b).to_string(),
                }
            }
            Family::Compare => {
                let n = 3 + d as usize;
                let hi = 50 + d * 150;
                let xs: Vec<i64> = (0..n).map(|_| rng.range_i64(0, hi)).collect();
                let use_max = rng.f64() < 0.5;
                let ans = if use_max {
                    *xs.iter().max().unwrap()
                } else {
                    *xs.iter().min().unwrap()
                };
                let list = xs
                    .iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                Problem {
                    prompt: format!("{}({list})=?", if use_max { "max" } else { "min" }),
                    answer: ans.to_string(),
                }
            }
            Family::Gcd => {
                let hi = 12 + d * 20;
                let g = rng.range_i64(1, 9 + d * 2);
                let a = g * rng.range_i64(1, hi / 2);
                let b = g * rng.range_i64(1, hi / 2);
                let ans = gcd(a.max(1), b.max(1));
                Problem {
                    prompt: format!("gcd({},{})=?", a.max(1), b.max(1)),
                    answer: ans.to_string(),
                }
            }
            Family::Sequence => {
                let start = rng.range_i64(0, 20 + d * 10);
                let step = rng.range_i64(1, 4 + d * 4);
                let geometric = d >= 2 && rng.f64() < 0.3;
                let (xs, ans) = if geometric {
                    let r = rng.range_i64(2, 3);
                    let s0 = rng.range_i64(1, 5);
                    let xs: Vec<i64> = (0..4).map(|i| s0 * r.pow(i as u32)).collect();
                    let ans = s0 * r.pow(4);
                    (xs, ans)
                } else {
                    let sign = if rng.f64() < 0.3 { -1 } else { 1 };
                    let xs: Vec<i64> =
                        (0..4).map(|i| start + sign * step * i).collect();
                    (xs.clone(), start + sign * step * 4)
                };
                let list = xs
                    .iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                Problem { prompt: format!("{list},?"), answer: ans.to_string() }
            }
        }
    }
}

pub fn gcd(mut a: i64, mut b: i64) -> i64 {
    while b != 0 {
        let t = b;
        b = a % t;
        a = t;
    }
    a.abs()
}

/// Exact-match verifier (the RLVR reward function): 1.0 iff the generated
/// span, trimmed, equals the reference answer.
pub fn verify(problem: &Problem, generated: &str) -> f32 {
    if generated.trim() == problem.answer {
        1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answers_are_correct_arith() {
        let mut rng = Pcg64::new(1);
        for d in 0..4 {
            for _ in 0..200 {
                let p = Family::ArithChain.sample(&mut rng, d);
                // re-evaluate the chain
                let expr = p.prompt.trim_end_matches("=?");
                let mut total = 0i64;
                let mut cur = String::new();
                let mut sign = 1;
                for c in expr.chars().chain(std::iter::once('+')) {
                    if c == '+' || c == '-' {
                        total += sign * cur.parse::<i64>().unwrap();
                        sign = if c == '+' { 1 } else { -1 };
                        cur.clear();
                    } else {
                        cur.push(c);
                    }
                }
                assert_eq!(total.to_string(), p.answer, "{}", p.prompt);
            }
        }
    }

    #[test]
    fn answers_are_correct_modular() {
        let mut rng = Pcg64::new(2);
        for _ in 0..200 {
            let p = Family::Modular.sample(&mut rng, 3);
            let ans: i64 = p.answer.parse().unwrap();
            assert!(ans >= 0);
            let m: i64 = p.prompt[p.prompt.find('%').unwrap() + 1
                ..p.prompt.find('=').unwrap()]
                .parse()
                .unwrap();
            assert!(ans < m, "{} -> {}", p.prompt, p.answer);
        }
    }

    #[test]
    fn gcd_divides_operands() {
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            let p = Family::Gcd.sample(&mut rng, 2);
            let inner = &p.prompt[4..p.prompt.len() - 3];
            let (a, b) = inner.split_once(',').unwrap();
            let (a, b): (i64, i64) = (a.parse().unwrap(), b.parse().unwrap());
            let g: i64 = p.answer.parse().unwrap();
            assert_eq!(a % g, 0);
            assert_eq!(b % g, 0);
            assert_eq!(g, gcd(a, b));
        }
    }

    #[test]
    fn prompts_fit_charset_and_length() {
        use crate::tasks::tokenizer::Tokenizer;
        let tk = Tokenizer::new();
        let mut rng = Pcg64::new(4);
        for fam in ALL_FAMILIES {
            for d in 0..4 {
                for _ in 0..100 {
                    let p = fam.sample(&mut rng, d);
                    let ids = tk.encode_prompt(&p.prompt);
                    assert!(ids.len() <= 48, "prompt too long: {}", p.prompt);
                    let a = tk.encode(&p.answer);
                    assert!(a.len() <= 12, "answer too long: {}", p.answer);
                }
            }
        }
    }

    #[test]
    fn verify_exact_match_only() {
        let p = Problem { prompt: "1+1=?".into(), answer: "2".into() };
        assert_eq!(verify(&p, "2"), 1.0);
        assert_eq!(verify(&p, " 2 "), 1.0);
        assert_eq!(verify(&p, "3"), 0.0);
        assert_eq!(verify(&p, "2.0"), 0.0);
        assert_eq!(verify(&p, ""), 0.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        for fam in ALL_FAMILIES {
            let pa = fam.sample(&mut a, 1);
            let pb = fam.sample(&mut b, 1);
            assert_eq!(pa.prompt, pb.prompt);
            assert_eq!(pa.answer, pb.answer);
        }
    }
}
