//! Synthetic verifiable-reward workloads (the paper's math benchmarks,
//! simulated — see DESIGN.md §2) + the char-level tokenizer.

pub mod families;
pub mod suite;
pub mod tokenizer;

pub use families::{verify, Family, Problem, ALL_FAMILIES};
pub use suite::{encode_batch, encode_sft_batch, ProblemSampler, Suite};
pub use tokenizer::{Tokenizer, BOS, EOS, PAD};
