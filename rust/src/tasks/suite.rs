//! Task suites: named mixtures of families with difficulty + split handling.
//!
//! A suite is the analog of a paper dataset: `gsm8k` (single easy family),
//! `aime` (single hard family), `deepscaler` (the 6-family mixture used for
//! the Table 3 analog).  Train and test problems come from disjoint seeded
//! RNG streams so evaluation never sees training prompts.

use crate::util::rng::Pcg64;

use super::families::{Family, Problem, ALL_FAMILIES};
use super::tokenizer::Tokenizer;

#[derive(Clone, Debug)]
pub struct Suite {
    pub name: String,
    pub families: Vec<Family>,
    pub difficulty: usize,
}

impl Suite {
    pub fn by_name(name: &str) -> Option<Suite> {
        let mk = |families: Vec<Family>, difficulty| Suite {
            name: name.to_string(),
            families,
            difficulty,
        };
        match name {
            // PPO experiment: single mid-difficulty family (GSM8K analog)
            "gsm8k" => Some(mk(vec![Family::ArithChain], 1)),
            // DAPO experiment: hard single family (AIME analog)
            "aime" => Some(mk(vec![Family::Modular], 2)),
            // GRPO experiment: the 5+1-task mixture (DeepScaleR analog)
            "deepscaler" => Some(mk(ALL_FAMILIES.to_vec(), 2)),
            // smoke/debug
            "tiny" => Some(mk(vec![Family::Compare], 0)),
            _ => {
                // single-family suite by family name, e.g. "gcd"
                Family::parse(name).map(|f| mk(vec![f], 2))
            }
        }
    }

    /// Deterministic train-split sampler (stream 0).
    pub fn train_sampler(&self, seed: u64) -> ProblemSampler {
        ProblemSampler {
            rng: Pcg64::new(seed ^ 0x7261_696e),
            families: self.families.clone(),
            difficulty: self.difficulty,
        }
    }

    /// Fixed, reproducible test set: `n` problems per family (stream 1).
    pub fn test_set(&self, seed: u64, n_per_family: usize) -> Vec<(Family, Problem)> {
        let mut rng = Pcg64::new(seed ^ 0x7465_7374);
        let mut out = Vec::new();
        for &fam in &self.families {
            for _ in 0..n_per_family {
                out.push((fam, fam.sample(&mut rng, self.difficulty)));
            }
        }
        out
    }
}

pub struct ProblemSampler {
    rng: Pcg64,
    families: Vec<Family>,
    difficulty: usize,
}

impl ProblemSampler {
    pub fn next(&mut self) -> (Family, Problem) {
        let fam = self.families[self.rng.below(self.families.len() as u64) as usize];
        let p = fam.sample(&mut self.rng, self.difficulty);
        (fam, p)
    }

    pub fn batch(&mut self, n: usize) -> Vec<(Family, Problem)> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Encode problems into a fixed [B, S] rollout batch (left-aligned prompts,
/// PAD fill).  Returns (tokens, lens).  Panics if a prompt overflows
/// max_prompt — families are tested to stay within it.
pub fn encode_batch(tk: &Tokenizer, problems: &[&Problem], b: usize, s: usize,
                    max_prompt: usize) -> (Vec<i32>, Vec<i32>) {
    assert!(problems.len() <= b, "{} > batch {b}", problems.len());
    let mut tokens = vec![super::tokenizer::PAD; b * s];
    let mut lens = vec![1i32; b];
    for (r, p) in problems.iter().enumerate() {
        let ids = tk.encode_prompt(&p.prompt);
        assert!(ids.len() <= max_prompt,
                "prompt overflows max_prompt: {}", p.prompt);
        tokens[r * s..r * s + ids.len()].copy_from_slice(&ids);
        lens[r] = ids.len() as i32;
    }
    // unused rows: a lone BOS keeps prefill well-defined
    for r in problems.len()..b {
        tokens[r * s] = super::tokenizer::BOS;
    }
    (tokens, lens)
}

/// SFT pretraining batch: full (prompt + answer + EOS) sequences with the
/// loss mask over answer+EOS positions.  This builds the "base model" the
/// paper starts RL from (their Qwen/DeepSeek checkpoints).
pub fn encode_sft_batch(tk: &Tokenizer, problems: &[(Family, Problem)],
                        b: usize, s: usize) -> (Vec<i32>, Vec<f32>) {
    assert!(problems.len() <= b);
    let mut tokens = vec![super::tokenizer::PAD; b * s];
    let mut mask = vec![0.0f32; b * s];
    for (r, (_, p)) in problems.iter().enumerate() {
        let mut ids = tk.encode_prompt(&p.prompt);
        let plen = ids.len();
        ids.extend(tk.encode(&p.answer));
        ids.push(super::tokenizer::EOS);
        assert!(ids.len() <= s);
        tokens[r * s..r * s + ids.len()].copy_from_slice(&ids);
        for t in plen..ids.len() {
            mask[r * s + t] = 1.0;
        }
    }
    for r in problems.len()..b {
        tokens[r * s] = super::tokenizer::BOS;
    }
    (tokens, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_resolve() {
        for name in ["gsm8k", "aime", "deepscaler", "tiny", "gcd"] {
            let s = Suite::by_name(name).unwrap();
            assert!(!s.families.is_empty());
        }
        assert!(Suite::by_name("nope").is_none());
    }

    #[test]
    fn train_test_disjoint_streams() {
        let s = Suite::by_name("gsm8k").unwrap();
        let mut tr = s.train_sampler(7);
        let te = s.test_set(7, 50);
        let train_prompts: std::collections::HashSet<String> =
            (0..200).map(|_| tr.next().1.prompt).collect();
        let overlap = te
            .iter()
            .filter(|(_, p)| train_prompts.contains(&p.prompt))
            .count();
        // prompts can collide by value; streams must not be identical
        assert!(overlap < te.len() / 2);
    }

    #[test]
    fn encode_batch_layout() {
        let tk = Tokenizer::new();
        let s = Suite::by_name("deepscaler").unwrap();
        let probs = s.test_set(1, 2);
        let refs: Vec<&crate::tasks::families::Problem> =
            probs.iter().map(|(_, p)| p).collect();
        let (tokens, lens) = encode_batch(&tk, &refs, 16, 128, 48);
        assert_eq!(tokens.len(), 16 * 128);
        for (r, p) in refs.iter().enumerate() {
            let l = lens[r] as usize;
            assert_eq!(tokens[r * 128], super::super::tokenizer::BOS);
            let dec = tk.decode(&tokens[r * 128..r * 128 + l]);
            assert_eq!(dec, p.prompt);
        }
        // unused rows are BOS-only
        assert_eq!(tokens[15 * 128], super::super::tokenizer::BOS);
        assert_eq!(tokens[15 * 128 + 1], super::super::tokenizer::PAD);
    }

    #[test]
    fn sft_mask_covers_answer_and_eos() {
        let tk = Tokenizer::new();
        let s = Suite::by_name("gsm8k").unwrap();
        let probs = s.test_set(2, 1);
        let (tokens, mask) = encode_sft_batch(&tk, &probs, 4, 128);
        let p = &probs[0].1;
        let plen = tk.encode_prompt(&p.prompt).len();
        let alen = tk.encode(&p.answer).len();
        let row_mask: f32 = mask[..128].iter().sum();
        assert_eq!(row_mask as usize, alen + 1); // answer + EOS
        assert_eq!(tokens[plen + alen], super::super::tokenizer::EOS);
        assert_eq!(mask[plen - 1], 0.0);
        assert_eq!(mask[plen], 1.0);
    }
}
