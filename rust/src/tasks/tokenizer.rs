//! Character-level tokenizer over the 64-symbol vocabulary the artifacts
//! were compiled for.  IDs 0/1/2 are PAD/BOS/EOS (mirrored in the manifest);
//! the charset covers digits, arithmetic operators and lowercase letters —
//! everything the synthetic task families emit.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Characters mapped to ids 3..3+len; must stay within vocab_size-3 = 61.
pub const CHARSET: &str = "0123456789+-*/=%()<>., ?abcdefghijklmnopqrstuvwxyz";

#[derive(Clone, Debug)]
pub struct Tokenizer {
    to_id: [i32; 128],
    to_char: Vec<char>,
    pub vocab_size: usize,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut to_id = [-1i32; 128];
        let mut to_char = vec!['\0', '\u{1}', '\u{2}']; // specials
        for (i, c) in CHARSET.chars().enumerate() {
            to_id[c as usize] = 3 + i as i32;
            to_char.push(c);
        }
        Tokenizer { to_id, to_char, vocab_size: 3 + CHARSET.len() }
    }

    /// Encode text (panics on out-of-charset characters — task generators
    /// only emit CHARSET).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .map(|c| {
                let id = if (c as usize) < 128 { self.to_id[c as usize] } else { -1 };
                assert!(id >= 0, "character {c:?} not in charset");
                id
            })
            .collect()
    }

    /// Encode a prompt with BOS: `[BOS] + chars`.
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        out.extend(self.encode(text));
        out
    }

    /// Decode ids, stopping at EOS/PAD; unknown ids render as '\u{fffd}'.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS || id == PAD {
                break;
            }
            if id == BOS {
                continue;
            }
            out.push(
                self.to_char
                    .get(id as usize)
                    .copied()
                    .unwrap_or('\u{fffd}'),
            );
        }
        out
    }

    /// Decode the generated span of a rollout row: tokens after `prompt_len`
    /// up to EOS.
    pub fn decode_generation(&self, row: &[i32], prompt_len: usize) -> String {
        self.decode(&row[prompt_len.min(row.len())..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tk = Tokenizer::new();
        let s = "12+34=? max(7,9)";
        let ids = tk.encode(s);
        assert_eq!(tk.decode(&ids), s);
    }

    #[test]
    fn vocab_fits_model() {
        let tk = Tokenizer::new();
        assert!(tk.vocab_size <= 64, "vocab {} > 64", tk.vocab_size);
        for c in CHARSET.chars() {
            let ids = tk.encode(&c.to_string());
            assert!(ids[0] >= 3 && (ids[0] as usize) < tk.vocab_size);
        }
    }

    #[test]
    fn decode_stops_at_eos() {
        let tk = Tokenizer::new();
        let mut ids = tk.encode("42");
        ids.push(EOS);
        ids.extend(tk.encode("99"));
        assert_eq!(tk.decode(&ids), "42");
    }

    #[test]
    fn prompt_has_bos() {
        let tk = Tokenizer::new();
        let ids = tk.encode_prompt("1+1=?");
        assert_eq!(ids[0], BOS);
        assert_eq!(tk.decode(&ids), "1+1=?");
    }

    #[test]
    fn charset_ids_unique() {
        let tk = Tokenizer::new();
        let mut seen = std::collections::HashSet::new();
        for c in CHARSET.chars() {
            assert!(seen.insert(tk.encode(&c.to_string())[0]));
        }
    }
}
