//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated keys,
//! and positional arguments.  Each binary declares its options and gets
//! `--help` for free.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli { bin, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.bin, self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => "(flag)".to_string(),
                (Some(d), _) => format!("(default: {d})"),
                (None, _) => "(required)".to_string(),
            };
            s.push_str(&format!("  --{:24} {} {}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse a raw argv slice (without the binary name).
    pub fn parse_from(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                let val = if spec.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{key} needs a value"))?
                };
                args.values.entry(key).or_default().push(val);
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        // fill defaults & check required
        for o in &self.opts {
            if !args.values.contains_key(o.name) {
                if let Some(d) = &o.default {
                    args.values
                        .entry(o.name.to_string())
                        .or_default()
                        .push(d.clone());
                } else if !o.is_flag {
                    return Err(format!("missing required --{}\n\n{}", o.name,
                                       self.usage()));
                }
            }
        }
        Ok(args)
    }

    /// Parse the process argv; exits with usage on error or --help.
    pub fn parse(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str) -> String {
        self.get(key).unwrap_or_default().to_string()
    }

    pub fn usize(&self, key: &str) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("option --{key} must be an integer"))
    }

    pub fn u64(&self, key: &str) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("option --{key} must be an integer"))
    }

    pub fn f64(&self, key: &str) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("option --{key} must be a number"))
    }

    pub fn f32(&self, key: &str) -> f32 {
        self.f64(key) as f32
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn all(&self, key: &str) -> Vec<String> {
        self.values.get(key).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", "10", "steps")
            .opt("lr", "0.1", "learning rate")
            .flag("verbose", "verbosity")
            .opt_req("mode", "mode")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = cli().parse_from(&v(&["--mode", "x", "--steps=25"])).unwrap();
        assert_eq!(a.usize("steps"), 25);
        assert_eq!(a.f64("lr"), 0.1);
        assert_eq!(a.str("mode"), "x");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn flags_and_positional() {
        let a = cli()
            .parse_from(&v(&["--mode", "x", "--verbose", "pos1"]))
            .unwrap();
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(&v(&["--steps", "5"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse_from(&v(&["--mode", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn repeated_keys_collected() {
        let a = cli()
            .parse_from(&v(&["--mode", "a", "--mode", "b"]))
            .unwrap();
        assert_eq!(a.all("mode"), vec!["a", "b"]);
        assert_eq!(a.str("mode"), "b");
    }
}
