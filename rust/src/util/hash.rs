//! FNV-1a 64-bit hashing for checkpoint integrity.
//!
//! The offline build ships no hashing crates, so checkpoint payloads and
//! manifests carry a hand-rolled FNV-1a digest: simple, allocation-free,
//! byte-order independent (it hashes the serialized bytes), and plenty for
//! torn/truncated-write *detection* — this is an integrity checksum against
//! partial writes and bit rot, not a cryptographic signature.  Both the
//! [`ParamStore`](crate::runtime::ParamStore) binary format (V2 header) and
//! the [`rl::checkpoint`](crate::rl::checkpoint) manifest use it, so the
//! constants here are load-bearing for every checkpoint on disk: changing
//! them invalidates existing snapshots and requires a format-version bump.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One-shot FNV-1a 64 over a byte slice.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_continue(FNV_OFFSET, bytes)
}

/// Streaming form: fold more bytes into an existing digest (start from
/// [`FNV_OFFSET`]).  Lets multi-section payloads checksum without
/// concatenating buffers.
#[inline]
pub fn fnv1a64_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the digest values: checksums live inside on-disk checkpoint
    /// formats, so these bits must never drift without a version bump.
    #[test]
    fn fnv1a64_pinned_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f737_10b0);
    }

    /// Streaming in chunks must equal the one-shot digest.
    #[test]
    fn streaming_matches_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let whole = fnv1a64(data);
        let mut h = FNV_OFFSET;
        for chunk in data.chunks(7) {
            h = fnv1a64_continue(h, chunk);
        }
        assert_eq!(h, whole);
    }

    /// A single flipped bit anywhere changes the digest (the torn-write
    /// detection property the checkpoint loader relies on).
    #[test]
    fn single_bit_flips_detected() {
        let base: Vec<u8> = (0..64u8).collect();
        let h0 = fnv1a64(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut tampered = base.clone();
                tampered[i] ^= 1 << bit;
                assert_ne!(fnv1a64(&tampered), h0, "byte {i} bit {bit}");
            }
        }
    }
}
