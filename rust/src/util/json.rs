//! Minimal JSON parser/writer (serde is unavailable in the offline image).
//!
//! Covers the full JSON grammar; used for `artifacts/manifest.json`,
//! experiment configs, and the JSONL metric logs.  Numbers are kept as f64
//! plus an integer fast path, which is ample for manifests and metrics.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- constructors ------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- parse --------------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- write ----------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no inf/nan; emit null (metrics may overflow)
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("utf8"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("utf8"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 code point
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xe0 {
        2
    } else if b < 0xf0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.req("n").as_usize(), Some(42));
        assert_eq!(v.req("s").as_str(), Some("hi"));
        assert_eq!(v.req("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn nonfinite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn nested_depth() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
