//! Leveled stderr logging with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info

pub fn set_level(l: Level) {
    VERBOSITY.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: &str) {
    if (l as u8) <= level() {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{t:.3}] {tag} {module}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $mod,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $mod,
                                   &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $mod,
                                   &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        set_level(Level::Debug);
        assert_eq!(level(), 3);
        set_level(Level::Info);
        assert_eq!(level(), 2);
    }
}
