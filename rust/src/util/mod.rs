//! Self-contained infrastructure substrate.
//!
//! The offline build image ships only the `xla` crate and its transitive
//! dependencies, so the usual ecosystem crates (rand, serde, clap, tokio,
//! criterion, proptest) are unavailable.  This module provides the small,
//! tested subset the coordinator needs; DESIGN.md §2 records the
//! substitution.

pub mod cli;
pub mod hash;
pub mod json;
pub mod logging;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod timer;
