//! Fixed-size worker thread pool with scoped parallel-for (tokio is
//! unavailable offline; the coordinator's concurrency needs are CPU-bound
//! fan-out + channels, which std threads cover), plus a single-threaded
//! buffer free-list ([`F32Pool`]) for the rollout hot path.

use std::cell::RefCell;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("qurl-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool closed")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Single-threaded free-list of `f32` buffers.
///
/// Engines that fill their own logits blocks every tick (the scheduler
/// path emits one block per prefill/decode call) recycle spent
/// allocations through this instead of hitting the allocator once per
/// tick: a dropped [`LogitsBlock`](crate::coordinator::engine::LogitsBlock)
/// returns its storage here and the next call's block reuses it.
/// Deliberately not `Sync` — each engine owns its pool behind an `Rc`, and
/// engines never cross threads (see `coordinator::service`'s worker
/// model).
pub struct F32Pool {
    free: RefCell<Vec<Vec<f32>>>,
}

/// Retained free buffers are capped so a one-off wide call cannot pin
/// memory forever.
const POOL_MAX_FREE: usize = 64;

impl F32Pool {
    pub fn new() -> F32Pool {
        F32Pool { free: RefCell::new(Vec::new()) }
    }

    /// An empty buffer with at least `capacity` reserved, reusing a
    /// recycled allocation when one is available.
    pub fn take(&self, capacity: usize) -> Vec<f32> {
        match self.free.borrow_mut().pop() {
            Some(mut v) => {
                v.clear();
                if v.capacity() < capacity {
                    v.reserve(capacity);
                }
                v
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// Return a spent buffer to the free list.
    pub fn put(&self, v: Vec<f32>) {
        let mut free = self.free.borrow_mut();
        if free.len() < POOL_MAX_FREE && v.capacity() > 0 {
            free.push(v);
        }
    }

    /// Buffers currently parked on the free list (test observability).
    pub fn free_count(&self) -> usize {
        self.free.borrow().len()
    }
}

/// Parallel map over indexed chunks using plain scoped threads (no pool
/// needed — used by CPU-side quantization mirrors over parameter slabs).
pub fn par_chunks<T: Sync, R: Send>(
    data: &[T],
    chunk: usize,
    threads: usize,
    f: impl Fn(usize, &[T]) -> R + Sync,
) -> Vec<R> {
    assert!(chunk > 0);
    let chunks: Vec<(usize, &[T])> = data.chunks(chunk).enumerate().collect();
    let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(chunks.len()));
    let next = std::sync::atomic::AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads.max(1) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= chunks.len() {
                    break;
                }
                let (idx, slice) = chunks[i];
                let r = f(idx, slice);
                results.lock().unwrap().push((idx, r));
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_chunks_ordered() {
        let data: Vec<u64> = (0..1000).collect();
        let sums = par_chunks(&data, 100, 4, |_, xs| xs.iter().sum::<u64>());
        assert_eq!(sums.len(), 10);
        assert_eq!(sums.iter().sum::<u64>(), 499_500);
        assert_eq!(sums[0], (0..100).sum::<u64>());
    }

    #[test]
    fn pool_drop_joins() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.len(), 2);
        drop(pool); // must not hang
    }

    #[test]
    fn f32_pool_recycles_allocations() {
        let pool = F32Pool::new();
        let mut a = pool.take(16);
        a.extend([1.0; 16]);
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.free_count(), 1);
        let b = pool.take(8);
        assert!(b.is_empty(), "recycled buffer not cleared");
        assert!(std::ptr::eq(ptr, b.as_ptr()), "allocation not reused");
        assert_eq!(pool.free_count(), 0);
    }
}
